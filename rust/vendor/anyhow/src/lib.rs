//! Vendored minimal `anyhow` shim.
//!
//! The build environment has no network access, so instead of the real
//! `anyhow` crate this path dependency provides the subset of its API the
//! `fast-esrnn` codebase uses: a string-backed [`Error`] with a context
//! chain and a typed root-cause payload ([`Error::new`] /
//! [`Error::downcast_ref`], used by the serving layer to recognize
//! `QueueFull` rejections), the [`Result`] alias, the
//! [`anyhow!`]/[`bail!`] macros and the [`Context`] extension trait.
//! Swapping back to the real crate is a one-line change in the root
//! `Cargo.toml`; no call site would change.

use std::any::Any;
use std::fmt;

/// A string-backed error with a chain of context frames and an optional
/// typed root-cause payload.
///
/// `chain[0]` is the outermost (most recently attached) context; the last
/// entry is the root cause. `Display` shows the outermost frame, `{:#}`
/// (alternate) shows the whole chain joined by `": "` — mirroring the real
/// crate's formatting contract. Errors built from a concrete
/// `std::error::Error` (via [`Error::new`] or `?` conversion) retain the
/// original value, recoverable through [`Error::downcast_ref`] no matter
/// how many context frames were stacked on top — same contract as the
/// real crate.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a displayable message (root cause).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()], payload: None }
    }

    /// Build an error from a concrete error value, keeping the value as a
    /// typed payload so callers can [`downcast_ref`](Self::downcast_ref)
    /// it back out (the serving layer maps `QueueFull` to HTTP 429 this
    /// way).
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self {
            chain: vec![error.to_string()],
            payload: Some(Box::new(error)),
        }
    }

    /// Attach an outer context frame (the payload is preserved).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The typed root cause, if this error was built from a concrete
    /// error value of type `E`.
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }

    /// Whether the root cause is a value of type `E`.
    pub fn is<E: 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

mod private {
    /// Sealed unifier over "things that convert into [`crate::Error`]":
    /// our own `Error` (identity) and any std error. Mirrors the real
    /// crate's private `ext::StdError` trick to avoid overlapping impls.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: private::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(format!("{e:#}"), "bad value 42");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = io_fail()
            .with_context(|| format!("reading {}", "x.json"))
            .unwrap_err()
            .context("loading corpus");
        assert_eq!(e.to_string(), "loading corpus");
        assert_eq!(format!("{e:#}"), "loading corpus: reading x.json: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
    }

    #[test]
    fn context_on_own_result_type() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[derive(Debug, PartialEq)]
    struct Marker(u32);

    impl fmt::Display for Marker {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "marker {}", self.0)
        }
    }

    impl std::error::Error for Marker {}

    #[test]
    fn downcast_recovers_typed_root_cause() {
        let e = Error::new(Marker(7));
        assert!(e.is::<Marker>());
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(!e.is::<std::io::Error>());
        // A plain message error has no payload.
        assert!(!anyhow!("plain").is::<Marker>());
    }

    #[test]
    fn payload_survives_context_and_question_mark() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), Marker> = Err(Marker(9));
            r?; // `?` converts via From, keeping the payload
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: marker 9");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(9)));
    }
}
