//! Stub of the `xla` (PJRT C API) crate surface used by
//! `fast_esrnn::runtime::pjrt`.
//!
//! The offline build environment cannot link libxla, so this crate makes
//! `--features pjrt` *compile* everywhere while failing fast at runtime:
//! [`PjRtClient::cpu`] — the first call every PJRT code path makes —
//! returns an error explaining how to swap in the real bindings (point the
//! `xla` path dependency in the root `Cargo.toml` at the real crate, or
//! use a `[patch]` section). No other entry point can be reached without
//! a client, so the remaining methods are honest `unreachable!`s.

use std::fmt;

/// Error type mirroring the real crate's (implements `std::error::Error`
/// so `?` converts it into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: the `xla` dependency is the vendored \
         stub (rust/vendor/xla). To run the PJRT backend, point the `xla` \
         path dependency in Cargo.toml at the real PJRT bindings and \
         rebuild with --features pjrt"
            .to_string(),
    )
}

/// Host literal (stub: carries no data; unreachable without a client).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot exist: PjRtClient::cpu() errors")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot exist")
    }
}

/// PJRT client (stub: construction always fails with a clear message).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot exist")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("vendored stub"));
    }

    #[test]
    fn literal_ops_error_not_panic() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
