//! SIMD-equivalence suite: the lane-vectorized kernels
//! (`runtime::native::lanes`) against the scalar oracle
//! (`runtime::native::model`), at two levels:
//!
//! * **kernel level** — property tests over toy shapes *and* every real
//!   Table-1 frequency shape (including the §8.2 hourly dual path):
//!   lane forward output/levels/windows and lane backward gradients
//!   (shared RNN weights + per-series Holt-Winters leaves) must match
//!   the scalar oracle within fast-math tolerance, for ragged batch
//!   sizes that do not fill a lane and for masked-out slots (exact
//!   zeros);
//! * **backend level** — a scalar-mode and a lane-mode `NativeBackend`
//!   (different thread counts on purpose) serve the same `train_step`
//!   and `predict` programs for every Table-1 frequency; losses and
//!   forecasts must agree.
//!
//! Tolerances: each lane runs the scalar operation sequence with the
//! fast transcendental approximations (≤ 3e-7 per op, see
//! `simd::Lanes`), so forward values agree to ~1e-5 and gradients to
//! well under 1%; real kernel bugs (dropped terms, index mixups,
//! lane/slot transposition) show up orders of magnitude above these
//! bounds. This suite is run by name in CI (`run_named_tests.sh
//! simd_parity lane`), so renaming or feature-gating it fails the build
//! instead of silently skipping.

use std::collections::HashMap;

use fast_esrnn::runtime::native::lanes;
use fast_esrnn::runtime::native::model::{self, RnnView, Shape};
use fast_esrnn::runtime::native::{ComputeMode, NativeBackend};
use fast_esrnn::runtime::{Backend, HostTensor, Manifest};
use fast_esrnn::simd::LANES;
use fast_esrnn::util::prop::{forall, gen_positive_series_dual};
use fast_esrnn::util::rng::Rng;

// ---------------------------------------------------------------- helpers

/// Owned toy parameters (same construction as the native_backend suite).
struct Params {
    cells: Vec<(Vec<f32>, Vec<f32>)>,
    dense_w: Vec<f32>,
    dense_b: Vec<f32>,
    out_w: Vec<f32>,
    out_b: Vec<f32>,
    alpha: Vec<f32>,
    gamma: Vec<f32>,
    gamma2: Vec<f32>,
    log_s: Vec<f32>,
}

fn toy_params(shape: &Shape, n_series: usize, rng: &mut Rng) -> Params {
    let hid = shape.hidden;
    let mut cells = Vec::new();
    for &din in &shape.layer_din {
        let lim = (6.0 / (din + hid + 4 * hid) as f64).sqrt();
        cells.push((
            (0..(din + hid) * 4 * hid)
                .map(|_| rng.uniform(-lim, lim) as f32)
                .collect(),
            vec![0.0; 4 * hid],
        ));
    }
    let lim_d = (6.0 / (2 * hid) as f64).sqrt();
    let lim_o = (6.0 / (hid + shape.h) as f64).sqrt();
    Params {
        cells,
        dense_w: (0..hid * hid)
            .map(|_| rng.uniform(-lim_d, lim_d) as f32)
            .collect(),
        dense_b: vec![0.0; hid],
        out_w: (0..hid * shape.h)
            .map(|_| rng.uniform(-lim_o, lim_o) as f32)
            .collect(),
        out_b: vec![0.0; shape.h],
        alpha: (0..n_series).map(|_| rng.uniform(-1.5, 0.5) as f32).collect(),
        gamma: (0..n_series).map(|_| rng.uniform(-3.0, -0.5) as f32).collect(),
        gamma2: (0..n_series)
            .map(|_| rng.uniform(-3.0, -0.5) as f32)
            .collect(),
        log_s: (0..n_series * shape.s_total())
            .map(|_| rng.uniform(-0.2, 0.2) as f32)
            .collect(),
    }
}

fn cell_refs(p: &Params) -> Vec<(&[f32], &[f32])> {
    p.cells.iter().map(|c| (c.0.as_slice(), c.1.as_slice())).collect()
}

fn view<'a>(p: &'a Params, cells: &'a [(&'a [f32], &'a [f32])]) -> RnnView<'a> {
    RnnView {
        cells,
        dense_w: &p.dense_w,
        dense_b: &p.dense_b,
        out_w: &p.out_w,
        out_b: &p.out_b,
    }
}

fn hw_view<'a>(p: &'a Params, shape: &Shape, i: usize) -> model::HwView<'a> {
    let w = shape.s_total();
    model::HwView {
        alpha_logit: p.alpha[i],
        gamma_logit: p.gamma[i],
        gamma2_logit: p.gamma2[i],
        log_s_init: &p.log_s[i * w..(i + 1) * w],
    }
}

/// Batch series with both cycles planted when the shape is dual, so the
/// secondary seasonal track carries gradient signal.
fn gen_batch(shape: &Shape, b: usize, rng: &mut Rng) -> Vec<f32> {
    let mut y = Vec::with_capacity(b * shape.c);
    for _ in 0..b {
        y.extend(gen_positive_series_dual(rng, shape.c, shape.s, shape.s2));
    }
    y
}

/// `|got - want| <= abs + rel·max(|got|, |want|)` with a labelled error.
fn close(got: f32, want: f32, rel: f32, abs: f32, what: &str)
         -> Result<(), String> {
    let tol = abs + rel * got.abs().max(want.abs());
    if (got - want).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: lane {got} vs scalar {want} (tol {tol:.3e})"))
    }
}

/// The shapes under test: two toy configs (fast, hammered by many random
/// cases) plus every Table-1 frequency (real C/P/layer counts, fewer
/// cases), dual hourly included.
fn parity_shapes() -> Vec<(String, Shape, usize)> {
    let backend = NativeBackend::with_threads_mode(1, ComputeMode::Scalar);
    let mut shapes = vec![
        ("toy".to_string(),
         Shape::new(4, 0, 4, 5, 20, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap(),
         6),
        ("toy_dual".to_string(),
         Shape::new(3, 6, 4, 5, 24, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap(),
         4),
    ];
    for freq in ["yearly", "quarterly", "monthly", "daily", "hourly"] {
        let cfg = backend.manifest().config(freq).unwrap().clone();
        shapes.push((
            freq.to_string(),
            Shape::new(cfg.seasonality, cfg.seasonality2, cfg.horizon,
                       cfg.input_window, cfg.length, cfg.hidden,
                       &cfg.dilations, 6)
                .unwrap(),
            1,
        ));
    }
    shapes
}

// --------------------------------------------------------- forward parity

#[test]
fn prop_lane_forward_matches_scalar_oracle() {
    for (name, shape, cases) in parity_shapes() {
        let shape = &shape;
        forall(301, cases, |r| {
            // Ragged sizes on purpose: 1..LANES+3 never tiles evenly.
            let b = 1 + r.below(LANES + 3);
            let y = gen_batch(shape, b, r);
            let seed = r.next_u64();
            (b, seed, y)
        }, |(b, seed, y)| {
            let (b, seed) = (*b, *seed);
            let mut rng = Rng::new(seed);
            let p = toy_params(shape, b, &mut rng);
            let mut cat = vec![0.0f32; b * 6];
            for i in 0..b {
                cat[i * 6 + i % 6] = 1.0;
            }
            let groups = lanes::marshal_groups(
                shape, b, y, &cat, None, &p.alpha, &p.gamma,
                if shape.dual() { &p.gamma2 } else { &[] }, &p.log_s);
            let cells = cell_refs(&p);
            let rnn = view(&p, &cells);
            for grp in &groups {
                let fwd = lanes::forward_lanes(shape, grp, &rnn, true);
                let fc = lanes::forecast_from_lanes(shape, &fwd);
                for l in 0..grp.fill {
                    let i = grp.start + l;
                    let sf = model::forward_series(
                        shape, &y[i * shape.c..(i + 1) * shape.c],
                        &cat[i * 6..(i + 1) * 6], &rnn, hw_view(&p, shape, i),
                        true);
                    for t in 0..shape.c {
                        close(fwd.levels[t * LANES + l], sf.levels[t], 1e-4,
                              1e-5, &format!("{name} b{b} level[{i},{t}]"))?;
                    }
                    for t in 0..shape.c + shape.h {
                        close(fwd.seas_ext[t * LANES + l], sf.seas_ext[t],
                              1e-4, 1e-5,
                              &format!("{name} b{b} seas_ext[{i},{t}]"))?;
                    }
                    for j in 0..shape.p * shape.in_w {
                        close(fwd.x[j * LANES + l], sf.x[j], 1e-4, 5e-5,
                              &format!("{name} b{b} x[{i},{j}]"))?;
                    }
                    for j in 0..shape.p * shape.h {
                        close(fwd.out[j * LANES + l], sf.out[j], 1e-3, 1e-4,
                              &format!("{name} b{b} out[{i},{j}]"))?;
                        close(fwd.z[j * LANES + l], sf.z[j], 1e-4, 5e-5,
                              &format!("{name} b{b} z[{i},{j}]"))?;
                    }
                    let want_fc = model::forecast_from(shape, &sf);
                    for k in 0..shape.h {
                        close(fc[k * LANES + l], want_fc[k], 1e-3, 1e-4,
                              &format!("{name} b{b} forecast[{i},{k}]"))?;
                    }
                }
            }
            Ok(())
        });
    }
}

// -------------------------------------------------------- backward parity

#[test]
fn prop_lane_backward_matches_scalar_oracle() {
    for (name, shape, cases) in parity_shapes() {
        let shape = &shape;
        forall(302, cases, |r| {
            let b = 1 + r.below(LANES + 3);
            let y = gen_batch(shape, b, r);
            // Mask out one slot when the batch allows it, to cover the
            // masked-lane zero-gradient contract alongside live lanes.
            let masked = if b >= 3 { Some(r.below(b)) } else { None };
            let seed = r.next_u64();
            (b, seed, masked, y)
        }, |(b, seed, masked, y)| {
            let (b, seed) = (*b, *seed);
            let mut rng = Rng::new(seed);
            let p = toy_params(shape, b, &mut rng);
            let w = shape.s_total();
            let mut cat = vec![0.0f32; b * 6];
            for i in 0..b {
                cat[i * 6 + i % 6] = 1.0;
            }
            let mut mask = vec![1.0f32; b];
            if let Some(mi) = masked {
                mask[*mi] = 0.0;
            }
            let mask_sum: f32 = mask.iter().sum();
            let denom = (shape.valid_positions as f32 * mask_sum
                         * shape.h as f32)
                .max(1.0);
            let tau = 0.48f32;
            let cells = cell_refs(&p);
            let rnn = view(&p, &cells);

            // Scalar oracle: per-series backward into shared grads.
            let mut want_rnn = model::RnnGrads::zeros(shape);
            let mut want_series = Vec::with_capacity(b);
            let mut want_loss = 0.0f64;
            for i in 0..b {
                if mask[i] == 0.0 {
                    want_series.push(model::SeriesGrads::zeros(w));
                    continue;
                }
                let fwd = model::forward_series(
                    shape, &y[i * shape.c..(i + 1) * shape.c],
                    &cat[i * 6..(i + 1) * 6], &rnn, hw_view(&p, shape, i),
                    true);
                let (ln, dout, dz) =
                    model::pinball_seeds(shape, &fwd, tau, mask[i], denom);
                want_loss += ln;
                want_series.push(model::backward_series(
                    shape, &y[i * shape.c..(i + 1) * shape.c], &rnn, &fwd,
                    &dout, &dz, &mut want_rnn));
            }

            // Lane path.
            let groups = lanes::marshal_groups(
                shape, b, y, &cat, Some(&mask), &p.alpha, &p.gamma,
                if shape.dual() { &p.gamma2 } else { &[] }, &p.log_s);
            let mut got_rnn = model::RnnGrads::zeros(shape);
            let mut got_loss = 0.0f64;
            let mut got_series: Vec<(usize, usize, lanes::SeriesGradsLanes)> =
                Vec::new();
            for grp in &groups {
                let fwd = lanes::forward_lanes(shape, grp, &rnn, true);
                let (ln, dout, dz) = lanes::pinball_seeds_lanes(
                    shape, &fwd, tau, grp.mask, denom);
                got_loss += ln;
                let sg = lanes::backward_lanes(shape, grp, &rnn, &fwd, &dout,
                                               &dz, &mut got_rnn);
                got_series.push((grp.start, grp.fill, sg));
            }

            close(got_loss as f32, want_loss as f32, 1e-4, 1e-3,
                  &format!("{name} b{b} loss numerator"))?;

            // Shared RNN weight gradients.
            let pairs: Vec<(String, &[f32], &[f32])> = {
                let mut v: Vec<(String, &[f32], &[f32])> = Vec::new();
                for (li, (gw, gb)) in got_rnn.cells.iter().enumerate() {
                    v.push((format!("cells.{li}.w"), gw,
                            &want_rnn.cells[li].0));
                    v.push((format!("cells.{li}.b"), gb,
                            &want_rnn.cells[li].1));
                }
                v.push(("dense_w".into(), &got_rnn.dense_w,
                        &want_rnn.dense_w));
                v.push(("dense_b".into(), &got_rnn.dense_b,
                        &want_rnn.dense_b));
                v.push(("out_w".into(), &got_rnn.out_w, &want_rnn.out_w));
                v.push(("out_b".into(), &got_rnn.out_b, &want_rnn.out_b));
                v
            };
            for (gname, got, want) in pairs {
                for (j, (g, wv)) in got.iter().zip(want.iter()).enumerate() {
                    close(*g, *wv, 5e-3, 1e-4,
                          &format!("{name} b{b} grad {gname}[{j}]"))?;
                }
            }

            // Per-series Holt-Winters gradients, lane-demarshalled.
            for (start, fill, sg) in &got_series {
                for l in 0..*fill {
                    let i = start + l;
                    let ws = &want_series[i];
                    if mask[i] == 0.0 {
                        // Masked slots: exact zeros on both sides.
                        if sg.alpha_logit.0[l] != 0.0
                            || sg.gamma_logit.0[l] != 0.0
                            || sg.gamma2_logit.0[l] != 0.0
                        {
                            return Err(format!(
                                "{name} masked slot {i} has nonzero lane \
                                 gradient"));
                        }
                        continue;
                    }
                    close(sg.alpha_logit.0[l], ws.alpha_logit, 5e-3, 1e-4,
                          &format!("{name} b{b} d alpha[{i}]"))?;
                    close(sg.gamma_logit.0[l], ws.gamma_logit, 5e-3, 1e-4,
                          &format!("{name} b{b} d gamma[{i}]"))?;
                    close(sg.gamma2_logit.0[l], ws.gamma2_logit, 5e-3, 1e-4,
                          &format!("{name} b{b} d gamma2[{i}]"))?;
                    for k in 0..w {
                        close(sg.log_s_init[k * LANES + l], ws.log_s_init[k],
                              5e-3, 1e-4,
                              &format!("{name} b{b} d log_s[{i},{k}]"))?;
                    }
                }
            }
            Ok(())
        });
    }
}

// --------------------------------------------------- backend-level parity

/// Build the full train_step input map for `freq` at batch `b`.
fn train_state(backend: &NativeBackend, freq: &str, b: usize, seed: u64)
               -> HashMap<String, HostTensor> {
    let cfg = backend.manifest().config(freq).unwrap().clone();
    let w = cfg.seasonality + cfg.seasonality2;
    let dual = cfg.seasonality2 > 0;
    let mut rng = Rng::new(seed);
    let mut y = Vec::new();
    for _ in 0..b {
        y.extend(gen_positive_series_dual(&mut rng, cfg.length,
                                          cfg.seasonality, cfg.seasonality2));
    }
    let rnn = backend.execute_init(freq, 42).unwrap();
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    if dual {
        state.insert("params.series.gamma2_logit".into(),
                     HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    }
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, w], vec![0.0; b * w]).unwrap());
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));
    state.insert("data.y".into(),
                 HostTensor::new(vec![b, cfg.length], y).unwrap());
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    state.insert("data.cat".into(), HostTensor::new(vec![b, 6], cat).unwrap());
    let mut mask = vec![1.0f32; b];
    mask[b - 1] = 0.0; // one padded slot, so masking parity is exercised
    state.insert("data.mask".into(), HostTensor::new(vec![b], mask).unwrap());
    state.insert("lr".into(), HostTensor::scalar(1e-3));
    state
}

fn run_program(backend: &NativeBackend, name: &str,
               state: &HashMap<String, HostTensor>)
               -> Vec<(String, HostTensor)> {
    backend
        .execute_named(name, &mut |spec| {
            state.get(&spec.name).ok_or_else(
                || anyhow::anyhow!("missing `{}`", spec.name))
        })
        .unwrap()
}

#[test]
fn lane_backend_matches_scalar_backend_on_all_table1_freqs() {
    // Different thread counts on purpose: group/chunk partitioning must
    // not leak into the numerics in either mode.
    let scalar = NativeBackend::with_threads_mode(2, ComputeMode::Scalar);
    let lane = NativeBackend::with_threads_mode(3, ComputeMode::Lanes);
    let b = 5usize; // ragged: one partial lane group
    for freq in ["yearly", "quarterly", "monthly", "daily", "hourly"] {
        let state = train_state(&scalar, freq, b, 99);
        let name = Manifest::program_name(freq, b, "train_step");
        let s_out = run_program(&scalar, &name, &state);
        let l_out = run_program(&lane, &name, &state);
        assert_eq!(s_out[0].0, "loss");
        let (ls, ll) = (s_out[0].1.data[0], l_out[0].1.data[0]);
        assert!(ls.is_finite() && ll.is_finite(), "{freq}: non-finite loss");
        assert!((ls - ll).abs() <= 5e-4 * ls.abs().max(1e-2),
                "{freq}: scalar loss {ls} != lane loss {ll}");
        // Updated per-series alpha agrees (Adam on near-identical grads).
        let find = |outs: &[(String, HostTensor)], key: &str| -> Vec<f32> {
            outs.iter()
                .find(|(n, _)| n.as_str() == key)
                .map(|(_, t)| t.data.clone())
                .unwrap()
        };
        // 3.5e-3 ≳ 2·lr·mult: even a sign-flipped Adam direction on a
        // near-zero gradient stays inside; scatter/transposition bugs
        // land entire different series here and in the predict check.
        let sa = find(&s_out, "params.series.alpha_logit");
        let la = find(&l_out, "params.series.alpha_logit");
        for i in 0..b {
            assert!((sa[i] - la[i]).abs() <= 3.5e-3,
                    "{freq}: alpha[{i}] {s} vs {l}", s = sa[i], l = la[i]);
        }

        // Predict parity on the same parameters.
        let pname = Manifest::program_name(freq, b, "predict");
        let s_fc = run_program(&scalar, &pname, &state);
        let l_fc = run_program(&lane, &pname, &state);
        for (k, (sv, lv)) in
            s_fc[0].1.data.iter().zip(&l_fc[0].1.data).enumerate()
        {
            assert!(sv.is_finite() && lv.is_finite(),
                    "{freq}: non-finite forecast[{k}]");
            assert!((sv - lv).abs() <= 1e-3 * sv.abs().max(1.0),
                    "{freq}: forecast[{k}] scalar {sv} vs lane {lv}");
        }
    }
}
