//! Property-based tests over the coordinator's invariants (in-tree
//! `util::prop` driver — no proptest crate offline).
//!
//! These cover the L3 surfaces the paper's correctness rests on: the
//! Holt-Winters filter/primer, metrics bounds, baseline sanity, the
//! per-series store's gather/scatter discipline, batching coverage, and
//! JSON round-trips.

use std::collections::HashMap;

use fast_esrnn::baselines::{all_baselines, Comb, Forecaster, SeasonalNaive};
use fast_esrnn::coordinator::{Batcher, ParamStore};
use fast_esrnn::hw::{self, es_filter, seasonal_indices};
use fast_esrnn::metrics::{mase, pinball, smape};
use fast_esrnn::runtime::native::{ComputeMode, NativeBackend};
use fast_esrnn::runtime::{Backend, HostTensor, Manifest};
use fast_esrnn::util::json::Json;
use fast_esrnn::util::prop::{forall, gen_positive_series};
use fast_esrnn::util::rng::Rng;

#[test]
fn prop_seasonal_indices_normalized_positive() {
    forall(101, 200, |r| {
        let period = [1usize, 2, 4, 7, 12][r.below(5)];
        let len = period * 2 + r.below(120);
        (gen_positive_series(r, len.max(4), period), period)
    }, |(y, period)| {
        let idx = seasonal_indices(y, *period);
        if idx.len() != (*period).max(1) {
            return Err(format!("wrong length {}", idx.len()));
        }
        if !idx.iter().all(|v| *v > 0.0 && v.is_finite()) {
            return Err(format!("nonpositive index: {idx:?}"));
        }
        if y.len() >= 2 * period && *period > 1 {
            let mean: f32 = idx.iter().sum::<f32>() / *period as f32;
            if (mean - 1.0).abs() > 0.05 {
                return Err(format!("mean {mean} far from 1"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_es_filter_positive_and_bounded() {
    forall(102, 200, |r| {
        let s = [1usize, 4, 12][r.below(3)];
        let c = 2 * s + 8 + r.below(80);
        let y = gen_positive_series(r, c, s);
        let alpha = r.uniform(0.01, 0.99) as f32;
        let gamma = r.uniform(0.0, 0.5) as f32;
        let s_init: Vec<f32> =
            (0..s).map(|_| r.uniform(0.5, 1.5) as f32).collect();
        (y, alpha, gamma, s_init)
    }, |(y, alpha, gamma, s_init)| {
        let out = es_filter(y, *alpha, *gamma, s_init);
        if !out.levels.iter().all(|v| v.is_finite() && *v > 0.0) {
            return Err("nonpositive level".into());
        }
        if !out.seas.iter().all(|v| v.is_finite() && *v > 0.0) {
            return Err("nonpositive seasonality".into());
        }
        // Level stays within the envelope of deseasonalized observations.
        let lo = y.iter().zip(out.seas.iter())
            .map(|(v, s)| v / s).fold(f32::INFINITY, f32::min);
        let hi = y.iter().zip(out.seas.iter())
            .map(|(v, s)| v / s).fold(0.0f32, f32::max);
        for l in &out.levels {
            if *l < lo * 0.5 || *l > hi * 2.0 {
                return Err(format!("level {l} escapes envelope [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_es_filter_alpha_one_tracks_deseasonalized_obs() {
    forall(103, 100, |r| {
        let y = gen_positive_series(r, 40, 4);
        let s_init: Vec<f32> = (0..4).map(|_| r.uniform(0.7, 1.3) as f32).collect();
        (y, s_init)
    }, |(y, s_init)| {
        let out = es_filter(y, 1.0, 0.0, s_init);
        for t in 0..y.len() {
            let expect = y[t] / out.seas[t];
            if (out.levels[t] - expect).abs() > 1e-3 * expect {
                return Err(format!("alpha=1 level[{t}] {} != {}",
                                   out.levels[t], expect));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_smape_bounds_and_symmetry() {
    forall(104, 300, |r| {
        let h = 1 + r.below(18);
        let a: Vec<f32> = (0..h).map(|_| r.uniform(0.1, 1e4) as f32).collect();
        let b: Vec<f32> = (0..h).map(|_| r.uniform(0.1, 1e4) as f32).collect();
        (a, b)
    }, |(a, b)| {
        let v = smape(a, b);
        if !(0.0..=200.0 + 1e-9).contains(&v) {
            return Err(format!("smape {v} out of [0, 200]"));
        }
        if (smape(b, a) - v).abs() > 1e-9 {
            return Err("smape asymmetric".into());
        }
        if smape(a, a) > 1e-12 {
            return Err("smape(x,x) != 0".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mase_scales_linearly() {
    forall(105, 200, |r| {
        let h = 1 + r.below(12);
        let f: Vec<f32> = (0..h).map(|_| r.uniform(1.0, 100.0) as f32).collect();
        let a: Vec<f32> = (0..h).map(|_| r.uniform(1.0, 100.0) as f32).collect();
        let scale = r.uniform(0.1, 10.0) as f32;
        (f, a, scale)
    }, |(f, a, scale)| {
        let m1 = mase(f, a, *scale);
        let m2 = mase(f, a, *scale * 2.0);
        if (m1 / m2 - 2.0).abs() > 1e-6 {
            return Err(format!("mase not inverse-linear in scale: {m1} {m2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pinball_zero_iff_perfect_and_tau_weighting() {
    forall(106, 200, |r| {
        let h = 1 + r.below(8);
        let f: Vec<f32> = (0..h).map(|_| r.uniform(1.0, 50.0) as f32).collect();
        let d = r.uniform(0.1, 5.0) as f32;
        (f, d)
    }, |(f, d)| {
        if pinball(f, f, 0.48) > 1e-12 {
            return Err("pinball(x,x) != 0".into());
        }
        let over: Vec<f32> = f.iter().map(|v| v + d).collect();
        let under: Vec<f32> = f.iter().map(|v| v - d).collect();
        // tau < 0.5 ⇒ over-forecasting (actual below) costs more.
        let c_over = pinball(&over, f, 0.48);
        let c_under = pinball(&under, f, 0.48);
        if c_over <= c_under {
            return Err(format!("tau weighting broken: over {c_over} \
                                under {c_under}"));
        }
        Ok(())
    });
}

#[test]
fn prop_baselines_finite_positive() {
    forall(107, 120, |r| {
        let period = [1usize, 4, 12][r.below(3)];
        let len = (2 * period + 10 + r.below(90)).max(12);
        let y = gen_positive_series(r, len, period);
        let horizon = 1 + r.below(18);
        (y, period, horizon)
    }, |(y, period, horizon)| {
        for m in all_baselines() {
            let fc = m.forecast(y, *period, *horizon);
            if fc.len() != *horizon {
                return Err(format!("{} wrong horizon", m.name()));
            }
            if !fc.iter().all(|v| v.is_finite()) {
                return Err(format!("{} non-finite forecast", m.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_seasonal_naive_is_periodic() {
    forall(108, 100, |r| {
        let period = 2 + r.below(11);
        let len = period * 3 + r.below(30);
        let y = gen_positive_series(r, len, period);
        (y, period)
    }, |(y, period)| {
        let fc = SeasonalNaive.forecast(y, *period, period * 2);
        for h in 0..*period {
            if (fc[h] - fc[h + period]).abs() > 1e-6 {
                return Err("seasonal naive not periodic".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_store_scatter_gather_roundtrip() {
    forall(109, 100, |r| {
        let n = 2 + r.below(50);
        let s = 1 + r.below(12);
        let b = 1 + r.below(n.min(16));
        // random distinct indices
        let mut idx: Vec<usize> = (0..n).collect();
        r.shuffle(&mut idx);
        idx.truncate(b);
        let values: Vec<f32> = (0..b * s).map(|_| r.normal() as f32).collect();
        (n, s, idx, values)
    }, |(n, s, idx, values)| {
        let primers: Vec<hw::Primer> = (0..*n)
            .map(|_| hw::Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0; *s],
            })
            .collect();
        let mut store = ParamStore::from_primers(&primers, *s).unwrap();
        let valid = vec![true; idx.len()];
        let t = HostTensor::new(vec![idx.len(), *s], values.clone()).unwrap();
        store.scatter("params.series.log_s_init", idx, &valid, &t).unwrap();
        let g = store.gather_batch(idx).unwrap();
        if g["params.series.log_s_init"].data != *values {
            return Err("gather != scatter input".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_covers_without_duplicates() {
    forall(110, 100, |r| {
        let n = 1 + r.below(500);
        let b = 1 + r.below(64);
        let seed = r.next_u64();
        (n, b, seed)
    }, |(n, b, seed)| {
        let mut batcher = Batcher::new(*n, *b, *seed);
        let mut seen = vec![false; *n];
        for batch in batcher.epoch() {
            if batch.indices.len() != *b {
                return Err("batch wrong width".into());
            }
            for (slot, &i) in batch.indices.iter().enumerate() {
                if batch.valid[slot] {
                    if seen[i] {
                        return Err(format!("series {i} scheduled twice"));
                    }
                    seen[i] = true;
                }
            }
        }
        if !seen.iter().all(|s| *s) {
            return Err("not all series scheduled".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.chance(0.5)),
            2 => Json::Num((r.normal() * 100.0 * 128.0).round() / 128.0),
            3 => {
                let n = r.below(12);
                Json::Str((0..n).map(|_| {
                    ['a', 'é', '"', '\\', '\n', 'z', '7', ' ']
                        [r.below(8)]
                }).collect())
            }
            4 => Json::Arr((0..r.below(5))
                .map(|_| gen_json(r, depth - 1)).collect()),
            _ => Json::Obj((0..r.below(5))
                .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                .collect()),
        }
    }
    forall(111, 300, |r| gen_json(r, 3), |doc| {
        let text = doc.to_string();
        let re = Json::parse(&text)
            .map_err(|e| format!("reparse failed on `{text}`: {e}"))?;
        if re != *doc {
            return Err(format!("roundtrip mismatch: {doc:?} -> {re:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_comb_between_min_max_of_components() {
    forall(112, 100, |r| {
        let len = 60 + r.below(40);
        let y = gen_positive_series(r, len, 4);
        (y,)
    }, |(y,)| {
        use fast_esrnn::baselines::{DampedHolt, Holt, Ses};
        let c = Comb.forecast(y, 4, 8);
        let s = Ses.forecast(y, 4, 8);
        let h = Holt.forecast(y, 4, 8);
        let d = DampedHolt.forecast(y, 4, 8);
        for i in 0..8 {
            let lo = s[i].min(h[i]).min(d[i]);
            let hi = s[i].max(h[i]).max(d[i]);
            if c[i] < lo - 1e-3 || c[i] > hi + 1e-3 {
                return Err(format!("comb[{i}]={} outside [{lo}, {hi}]", c[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_primer_seasonality_normalized() {
    forall(113, 150, |r| {
        let s = [4usize, 12][r.below(2)];
        let len = 3 * s + r.below(60);
        let y = gen_positive_series(r, len, s);
        (y, s)
    }, |(y, s)| {
        let p = hw::primer(y, *s);
        if p.log_s_init.len() != *s {
            return Err("wrong seasonality length".into());
        }
        let mean: f32 =
            p.log_s_init.iter().map(|v| v.exp()).sum::<f32>() / *s as f32;
        if (mean - 1.0).abs() > 0.06 {
            return Err(format!("primer indices mean {mean} far from 1"));
        }
        Ok(())
    });
}

#[test]
fn prop_dual_filter_degenerates_to_single() {
    // With s2 ≡ 1 and gamma2 = 0 the dual recurrence must equal the
    // single-seasonality filter exactly.
    forall(114, 100, |r| {
        let y = gen_positive_series(r, 48, 4);
        let alpha = r.uniform(0.05, 0.95) as f32;
        let gamma = r.uniform(0.0, 0.6) as f32;
        let s_init: Vec<f32> = (0..4).map(|_| r.uniform(0.6, 1.4) as f32).collect();
        (y, alpha, gamma, s_init)
    }, |(y, alpha, gamma, s_init)| {
        let single = es_filter(y, *alpha, *gamma, s_init);
        let (lv, s1, _) = hw::es_dual_filter(y, *alpha, *gamma, 0.0, s_init,
                                             &[1.0, 1.0]);
        for t in 0..y.len() {
            if (lv[t] - single.levels[t]).abs() > 1e-4 * single.levels[t].abs() {
                return Err(format!("level[{t}] {} != {}", lv[t],
                                   single.levels[t]));
            }
        }
        for t in 0..s1.len() {
            if (s1[t] - single.seas[t]).abs() > 1e-4 {
                return Err(format!("seas[{t}] mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dual_store_rotation_per_component() {
    // gather_batch_rotated must rotate the [S1 | S2] block per component
    // by shift mod its own period.
    forall(115, 60, |r| {
        let s1 = 2 + r.below(6);
        let s2 = s1 + 1 + r.below(8);
        let shift = r.below(40);
        (s1, s2, shift)
    }, |(s1, s2, shift)| {
        let primer = hw::Primer {
            alpha_logit: 0.0,
            gamma_logit: 0.0,
            gamma2_logit: 0.0,
            log_s_init: (0..s1 + s2).map(|k| k as f32).collect(),
        };
        let store = ParamStore::from_primers_dual(&[primer], *s1, *s2).unwrap();
        let g = store.gather_batch_rotated(&[0], *shift).unwrap();
        let got = &g["params.series.log_s_init"].data;
        let (r1, r2) = (shift % s1, shift % s2);
        for k in 0..*s1 {
            let expect = ((k + r1) % s1) as f32;
            if got[k] != expect {
                return Err(format!("s1[{k}] = {} want {expect}", got[k]));
            }
        }
        for k in 0..*s2 {
            let expect = (s1 + (k + r2) % s2) as f32;
            if got[s1 + k] != expect {
                return Err(format!("s2[{k}] = {} want {expect}", got[s1 + k]));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------- pathological series
//
// ROADMAP's adversarial-correctness backstop, part (a): chutoro-style
// pathological inputs — constant, bursty, near-zero and
// subnormal-adjacent series — run through full scalar-vs-lanes
// `train_step` + `predict` equivalence on a real Table-1 shape. The model
// normalizes in log space (x = log(y / (level · seas))), so it is scale
// invariant; these series probe the f32 edges where that invariance could
// silently break in one kernel implementation but not the other.

const PATH_FREQ: &str = "quarterly";
const PATH_B: usize = 5; // ragged: one partial lane group + a masked slot

fn path_len() -> usize {
    NativeBackend::with_threads_mode(1, ComputeMode::Scalar)
        .manifest()
        .config(PATH_FREQ)
        .unwrap()
        .length
}

/// Full train_step/predict input map with the given batch values
/// (mirrors the simd_parity suite's `train_state`, with `y` injected).
fn pathological_state(backend: &NativeBackend, y: &[f32])
                      -> HashMap<String, HostTensor> {
    let cfg = backend.manifest().config(PATH_FREQ).unwrap().clone();
    let b = PATH_B;
    assert_eq!(y.len(), b * cfg.length);
    let w = cfg.seasonality + cfg.seasonality2;
    let rnn = backend.execute_init(PATH_FREQ, 42).unwrap();
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, w], vec![0.0; b * w]).unwrap());
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));
    state.insert("data.y".into(),
                 HostTensor::new(vec![b, cfg.length], y.to_vec()).unwrap());
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    state.insert("data.cat".into(),
                 HostTensor::new(vec![b, 6], cat).unwrap());
    let mut mask = vec![1.0f32; b];
    mask[b - 1] = 0.0; // masked-slot zero-gradient contract rides along
    state.insert("data.mask".into(),
                 HostTensor::new(vec![b], mask).unwrap());
    state.insert("lr".into(), HostTensor::scalar(1e-3));
    state
}

fn run_pathological(backend: &NativeBackend, pname: &str,
                    state: &HashMap<String, HostTensor>)
                    -> Result<Vec<(String, HostTensor)>, String> {
    backend
        .execute_named(pname, &mut |spec| {
            state.get(&spec.name).ok_or_else(
                || anyhow::anyhow!("missing `{}`", spec.name))
        })
        .map_err(|e| format!("{pname}: {e:#}"))
}

/// Scalar (2 threads) vs lanes (3 threads) on one pathological batch:
/// finite losses/forecasts, agreement within the simd_parity tolerances,
/// and non-negative point forecasts (the model is multiplicative).
fn check_scalar_lane_equivalence(label: &str, y: &[f32])
                                 -> Result<(), String> {
    let scalar = NativeBackend::with_threads_mode(2, ComputeMode::Scalar);
    let lane = NativeBackend::with_threads_mode(3, ComputeMode::Lanes);
    let state = pathological_state(&scalar, y);
    let tname = Manifest::program_name(PATH_FREQ, PATH_B, "train_step");
    let s_out = run_pathological(&scalar, &tname, &state)?;
    let l_out = run_pathological(&lane, &tname, &state)?;
    let (ls, ll) = (s_out[0].1.data[0], l_out[0].1.data[0]);
    if !ls.is_finite() || !ll.is_finite() {
        return Err(format!("{label}: non-finite loss ({ls} / {ll})"));
    }
    if (ls - ll).abs() > 5e-4 * ls.abs().max(1e-2) {
        return Err(format!("{label}: scalar loss {ls} != lane loss {ll}"));
    }
    let pname = Manifest::program_name(PATH_FREQ, PATH_B, "predict");
    let s_fc = run_pathological(&scalar, &pname, &state)?;
    let l_fc = run_pathological(&lane, &pname, &state)?;
    for (k, (sv, lv)) in
        s_fc[0].1.data.iter().zip(&l_fc[0].1.data).enumerate()
    {
        if !sv.is_finite() || !lv.is_finite() {
            return Err(format!(
                "{label}: non-finite forecast[{k}] ({sv} / {lv})"));
        }
        if *sv < 0.0 || *lv < 0.0 {
            return Err(format!(
                "{label}: negative forecast[{k}] ({sv} / {lv})"));
        }
        if (sv - lv).abs() > 1e-3 * sv.abs().max(1.0) {
            return Err(format!(
                "{label}: forecast[{k}] scalar {sv} vs lane {lv}"));
        }
    }
    Ok(())
}

#[test]
fn prop_pathological_constant() {
    forall(117, 3, |r| {
        // Dead-flat series at three decades of scale: zero variance must
        // not produce NaN normalized windows or divergent kernels.
        let level = [1e-3f32, 1.0, 1e4][r.below(3)];
        vec![level; PATH_B * path_len()]
    }, |y| check_scalar_lane_equivalence("constant", y));
}

#[test]
fn prop_pathological_bursty() {
    forall(118, 3, |r| {
        // Calm baseline with 10x–1000x spikes at ~10% of positions: the
        // log transform must tame the dynamic range identically in both
        // kernel modes.
        (0..PATH_B * path_len())
            .map(|_| {
                let base = r.uniform(0.5, 2.0) as f32;
                if r.chance(0.1) {
                    base * r.uniform(10.0, 1000.0) as f32
                } else {
                    base
                }
            })
            .collect::<Vec<f32>>()
    }, |y| check_scalar_lane_equivalence("bursty", y));
}

#[test]
fn prop_pathological_near_zero() {
    forall(119, 3, |r| {
        // Positive but ~30 decades below 1: levels and seasonal indices
        // follow the series scale, so intermediate ratios stay O(1) —
        // unless a kernel sneaks in an absolute epsilon.
        (0..PATH_B * path_len())
            .map(|_| (r.uniform(1.0, 9.0) * 1e-30) as f32)
            .collect::<Vec<f32>>()
    }, |y| check_scalar_lane_equivalence("near_zero", y));
}

#[test]
fn prop_pathological_subnormal_adjacent() {
    forall(120, 3, |r| {
        // Just above f32::MIN_POSITIVE (~1.18e-38): the edge where
        // products of level × seasonality flirt with the subnormal range
        // without handing the kernels actual subnormal inputs.
        (0..PATH_B * path_len())
            .map(|_| (r.uniform(2.0, 9.0) * 1e-37) as f32)
            .collect::<Vec<f32>>()
    }, |y| check_scalar_lane_equivalence("subnormal_adjacent", y));
}

#[test]
fn prop_dual_filter_positive() {
    forall(116, 80, |r| {
        let y = gen_positive_series(r, 80, 8);
        let a = r.uniform(0.05, 0.9) as f32;
        let g1 = r.uniform(0.0, 0.5) as f32;
        let g2 = r.uniform(0.0, 0.5) as f32;
        let s1: Vec<f32> = (0..8).map(|_| r.uniform(0.6, 1.4) as f32).collect();
        let s2: Vec<f32> = (0..20).map(|_| r.uniform(0.6, 1.4) as f32).collect();
        (y, a, g1, g2, s1, s2)
    }, |(y, a, g1, g2, s1, s2)| {
        let (lv, e1, e2) = hw::es_dual_filter(y, *a, *g1, *g2, s1, s2);
        if !lv.iter().all(|v| v.is_finite() && *v > 0.0) {
            return Err("nonpositive level".into());
        }
        if !e1.iter().chain(e2.iter()).all(|v| v.is_finite() && *v > 0.0) {
            return Err("nonpositive seasonality".into());
        }
        Ok(())
    });
}
