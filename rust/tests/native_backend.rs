//! Correctness suite for the native backend's compute core:
//!
//! * property tests (in-tree `util::prop` driver, following the
//!   chutoro/trueno-viz exemplar style): the ES recurrence served by the
//!   backend matches the pure [`hw::es_filter`] oracle elementwise within
//!   1e-4 across random series/seasonality configs, and the batched
//!   predict program agrees with the single-series reference forward;
//! * a 5-step training run on a synthetic corpus whose pinball loss must
//!   fall (the train_step end-to-end signal), for both a single-
//!   seasonality config and the §8.2 hourly dual-seasonality program;
//! * directional finite-difference checks of the hand-written backward
//!   pass, for every parameter group, on seasonal, non-seasonal and
//!   dual-seasonality configs — the dual check covers alpha, gamma,
//!   gamma2, both packed `[S1 | S2]` log_s_init blocks and the RNN
//!   weights (the same derivation was validated at f64 precision during
//!   development; this guards the f32 transcription).

use std::collections::HashMap;

use fast_esrnn::hw;
use fast_esrnn::runtime::native::model::{self, RnnView, Shape};
use fast_esrnn::runtime::native::NativeBackend;
use fast_esrnn::runtime::{Backend, HostTensor, Manifest};
use fast_esrnn::util::prop::{forall, gen_positive_series};
use fast_esrnn::util::rng::Rng;

// ---------------------------------------------------------------- helpers

const FREQS: [(&str, usize); 4] =
    [("yearly", 1), ("quarterly", 4), ("monthly", 12), ("daily", 7)];

/// Owned toy parameters for direct model-module calls. `log_s` packs
/// `[S1 | S2]` per series (S2 = 0 for single-seasonality shapes).
struct Params {
    cells: Vec<(Vec<f32>, Vec<f32>)>,
    dense_w: Vec<f32>,
    dense_b: Vec<f32>,
    out_w: Vec<f32>,
    out_b: Vec<f32>,
    alpha: Vec<f32>,
    gamma: Vec<f32>,
    gamma2: Vec<f32>,
    log_s: Vec<f32>,
}

fn toy_params(shape: &Shape, n_series: usize, rng: &mut Rng) -> Params {
    let hid = shape.hidden;
    let mut cells = Vec::new();
    for &din in &shape.layer_din {
        let lim = (6.0 / (din + hid + 4 * hid) as f64).sqrt();
        cells.push((
            (0..(din + hid) * 4 * hid)
                .map(|_| rng.uniform(-lim, lim) as f32)
                .collect(),
            vec![0.0; 4 * hid],
        ));
    }
    let lim_d = (6.0 / (2 * hid) as f64).sqrt();
    let lim_o = (6.0 / (hid + shape.h) as f64).sqrt();
    Params {
        cells,
        dense_w: (0..hid * hid).map(|_| rng.uniform(-lim_d, lim_d) as f32).collect(),
        dense_b: vec![0.0; hid],
        out_w: (0..hid * shape.h).map(|_| rng.uniform(-lim_o, lim_o) as f32).collect(),
        out_b: vec![0.0; shape.h],
        alpha: (0..n_series).map(|_| rng.uniform(-1.5, 0.5) as f32).collect(),
        gamma: (0..n_series).map(|_| rng.uniform(-3.0, -0.5) as f32).collect(),
        gamma2: (0..n_series).map(|_| rng.uniform(-3.0, -0.5) as f32).collect(),
        log_s: (0..n_series * shape.s_total())
            .map(|_| rng.uniform(-0.2, 0.2) as f32)
            .collect(),
    }
}

fn hw_view<'a>(p: &'a Params, shape: &Shape, i: usize) -> model::HwView<'a> {
    let w = shape.s_total();
    model::HwView {
        alpha_logit: p.alpha[i],
        gamma_logit: p.gamma[i],
        gamma2_logit: p.gamma2[i],
        log_s_init: &p.log_s[i * w..(i + 1) * w],
    }
}

fn cell_refs(p: &Params) -> Vec<(&[f32], &[f32])> {
    p.cells.iter().map(|c| (c.0.as_slice(), c.1.as_slice())).collect()
}

fn view<'a>(p: &'a Params, cells: &'a [(&'a [f32], &'a [f32])]) -> RnnView<'a> {
    RnnView {
        cells,
        dense_w: &p.dense_w,
        dense_b: &p.dense_b,
        out_w: &p.out_w,
        out_b: &p.out_b,
    }
}

/// Batch pinball loss of the toy model (mirror of the backend's
/// train-step forward, without the optimizer).
fn batch_loss(shape: &Shape, ys: &[Vec<f32>], cats: &[[f32; 6]],
              smask: &[f32], p: &Params, tau: f32) -> f64 {
    let mask_sum: f32 = smask.iter().sum();
    let denom = (shape.valid_positions as f32 * mask_sum * shape.h as f32)
        .max(1.0);
    let cells = cell_refs(p);
    let rnn = view(p, &cells);
    let mut num = 0.0f64;
    for (i, y) in ys.iter().enumerate() {
        let fwd = model::forward_series(
            shape, y, &cats[i], &rnn, hw_view(p, shape, i), true);
        let (loss_num, _, _) = model::pinball_seeds(shape, &fwd, tau,
                                                    smask[i], denom);
        num += loss_num;
    }
    num / denom as f64
}

/// Analytic gradients of [`batch_loss`] via the hand-written backward.
fn batch_grads(shape: &Shape, ys: &[Vec<f32>], cats: &[[f32; 6]],
               smask: &[f32], p: &Params, tau: f32)
               -> (model::RnnGrads, Vec<model::SeriesGrads>) {
    let mask_sum: f32 = smask.iter().sum();
    let denom = (shape.valid_positions as f32 * mask_sum * shape.h as f32)
        .max(1.0);
    let cells = cell_refs(p);
    let rnn = view(p, &cells);
    let mut rnn_grads = model::RnnGrads::zeros(shape);
    let mut series_grads = Vec::new();
    for (i, y) in ys.iter().enumerate() {
        let fwd = model::forward_series(
            shape, y, &cats[i], &rnn, hw_view(p, shape, i), true);
        let (_, dout, dz) = model::pinball_seeds(shape, &fwd, tau, smask[i],
                                                 denom);
        if smask[i] == 0.0 {
            series_grads.push(model::SeriesGrads::zeros(shape.s_total()));
        } else {
            series_grads.push(model::backward_series(shape, y, &rnn, &fwd,
                                                     &dout, &dz,
                                                     &mut rnn_grads));
        }
    }
    (rnn_grads, series_grads)
}

// --------------------------------------------------------- property tests

#[test]
fn prop_es_program_matches_filter_oracle_within_1e4() {
    let backend = NativeBackend::with_threads(2);
    forall(201, 40, |r| {
        let (freq, s) = FREQS[r.below(FREQS.len())];
        let c = backend.manifest().config(freq).unwrap().length;
        let b = 8usize;
        let mut y = Vec::new();
        let mut alpha = Vec::new();
        let mut gamma = Vec::new();
        let mut log_s = Vec::new();
        for _ in 0..b {
            y.extend(gen_positive_series(r, c, s));
            alpha.push(r.uniform(-2.0, 2.0) as f32);
            gamma.push(r.uniform(-3.0, 0.0) as f32);
            for _ in 0..s {
                log_s.push(r.uniform(-0.3, 0.3) as f32);
            }
        }
        (freq.to_string(), s, c, y, alpha, gamma, log_s)
    }, |(freq, s, c, y, alpha, gamma, log_s)| {
        let (b, s, c) = (8usize, *s, *c);
        let inputs = HashMap::from([
            ("data.y".to_string(),
             HostTensor::new(vec![b, c], y.clone()).unwrap()),
            ("data.alpha_logit".to_string(),
             HostTensor::new(vec![b], alpha.clone()).unwrap()),
            ("data.gamma_logit".to_string(),
             HostTensor::new(vec![b], gamma.clone()).unwrap()),
            ("data.log_s_init".to_string(),
             HostTensor::new(vec![b, s], log_s.clone()).unwrap()),
        ]);
        let outs = backend
            .execute_named(&format!("{freq}_b8_es"), &mut |spec| {
                inputs.get(&spec.name)
                    .ok_or_else(|| anyhow::anyhow!("missing {}", spec.name))
            })
            .map_err(|e| format!("{e:#}"))?;
        for i in 0..b {
            let (a, g, si): (f32, f32, Vec<f32>) = if s > 1 {
                (hw::sigmoid(alpha[i]), hw::sigmoid(gamma[i]),
                 log_s[i * s..(i + 1) * s].iter().map(|v| v.exp()).collect())
            } else {
                (hw::sigmoid(alpha[i]), 0.0, vec![1.0])
            };
            let oracle = hw::es_filter(&y[i * c..(i + 1) * c], a, g, &si);
            for t in 0..c {
                let got = outs[0].1.data[i * c + t];
                let want = oracle.levels[t];
                if (got - want).abs() > 1e-4 * want.abs().max(1.0) {
                    return Err(format!(
                        "{freq} level[{i},{t}] {got} != oracle {want}"));
                }
            }
            for t in 0..c + s {
                let got = outs[1].1.data[i * (c + s) + t];
                let want = oracle.seas[t];
                if (got - want).abs() > 1e-4 * want.abs().max(1.0) {
                    return Err(format!(
                        "{freq} seas[{i},{t}] {got} != oracle {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_predict_program_matches_reference_forward() {
    // The batch-parallel predict program must agree with the per-series
    // reference forward — catches gather/scatter or threading mixups.
    let backend = NativeBackend::new();
    forall(202, 12, |r| {
        let (freq, s) = FREQS[r.below(2) + 1]; // quarterly or monthly
        let cfg = backend.manifest().config(freq).unwrap().clone();
        let b = [1usize, 2, 4, 8][r.below(4)];
        let mut y = Vec::new();
        for _ in 0..b {
            y.extend(gen_positive_series(r, cfg.length, s));
        }
        let seed = r.next_u64();
        (freq.to_string(), b, seed, y)
    }, |(freq, b, seed, y)| {
        let (b, seed) = (*b, *seed);
        let cfg = backend.manifest().config(freq).unwrap().clone();
        let shape = Shape::new(cfg.seasonality, cfg.seasonality2, cfg.horizon,
                               cfg.input_window, cfg.length, cfg.hidden,
                               &cfg.dilations, 6).unwrap();
        let mut rng = Rng::new(seed);
        let p = toy_params(&shape, b, &mut rng);
        let mut cat = vec![0.0f32; b * 6];
        let mut cats = Vec::new();
        for i in 0..b {
            cat[i * 6 + i % 6] = 1.0;
            let mut one = [0.0f32; 6];
            one[i % 6] = 1.0;
            cats.push(one);
        }
        // backend path
        let mut inputs: HashMap<String, HostTensor> = HashMap::new();
        inputs.insert("data.y".into(),
                      HostTensor::new(vec![b, cfg.length], y.clone()).unwrap());
        inputs.insert("data.cat".into(),
                      HostTensor::new(vec![b, 6], cat).unwrap());
        for (i, (w, bias)) in p.cells.iter().enumerate() {
            let din = shape.layer_din[i];
            inputs.insert(format!("params.rnn.cells.{i}.w"),
                          HostTensor::new(vec![din + shape.hidden,
                                               4 * shape.hidden],
                                          w.clone()).unwrap());
            inputs.insert(format!("params.rnn.cells.{i}.b"),
                          HostTensor::new(vec![4 * shape.hidden],
                                          bias.clone()).unwrap());
        }
        inputs.insert("params.rnn.dense_w".into(),
                      HostTensor::new(vec![shape.hidden, shape.hidden],
                                      p.dense_w.clone()).unwrap());
        inputs.insert("params.rnn.dense_b".into(),
                      HostTensor::new(vec![shape.hidden],
                                      p.dense_b.clone()).unwrap());
        inputs.insert("params.rnn.out_w".into(),
                      HostTensor::new(vec![shape.hidden, shape.h],
                                      p.out_w.clone()).unwrap());
        inputs.insert("params.rnn.out_b".into(),
                      HostTensor::new(vec![shape.h], p.out_b.clone()).unwrap());
        inputs.insert("params.series.alpha_logit".into(),
                      HostTensor::new(vec![b], p.alpha.clone()).unwrap());
        inputs.insert("params.series.gamma_logit".into(),
                      HostTensor::new(vec![b], p.gamma.clone()).unwrap());
        inputs.insert("params.series.log_s_init".into(),
                      HostTensor::new(vec![b, shape.s],
                                      p.log_s.clone()).unwrap());
        let name = Manifest::program_name(freq, b, "predict");
        let outs = backend
            .execute_named(&name, &mut |spec| {
                inputs.get(&spec.name)
                    .ok_or_else(|| anyhow::anyhow!("missing {}", spec.name))
            })
            .map_err(|e| format!("{e:#}"))?;
        let fc = &outs[0].1;
        // reference path, one series at a time
        let cells = cell_refs(&p);
        let rnn = view(&p, &cells);
        for i in 0..b {
            let fwd = model::forward_series(
                &shape, &y[i * cfg.length..(i + 1) * cfg.length], &cats[i],
                &rnn, hw_view(&p, &shape, i), false);
            let want = model::forecast_from(&shape, &fwd);
            for k in 0..shape.h {
                let got = fc.data[i * shape.h + k];
                // 1e-4: the default backend runs the lane kernels, whose
                // fast transcendentals (≤3e-7/op) drift up to ~1e-5
                // relative from this libm scalar reference over P LSTM
                // steps; gather/threading mixups are orders above this.
                if (got - want[k]).abs() > 1e-4 * want[k].abs().max(1.0) {
                    return Err(format!(
                        "{freq} b={b} forecast[{i},{k}] {got} != {}", want[k]));
                }
            }
            if !want.iter().all(|v| v.is_finite() && *v > 0.0) {
                return Err("non-positive forecast".into());
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- training-dynamics test

#[test]
fn train_step_reduces_pinball_loss_over_5_steps() {
    let backend = NativeBackend::new();
    let freq = "quarterly";
    let b = 8usize;
    let cfg = backend.manifest().config(freq).unwrap().clone();
    let mut rng = Rng::new(11);
    let mut y = Vec::new();
    for _ in 0..b {
        y.extend(gen_positive_series(&mut rng, cfg.length, cfg.seasonality));
    }

    let rnn = backend.execute_init(freq, 42).unwrap();
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, cfg.seasonality],
                                 vec![0.0; b * cfg.seasonality]).unwrap());
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));

    let yt = HostTensor::new(vec![b, cfg.length], y).unwrap();
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    let cat = HostTensor::new(vec![b, 6], cat).unwrap();
    let mask = HostTensor::new(vec![b], vec![1.0; b]).unwrap();
    let lr = HostTensor::scalar(1e-3);
    let name = Manifest::program_name(freq, b, "train_step");

    let mut losses = Vec::new();
    for _ in 0..5 {
        let outs = backend
            .execute_named(&name, &mut |spec| {
                Ok(match spec.name.as_str() {
                    "data.y" => &yt,
                    "data.cat" => &cat,
                    "data.mask" => &mask,
                    "lr" => &lr,
                    other => state.get(other).unwrap_or_else(
                        || panic!("missing `{other}`")),
                })
            })
            .unwrap();
        for (n, t) in outs {
            if n == "loss" {
                losses.push(t.data[0]);
            } else {
                state.insert(n, t);
            }
        }
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses[4] < losses[0],
            "pinball loss should fall over 5 steps: {losses:?}");
}

#[test]
fn hourly_es_program_matches_dual_filter_oracle() {
    // §8.2: the hourly es debug program must agree elementwise with the
    // pure-Rust coupled dual filter, emitting both seasonal tracks.
    let backend = NativeBackend::with_threads(2);
    let cfg = backend.manifest().config("hourly").unwrap().clone();
    let (b, c) = (8usize, cfg.length);
    let (s1, s2) = (cfg.seasonality, cfg.seasonality2);
    let w = s1 + s2;
    let mut rng = Rng::new(77);
    let mut y = Vec::new();
    let mut alpha = Vec::new();
    let mut gamma = Vec::new();
    let mut gamma2 = Vec::new();
    let mut log_s = Vec::new();
    for _ in 0..b {
        y.extend(gen_positive_series(&mut rng, c, s1));
        alpha.push(rng.uniform(-2.0, 2.0) as f32);
        gamma.push(rng.uniform(-3.0, 0.0) as f32);
        gamma2.push(rng.uniform(-3.0, 0.0) as f32);
        for _ in 0..w {
            log_s.push(rng.uniform(-0.3, 0.3) as f32);
        }
    }
    let inputs = HashMap::from([
        ("data.y".to_string(),
         HostTensor::new(vec![b, c], y.clone()).unwrap()),
        ("data.alpha_logit".to_string(),
         HostTensor::new(vec![b], alpha.clone()).unwrap()),
        ("data.gamma_logit".to_string(),
         HostTensor::new(vec![b], gamma.clone()).unwrap()),
        ("data.gamma2_logit".to_string(),
         HostTensor::new(vec![b], gamma2.clone()).unwrap()),
        ("data.log_s_init".to_string(),
         HostTensor::new(vec![b, w], log_s.clone()).unwrap()),
    ]);
    let outs = backend
        .execute_named("hourly_b8_es", &mut |spec| {
            inputs.get(&spec.name)
                .ok_or_else(|| anyhow::anyhow!("missing {}", spec.name))
        })
        .unwrap();
    assert_eq!(outs[0].0, "levels");
    assert_eq!(outs[1].0, "seas");
    assert_eq!(outs[2].0, "seas2");
    for i in 0..b {
        let a = hw::sigmoid(alpha[i]);
        let g1 = hw::sigmoid(gamma[i]);
        let g2 = hw::sigmoid(gamma2[i]);
        let row = &log_s[i * w..(i + 1) * w];
        let s1_init: Vec<f32> = row[..s1].iter().map(|v| v.exp()).collect();
        let s2_init: Vec<f32> = row[s1..].iter().map(|v| v.exp()).collect();
        let (lv, e1, e2) = hw::es_dual_filter(
            &y[i * c..(i + 1) * c], a, g1, g2, &s1_init, &s2_init);
        for t in 0..c {
            let got = outs[0].1.data[i * c + t];
            assert!((got - lv[t]).abs() <= 1e-4 * lv[t].abs().max(1.0),
                    "level[{i},{t}] {got} != {}", lv[t]);
        }
        for t in 0..c + s1 {
            let got = outs[1].1.data[i * (c + s1) + t];
            assert!((got - e1[t]).abs() <= 1e-4 * e1[t].abs().max(1.0),
                    "seas[{i},{t}] {got} != {}", e1[t]);
        }
        for t in 0..c + s2 {
            let got = outs[2].1.data[i * (c + s2) + t];
            assert!((got - e2[t]).abs() <= 1e-4 * e2[t].abs().max(1.0),
                    "seas2[{i},{t}] {got} != {}", e2[t]);
        }
    }
}

#[test]
fn hourly_train_step_reduces_pinball_loss_over_5_steps() {
    // §8.2 end-to-end training signal on the real hourly dual program:
    // 24h×168h seasonality, gamma2 leaf, packed [24 | 168] log_s_init.
    let backend = NativeBackend::new();
    let freq = "hourly";
    let b = 4usize;
    let cfg = backend.manifest().config(freq).unwrap().clone();
    let w = cfg.seasonality + cfg.seasonality2;
    let mut rng = Rng::new(13);
    let mut y = Vec::new();
    for _ in 0..b {
        // Daily cycle from the generator plus a planted weekly-style
        // modulation so both seasonal tracks carry signal.
        let base = gen_positive_series(&mut rng, cfg.length, cfg.seasonality);
        let amp2 = rng.uniform(0.05, 0.2);
        for (t, v) in base.iter().enumerate() {
            let wv = std::f64::consts::TAU * (t % cfg.seasonality2) as f64
                / cfg.seasonality2 as f64;
            y.push((*v as f64 * (1.0 + amp2 * wv.sin())) as f32);
        }
    }

    let rnn = backend.execute_init(freq, 42).unwrap();
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    state.insert("params.series.gamma2_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, w], vec![0.0; b * w]).unwrap());
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));

    let yt = HostTensor::new(vec![b, cfg.length], y).unwrap();
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + 5] = 1.0; // M4 hourly series are all "Other"
    }
    let cat = HostTensor::new(vec![b, 6], cat).unwrap();
    let mask = HostTensor::new(vec![b], vec![1.0; b]).unwrap();
    let lr = HostTensor::scalar(1e-3);
    let name = Manifest::program_name(freq, b, "train_step");

    let mut losses = Vec::new();
    for _ in 0..5 {
        let outs = backend
            .execute_named(&name, &mut |spec| {
                Ok(match spec.name.as_str() {
                    "data.y" => &yt,
                    "data.cat" => &cat,
                    "data.mask" => &mask,
                    "lr" => &lr,
                    other => state.get(other).unwrap_or_else(
                        || panic!("missing `{other}`")),
                })
            })
            .unwrap();
        for (n, t) in outs {
            if n == "loss" {
                losses.push(t.data[0]);
            } else {
                state.insert(n, t);
            }
        }
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses[4] < losses[0],
            "hourly pinball loss should fall over 5 steps: {losses:?}");
    // gamma2 moved: the dual smoothing coefficient received gradient.
    let g2 = &state["params.series.gamma2_logit"].data;
    assert!(g2.iter().any(|v| (*v - -1.0).abs() > 1e-7),
            "gamma2_logit never updated: {g2:?}");
}

#[test]
fn thread_count_does_not_change_train_step_numerics() {
    // Same inputs through 1-thread and 4-thread backends: losses must
    // agree to float tolerance (association order differs slightly).
    let mut losses = Vec::new();
    for threads in [1usize, 4] {
        let backend = NativeBackend::with_threads(threads);
        let freq = "yearly";
        let b = 8usize;
        let cfg = backend.manifest().config(freq).unwrap().clone();
        let rnn = backend.execute_init(freq, 7).unwrap();
        let mut state: HashMap<String, HostTensor> =
            rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
        state.insert("params.series.alpha_logit".into(),
                     HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
        state.insert("params.series.gamma_logit".into(),
                     HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
        state.insert("params.series.log_s_init".into(),
                     HostTensor::new(vec![b, cfg.seasonality],
                                     vec![0.0; b * cfg.seasonality]).unwrap());
        let keys: Vec<String> = state.keys().cloned().collect();
        for k in &keys {
            let z = HostTensor::zeros(state[k].shape.clone());
            state.insert(k.replace("params.", "opt.m."), z.clone());
            state.insert(k.replace("params.", "opt.v."), z);
        }
        state.insert("opt.step".into(), HostTensor::scalar(0.0));
        let mut rng = Rng::new(5);
        let mut y = Vec::new();
        for _ in 0..b {
            y.extend(gen_positive_series(&mut rng, cfg.length, 1));
        }
        let yt = HostTensor::new(vec![b, cfg.length], y).unwrap();
        let cat = HostTensor::new(vec![b, 6], {
            let mut c = vec![0.0f32; b * 6];
            for i in 0..b {
                c[i * 6] = 1.0;
            }
            c
        }).unwrap();
        let mask = HostTensor::new(vec![b], vec![1.0; b]).unwrap();
        let lr = HostTensor::scalar(1e-3);
        let name = Manifest::program_name(freq, b, "train_step");
        let outs = backend
            .execute_named(&name, &mut |spec| {
                Ok(match spec.name.as_str() {
                    "data.y" => &yt,
                    "data.cat" => &cat,
                    "data.mask" => &mask,
                    "lr" => &lr,
                    other => state.get(other).unwrap_or_else(
                        || panic!("missing `{other}`")),
                })
            })
            .unwrap();
        losses.push(outs[0].1.data[0]);
    }
    assert!((losses[0] - losses[1]).abs() <= 1e-5 * losses[0].abs().max(1.0),
            "thread count changed numerics: {losses:?}");
}

// -------------------------------------------- finite-difference gradients

/// Directional derivative check: analytic g·u vs central difference along
/// a random ±1 direction `u` over one parameter group. `mask` zeroes
/// direction entries outside a sub-block (used to exercise the two packed
/// `[S1 | S2]` seasonality blocks independently); `label` names the check
/// in failure messages.
#[allow(clippy::too_many_arguments)]
fn check_direction_masked(shape: &Shape, ys: &[Vec<f32>], cats: &[[f32; 6]],
                          smask: &[f32], p: &mut Params, tau: f32,
                          group: &str, label: &str, analytic: &[f32],
                          mask: &dyn Fn(usize) -> bool, rng: &mut Rng) {
    let u: Vec<f32> = (0..analytic.len())
        .map(|j| {
            if !mask(j) {
                0.0
            } else if rng.chance(0.5) {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    let dot: f64 = analytic
        .iter()
        .zip(&u)
        .map(|(g, d)| (*g as f64) * (*d as f64))
        .sum();
    let eps = 1e-2f32;
    let apply = |p: &mut Params, sign: f32| {
        let target: &mut [f32] = match group {
            "cells.0.w" => &mut p.cells[0].0,
            "cells.1.w" => &mut p.cells[1].0,
            "cells.2.w" => &mut p.cells[2].0,
            "cells.3.w" => &mut p.cells[3].0,
            "cells.0.b" => &mut p.cells[0].1,
            "cells.3.b" => &mut p.cells[3].1,
            "dense_w" => &mut p.dense_w,
            "dense_b" => &mut p.dense_b,
            "out_w" => &mut p.out_w,
            "out_b" => &mut p.out_b,
            "alpha" => &mut p.alpha,
            "gamma" => &mut p.gamma,
            "gamma2" => &mut p.gamma2,
            "log_s" => &mut p.log_s,
            other => panic!("unknown group {other}"),
        };
        for (t, d) in target.iter_mut().zip(&u) {
            *t += sign * eps * d;
        }
    };
    apply(p, 1.0);
    let lp = batch_loss(shape, ys, cats, smask, p, tau);
    apply(p, -2.0);
    let lm = batch_loss(shape, ys, cats, smask, p, tau);
    apply(p, 1.0); // restore
    let fd = (lp - lm) / (2.0 * eps as f64);
    let tol = 0.05 * dot.abs().max(fd.abs()) + 5e-4;
    assert!((dot - fd).abs() <= tol,
            "group {label}: analytic {dot:.6e} vs fd {fd:.6e} (tol {tol:.2e})");
}

#[allow(clippy::too_many_arguments)]
fn check_direction(shape: &Shape, ys: &[Vec<f32>], cats: &[[f32; 6]],
                   smask: &[f32], p: &mut Params, tau: f32, group: &str,
                   analytic: &[f32], rng: &mut Rng) {
    check_direction_masked(shape, ys, cats, smask, p, tau, group, group,
                           analytic, &|_| true, rng);
}

fn run_gradient_check(seasonal: bool, seed: u64) {
    let shape = if seasonal {
        Shape::new(4, 0, 4, 5, 20, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap()
    } else {
        Shape::new(1, 0, 3, 4, 16, 5, &[vec![1, 2], vec![2, 3]], 6).unwrap()
    };
    let mut rng = Rng::new(seed);
    let b = 3usize;
    let mut ys = Vec::new();
    let mut cats = Vec::new();
    for i in 0..b {
        ys.push(gen_positive_series(&mut rng, shape.c, shape.s));
        let mut one = [0.0f32; 6];
        one[i % 6] = 1.0;
        cats.push(one);
    }
    let smask = [1.0f32, 1.0, 0.0]; // include a padded slot
    let mut p = toy_params(&shape, b, &mut rng);
    let tau = 0.48;

    let (rnn_g, series_g) = batch_grads(&shape, &ys, &cats, &smask, &p, tau);

    // Padded slot: exactly zero gradients.
    assert_eq!(series_g[2].alpha_logit, 0.0);
    assert!(series_g[2].log_s_init.iter().all(|v| *v == 0.0));
    if !seasonal {
        // Non-seasonal: no gradient reaches gamma / seasonality.
        for sg in &series_g {
            assert_eq!(sg.gamma_logit, 0.0);
            assert!(sg.log_s_init.iter().all(|v| *v == 0.0));
        }
    }

    let alpha_g: Vec<f32> = series_g.iter().map(|s| s.alpha_logit).collect();
    let gamma_g: Vec<f32> = series_g.iter().map(|s| s.gamma_logit).collect();
    let log_s_g: Vec<f32> =
        series_g.iter().flat_map(|s| s.log_s_init.clone()).collect();

    let mut groups: Vec<(&str, Vec<f32>)> = vec![
        ("cells.0.w", rnn_g.cells[0].0.clone()),
        ("cells.1.w", rnn_g.cells[1].0.clone()),
        ("cells.2.w", rnn_g.cells[2].0.clone()),
        ("cells.3.w", rnn_g.cells[3].0.clone()),
        ("cells.0.b", rnn_g.cells[0].1.clone()),
        ("cells.3.b", rnn_g.cells[3].1.clone()),
        ("dense_w", rnn_g.dense_w.clone()),
        ("dense_b", rnn_g.dense_b.clone()),
        ("out_w", rnn_g.out_w.clone()),
        ("out_b", rnn_g.out_b.clone()),
        ("alpha", alpha_g),
    ];
    if seasonal {
        groups.push(("gamma", gamma_g));
        groups.push(("log_s", log_s_g));
    }
    for (name, analytic) in &groups {
        for _ in 0..2 {
            check_direction(&shape, &ys, &cats, &smask, &mut p, tau, name,
                            analytic, &mut rng);
        }
    }
}

#[test]
fn gradients_match_finite_differences_seasonal() {
    run_gradient_check(true, 1001);
}

#[test]
fn gradients_match_finite_differences_nonseasonal() {
    run_gradient_check(false, 1002);
}

/// §8.2 dual path: every parameter group — including gamma2 and the two
/// packed `[S1 | S2]` seasonality blocks independently — must match
/// central finite differences through the coupled ES recurrence.
#[test]
fn gradients_match_finite_differences_dual() {
    let shape =
        Shape::new(3, 6, 4, 5, 24, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap();
    assert!(shape.dual());
    let (s1, w) = (shape.s, shape.s_total());
    let mut rng = Rng::new(1003);
    let b = 3usize;
    let mut ys = Vec::new();
    let mut cats = Vec::new();
    for i in 0..b {
        // Plant both cycles so the second seasonal track carries signal.
        let base = gen_positive_series(&mut rng, shape.c, shape.s);
        let amp2 = rng.uniform(0.05, 0.2);
        let y: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(t, v)| {
                let wv = std::f64::consts::TAU * (t % shape.s2) as f64
                    / shape.s2 as f64;
                (*v as f64 * (1.0 + amp2 * wv.sin())) as f32
            })
            .collect();
        ys.push(y);
        let mut one = [0.0f32; 6];
        one[i % 6] = 1.0;
        cats.push(one);
    }
    let smask = [1.0f32, 1.0, 0.0]; // include a padded slot
    let mut p = toy_params(&shape, b, &mut rng);
    let tau = 0.48;

    let (rnn_g, series_g) = batch_grads(&shape, &ys, &cats, &smask, &p, tau);

    // Padded slot: exactly zero gradients, full packed width.
    assert_eq!(series_g[2].alpha_logit, 0.0);
    assert_eq!(series_g[2].gamma2_logit, 0.0);
    assert_eq!(series_g[2].log_s_init.len(), w);
    assert!(series_g[2].log_s_init.iter().all(|v| *v == 0.0));

    let alpha_g: Vec<f32> = series_g.iter().map(|s| s.alpha_logit).collect();
    let gamma_g: Vec<f32> = series_g.iter().map(|s| s.gamma_logit).collect();
    let gamma2_g: Vec<f32> =
        series_g.iter().map(|s| s.gamma2_logit).collect();
    let log_s_g: Vec<f32> =
        series_g.iter().flat_map(|s| s.log_s_init.clone()).collect();

    let groups: Vec<(&str, Vec<f32>)> = vec![
        ("cells.0.w", rnn_g.cells[0].0.clone()),
        ("cells.3.w", rnn_g.cells[3].0.clone()),
        ("dense_w", rnn_g.dense_w.clone()),
        ("out_w", rnn_g.out_w.clone()),
        ("out_b", rnn_g.out_b.clone()),
        ("alpha", alpha_g),
        ("gamma", gamma_g),
        ("gamma2", gamma2_g),
        ("log_s", log_s_g.clone()),
    ];
    for (name, analytic) in &groups {
        for _ in 0..2 {
            check_direction(&shape, &ys, &cats, &smask, &mut p, tau, name,
                            analytic, &mut rng);
        }
    }
    // The two packed seasonality blocks, each in isolation.
    for _ in 0..2 {
        check_direction_masked(&shape, &ys, &cats, &smask, &mut p, tau,
                               "log_s", "log_s[S1 block]", &log_s_g,
                               &|j| j % w < s1, &mut rng);
        check_direction_masked(&shape, &ys, &cats, &smask, &mut p, tau,
                               "log_s", "log_s[S2 block]", &log_s_g,
                               &|j| j % w >= s1, &mut rng);
    }
}
