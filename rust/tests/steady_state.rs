//! Steady-state guarantees of the native backend's persistent compute
//! pool and arena hot path (PR 6):
//!
//! * **Reuse safety** — the pooled/arena path must be *bit-identical* to
//!   a fresh-allocation path across 50 train steps for every Table-1
//!   frequency, including the §8.2 hourly dual-seasonality model, a
//!   ragged mask (padded slots mid-batch and in the tail) and a
//!   multi-group monthly batch (b=32 → 4 lane groups). Three paths are
//!   compared: (A) one warm backend stepped via `execute_named` with
//!   output write-back, (B) one warm backend stepped via
//!   `train_step_inplace`, and (C) a **fresh backend per step** — brand
//!   new arenas every call. Any stale-buffer leak in the arenas shows up
//!   as an A/C divergence; any in-place-update bug as an A/B divergence.
//! * **Zero allocation / zero spawn** — with the counting allocator
//!   installed, a post-warmup lanes-mode train step performs no heap
//!   allocation and no thread spawn (the ISSUE 6 acceptance gate).
//! * **Panic containment** — a worker panic inside a pooled task
//!   propagates to the caller without deadlocking subsequent rounds.
//!
//! All tests serialize on a process-wide gate: the allocation counter is
//! global, so concurrently running tests would pollute the measured
//! windows.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use fast_esrnn::runtime::native::pool::ComputePool;
use fast_esrnn::runtime::native::{ComputeMode, NativeBackend};
use fast_esrnn::runtime::{Backend, HostTensor, Manifest};
use fast_esrnn::util::allocmeter::{self, CountingAlloc};
use fast_esrnn::util::prop::gen_positive_series_dual;
use fast_esrnn::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Serializes the tests in this binary (poison-tolerant: a failing test
/// must not cascade into every later one).
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

const THREADS: usize = 3;

/// Synthetic batch + initial model/optimizer state for `freq`,
/// deterministic in `seed`. `mask` must have length `b`.
struct Scenario {
    name: String,
    data: HashMap<String, HostTensor>,
    state: HashMap<String, HostTensor>,
}

fn scenario(backend: &NativeBackend, freq: &str, b: usize, mask: Vec<f32>,
            seed: u64) -> Scenario {
    let cfg = backend.manifest().config(freq).unwrap().clone();
    let w = cfg.seasonality + cfg.seasonality2;
    let dual = cfg.seasonality2 > 0;
    let mut rng = Rng::new(seed);
    let mut y = Vec::new();
    for _ in 0..b {
        // Plants both cycles for the hourly dual model; degenerates to
        // the single-season generator when seasonality2 == 0.
        y.extend(gen_positive_series_dual(&mut rng, cfg.length,
                                          cfg.seasonality,
                                          cfg.seasonality2));
    }

    let rnn = backend.execute_init(freq, seed ^ 0xA5A5).unwrap();
    let mut state: HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    state.insert("params.series.alpha_logit".into(),
                 HostTensor::new(vec![b], vec![-0.5; b]).unwrap());
    state.insert("params.series.gamma_logit".into(),
                 HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    if dual {
        state.insert("params.series.gamma2_logit".into(),
                     HostTensor::new(vec![b], vec![-1.0; b]).unwrap());
    }
    state.insert("params.series.log_s_init".into(),
                 HostTensor::new(vec![b, w], vec![0.0; b * w]).unwrap());
    let keys: Vec<String> = state.keys().cloned().collect();
    for k in &keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));

    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    let data = HashMap::from([
        ("data.y".to_string(),
         HostTensor::new(vec![b, cfg.length], y).unwrap()),
        ("data.cat".to_string(), HostTensor::new(vec![b, 6], cat).unwrap()),
        ("data.mask".to_string(), HostTensor::new(vec![b], mask).unwrap()),
        ("lr".to_string(), HostTensor::scalar(1e-3)),
    ]);
    Scenario { name: Manifest::program_name(freq, b, "train_step"),
               data, state }
}

/// One `execute_named` step with output write-back; returns the loss.
fn step_named(backend: &NativeBackend, sc: &mut Scenario) -> f32 {
    let outs = backend
        .execute_named(&sc.name, &mut |spec| {
            sc.data
                .get(&spec.name)
                .or_else(|| sc.state.get(&spec.name))
                .ok_or_else(|| anyhow::anyhow!("missing `{}`", spec.name))
        })
        .unwrap();
    let mut loss = f32::NAN;
    for (n, t) in outs {
        if n == "loss" {
            loss = t.data[0];
        } else {
            sc.state.insert(n, t);
        }
    }
    loss
}

fn assert_states_bitwise_equal(a: &HashMap<String, HostTensor>,
                               b: &HashMap<String, HostTensor>,
                               la: &str, lb: &str) {
    assert_eq!(a.len(), b.len(), "{la} vs {lb}: different state keys");
    for (k, ta) in a {
        let tb = &b[k];
        assert_eq!(ta.shape, tb.shape, "{la} vs {lb}: `{k}` shape");
        for (i, (va, vb)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(),
                       "{la} vs {lb}: `{k}`[{i}] {va} != {vb}");
        }
    }
}

/// The reuse-safety triangle: warm execute_named (A) vs warm
/// train_step_inplace (B) vs fresh-backend-per-step (C), 50 steps,
/// bitwise loss and state equality.
fn run_parity(freq: &str, b: usize, mask: Vec<f32>, mode: ComputeMode,
              steps: usize) {
    let warm_a = NativeBackend::with_threads_mode(THREADS, mode);
    let warm_b = NativeBackend::with_threads_mode(THREADS, mode);
    let seed = 4242;
    let mut sc_a = scenario(&warm_a, freq, b, mask.clone(), seed);
    let mut sc_b = scenario(&warm_a, freq, b, mask.clone(), seed);
    let mut sc_c = scenario(&warm_a, freq, b, mask, seed);
    assert_states_bitwise_equal(&sc_a.state, &sc_b.state, "init A", "init B");

    for step in 0..steps {
        let la = step_named(&warm_a, &mut sc_a);
        let lb = warm_b
            .train_step_inplace(&sc_b.name, &sc_b.data, &mut sc_b.state)
            .unwrap();
        // Path C: brand-new backend (fresh arenas, fresh pool) every
        // step — the no-reuse reference.
        let fresh = NativeBackend::with_threads_mode(THREADS, mode);
        let lc = step_named(&fresh, &mut sc_c);
        assert!(la.is_finite(), "{freq} step {step}: non-finite loss");
        assert_eq!(la.to_bits(), lb.to_bits(),
                   "{freq} step {step}: warm-named {la} != inplace {lb}");
        assert_eq!(la.to_bits(), lc.to_bits(),
                   "{freq} step {step}: warm {la} != fresh-backend {lc}");
    }
    assert_states_bitwise_equal(&sc_a.state, &sc_b.state,
                                "warm execute_named", "train_step_inplace");
    assert_states_bitwise_equal(&sc_a.state, &sc_c.state,
                                "warm execute_named", "fresh-per-step");
}

/// Ragged mask for batch `b`: slot 1 padded mid-batch plus a padded
/// tail of `tail` slots.
fn ragged_mask(b: usize, tail: usize) -> Vec<f32> {
    let mut m = vec![1.0f32; b];
    if b > 1 {
        m[1] = 0.0;
    }
    for slot in m.iter_mut().rev().take(tail) {
        *slot = 0.0;
    }
    m
}

#[test]
fn pooled_path_is_bit_identical_yearly() {
    let _g = gate();
    run_parity("yearly", 4, ragged_mask(4, 1), ComputeMode::Lanes, 50);
}

#[test]
fn pooled_path_is_bit_identical_quarterly() {
    let _g = gate();
    run_parity("quarterly", 4, ragged_mask(4, 1), ComputeMode::Lanes, 50);
}

#[test]
fn pooled_path_is_bit_identical_monthly_multigroup() {
    let _g = gate();
    // b=32 → four lane groups across three pool chunks, with padded
    // slots both mid-group and in the ragged tail.
    run_parity("monthly", 32, ragged_mask(32, 5), ComputeMode::Lanes, 50);
}

#[test]
fn pooled_path_is_bit_identical_daily() {
    let _g = gate();
    run_parity("daily", 4, ragged_mask(4, 1), ComputeMode::Lanes, 50);
}

#[test]
fn pooled_path_is_bit_identical_hourly_dual() {
    let _g = gate();
    run_parity("hourly", 4, ragged_mask(4, 1), ComputeMode::Lanes, 50);
}

#[test]
fn pooled_path_is_bit_identical_scalar_oracle() {
    let _g = gate();
    // The scalar path shares the arena machinery (ScalarScratch) — guard
    // its buffer reuse the same way.
    run_parity("yearly", 4, ragged_mask(4, 1), ComputeMode::Scalar, 50);
}

#[test]
fn steady_state_train_step_allocates_and_spawns_nothing() {
    let _g = gate();
    // b=32 → 4 lane groups over 4 threads: the persistent pool is
    // actually exercised (n_chunks > 1), not the sequential inline path.
    let backend = NativeBackend::with_threads_mode(4, ComputeMode::Lanes);
    let mut sc = scenario(&backend, "yearly", 32, vec![1.0; 32], 7);

    // Warmup: grow every arena to its high-water shape. STEADY_WARMUP
    // in the backend is 3; one extra step for margin.
    for _ in 0..4 {
        backend
            .train_step_inplace(&sc.name, &sc.data, &mut sc.state)
            .unwrap();
    }

    let s0 = backend.stats();
    assert_eq!(s0.spawns, 3,
               "persistent pool should have spawned exactly threads-1 \
                workers during warmup");

    // Measure rounds of 2 steps each. Under the gate the only allocating
    // threads are ours, so every round must be exactly zero — the min
    // guards against incidental runtime noise (e.g. lazy stdlib init).
    let mut min_allocs = u64::MAX;
    for _ in 0..5 {
        let a0 = allocmeter::allocations();
        for _ in 0..2 {
            let loss = backend
                .train_step_inplace(&sc.name, &sc.data, &mut sc.state)
                .unwrap();
            assert!(loss.is_finite());
        }
        min_allocs = min_allocs.min(allocmeter::allocations() - a0);
    }
    assert_eq!(min_allocs, 0,
               "steady-state train_step_inplace must not allocate");

    let s1 = backend.stats();
    assert_eq!(s1.spawns, s0.spawns,
               "steady-state steps must not spawn threads");
    assert_eq!(s1.steady_allocs, 0,
               "backend charged steady-state allocations: {}",
               s1.steady_allocs);
    assert!(s1.scratch_bytes > 0,
            "arenas should report pinned scratch bytes");
}

#[test]
fn compute_pool_survives_worker_panic() {
    let _g = gate();
    let pool = ComputePool::new(4);

    // A panicking chunk must propagate to the caller as a panic...
    let result = catch_unwind(AssertUnwindSafe(|| {
        pool.run(4, &|i, _pid| {
            if i == 2 {
                panic!("injected chunk failure");
            }
        });
    }));
    assert!(result.is_err(), "worker panic should reach the caller");

    // ...and the pool must keep serving rounds afterwards (no dead
    // worker, no stuck epoch, no poisoned handoff).
    let sum = AtomicUsize::new(0);
    pool.run(8, &|i, _pid| {
        sum.fetch_add(i + 1, Ordering::Relaxed);
    });
    assert_eq!(sum.load(Ordering::Relaxed), 36,
               "pool did not run every chunk after a panic round");
}
