//! Overload / soak suite for the sharded, backpressured HTTP serving
//! stack — the contract under test:
//!
//! * under saturating concurrent load with a tiny queue limit, **every
//!   request completes with `200` or `429`** — zero hangs, zero drops,
//!   and accepted + shed exactly accounts for every submit;
//! * HTTP/1.1 keep-alive conformance: many requests per connection,
//!   pipelined sequential requests, `Connection: close` honored;
//! * request-size limits enforced *before* buffering: oversized bodies
//!   → `413`, oversized headers → `431` — a hostile `Content-Length`
//!   cannot balloon memory;
//! * the consistent-hash shard router splits real HTTP traffic by
//!   series id, aggregates stats as the exact sum of shard stats, and
//!   drains a removed shard without dropping anything.
//!
//! All tests run on the native backend with freshly-initialized weights
//! (`ModelState::init`) — overload behavior does not depend on trained
//! weights, and skipping training keeps the suite fast enough to run on
//! every CI push.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use fast_esrnn::config::Frequency;
use fast_esrnn::coordinator::ModelState;
use fast_esrnn::forecast::{http, HttpClient, HttpOptions, HttpServer,
                           ServiceOptions, ServingStack, ShardedStack};
use fast_esrnn::runtime::NativeBackend;
use fast_esrnn::util::json::Json;

const FREQ: Frequency = Frequency::Quarterly;
const HORIZON: usize = 8;

fn fresh_state() -> ModelState {
    let backend = NativeBackend::new();
    ModelState::init(&backend, FREQ.name(), 42).unwrap()
}

/// A positive synthetic history long enough for the quarterly C=72 cut.
fn probe_values() -> Vec<f32> {
    (0..80)
        .map(|i| 100.0 + i as f32 * 0.5 + (i % 4) as f32 * 3.0)
        .collect()
}

fn forecast_body(id: &str) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("values", Json::arr_f32(&probe_values())),
    ])
    .to_string()
}

/// Start a single-shard server with the given pool + HTTP options;
/// returns (server, the stack for in-process stats).
fn start_server(opts: ServiceOptions, http_opts: HttpOptions)
                -> (HttpServer, Arc<ServingStack>) {
    let mut stack = ServingStack::new();
    stack.start_pool_native(FREQ, fresh_state(), opts).unwrap();
    let stack = Arc::new(stack);
    let sharded =
        Arc::new(ShardedStack::single(Arc::clone(&stack)).unwrap());
    let server =
        HttpServer::start_with(sharded, "127.0.0.1:0", http_opts).unwrap();
    (server, stack)
}

#[test]
fn overload_sheds_load_with_429_and_never_hangs_or_drops() {
    // A deliberately starved pool: one worker, queue depth 1 — any
    // concurrency at all must overflow into 429s, never into an
    // unbounded queue or a hang.
    let (server, stack) = start_server(
        ServiceOptions {
            workers: 1,
            queue_limit: 1,
            batch_window: Duration::from_millis(1),
            max_batch: 1,
            ..Default::default()
        },
        HttpOptions {
            conn_workers: 16,
            accept_backlog: 64,
            ..Default::default()
        },
    );
    let addr = server.addr().to_string();

    const CLIENTS: usize = 12;
    const PER_CLIENT: usize = 15;
    let mut total_ok = 0u64;
    let mut total_shed = 0u64;
    // A couple of rounds so the test cannot flake on a scheduler that
    // briefly serializes the clients: invariants hold every round; we
    // stop once both outcomes (200 and 429) have been observed.
    for _round in 0..5 {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = HttpClient::connect(&addr).unwrap();
                let (mut ok, mut shed) = (0u64, 0u64);
                for i in 0..PER_CLIENT {
                    let body = forecast_body(&format!("load-{c}-{i}"));
                    let reply = client
                        .request("POST", "/v1/forecast", Some(&body))
                        .expect("request hung or connection died");
                    match reply.code {
                        200 => {
                            let doc = Json::parse(&reply.body).unwrap();
                            assert_eq!(
                                doc.get("forecast")
                                    .unwrap()
                                    .as_f32_vec()
                                    .unwrap()
                                    .len(),
                                HORIZON);
                            ok += 1;
                        }
                        429 => {
                            assert_eq!(reply.header("retry-after"),
                                       Some("1"),
                                       "429 must carry Retry-After");
                            shed += 1;
                        }
                        other => panic!(
                            "got {other} — overload must answer 200 or \
                             429, body: {}",
                            reply.body),
                    }
                }
                (ok, shed)
            }));
        }
        let mut round_ok = 0u64;
        let mut round_shed = 0u64;
        for j in joins {
            let (ok, shed) = j.join().expect("client thread panicked");
            round_ok += ok;
            round_shed += shed;
        }
        // Zero drops: every request got exactly one definite answer.
        assert_eq!(round_ok + round_shed, (CLIENTS * PER_CLIENT) as u64);
        total_ok += round_ok;
        total_shed += round_shed;
        if total_ok > 0 && total_shed > 0 {
            break;
        }
    }
    assert!(total_ok > 0, "nothing was served under overload");
    assert!(total_shed > 0,
            "queue_limit=1 under {CLIENTS} concurrent clients never shed — \
             backpressure is not engaging");
    assert_eq!(server.sheds(), 0,
               "accept backlog should not have shed (only the pool queue)");
    assert_eq!(server.stale_sheds(), 0,
               "no connection should have gone stale in the backlog");

    // Accounting closes exactly: accepted + shed == submitted.
    let st = stack.stats(FREQ).unwrap();
    assert_eq!(st.requests + st.rejected_overload, total_ok + total_shed);
    assert_eq!(st.requests, total_ok);
    assert_eq!(st.rejected_overload, total_shed);
    assert_eq!(st.queue_limit, 1);
}

#[test]
fn keep_alive_serves_sequential_and_pipelined_requests() {
    let (server, _stack) = start_server(
        ServiceOptions { workers: 1, ..Default::default() },
        HttpOptions::default(),
    );
    let addr = server.addr().to_string();

    // Many sequential requests on ONE connection, mixed routes.
    let mut client = HttpClient::connect(&addr).unwrap();
    for i in 0..4 {
        let reply = client.request("GET", "/v1/healthz", None).unwrap();
        assert_eq!(reply.code, 200, "request {i} on the shared connection");
        assert_eq!(reply.header("connection"), Some("keep-alive"));
        let body = forecast_body(&format!("ka-{i}"));
        let reply =
            client.request("POST", "/v1/forecast", Some(&body)).unwrap();
        assert_eq!(reply.code, 200, "{}", reply.body);
    }
    // Errors must not poison the connection: a 404 keeps it alive.
    let reply = client.request("GET", "/nope", None).unwrap();
    assert_eq!(reply.code, 404);
    let reply = client.request("GET", "/v1/healthz", None).unwrap();
    assert_eq!(reply.code, 200, "connection unusable after a 404");

    // Pipelined: two requests written back-to-back before reading —
    // both must come back, in order, on the same connection.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let two = "GET /v1/healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n\
               GET /v1/stats HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n";
    stream.write_all(two.as_bytes()).unwrap();
    let mut buf = Vec::new();
    let (code, body) = read_one_response(&mut stream, &mut buf);
    assert_eq!(code, 200);
    assert_eq!(Json::parse(&body).unwrap().get("status").unwrap()
                   .as_str().unwrap(), "ok");
    let (code, body) = read_one_response(&mut stream, &mut buf);
    assert_eq!(code, 200);
    assert!(Json::parse(&body).unwrap().get("serving").unwrap()
                .get(FREQ.name()).is_ok(),
            "second pipelined response should be /v1/stats");

    // Connection: close honored — response says close, then EOF.
    let req = "GET /v1/healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
               Connection: close\r\n\r\n";
    stream.write_all(req.as_bytes()).unwrap();
    let head = read_headers_raw(&mut stream, &mut buf);
    assert!(head.to_ascii_lowercase().contains("connection: close"),
            "close request must be answered with Connection: close: \
             {head}");
    // Drain the body, then expect EOF.
    let _ = read_one_response_from(&head, &mut stream, &mut buf);
    let mut probe = [0u8; 16];
    assert_eq!(stream.read(&mut probe).unwrap_or(0), 0,
               "server did not close after Connection: close");
}

#[test]
fn rotation_caps_requests_per_connection_and_clients_reconnect() {
    let (server, _stack) = start_server(
        ServiceOptions { workers: 1, ..Default::default() },
        HttpOptions { max_requests_per_conn: 2, ..Default::default() },
    );
    let addr = server.addr().to_string();

    // Raw socket: request 1 keeps the connection, request 2 hits the
    // rotation cap — `Connection: close` then EOF, freeing the worker.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let req = "GET /v1/healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n";
    stream.write_all(req.as_bytes()).unwrap();
    let mut buf = Vec::new();
    let head = read_headers_raw(&mut stream, &mut buf);
    assert!(head.to_ascii_lowercase().contains("connection: keep-alive"),
            "{head}");
    let _ = read_one_response_from(&head, &mut stream, &mut buf);
    stream.write_all(req.as_bytes()).unwrap();
    let head = read_headers_raw(&mut stream, &mut buf);
    assert!(head.to_ascii_lowercase().contains("connection: close"),
            "rotation cap must close the connection: {head}");
    let _ = read_one_response_from(&head, &mut stream, &mut buf);
    let mut probe = [0u8; 8];
    assert_eq!(stream.read(&mut probe).unwrap_or(0), 0,
               "server must close after the rotation cap");

    // HttpClient rides through rotations transparently.
    let mut client = HttpClient::connect(&addr).unwrap();
    for i in 0..7 {
        let reply = client.request("GET", "/v1/healthz", None).unwrap();
        assert_eq!(reply.code, 200, "request {i} across rotations");
    }
}

#[test]
fn oversized_requests_rejected_413_431_not_buffered() {
    let (server, _stack) = start_server(
        ServiceOptions { workers: 1, ..Default::default() },
        HttpOptions {
            max_body_bytes: 512,
            max_header_bytes: 512,
            ..Default::default()
        },
    );
    let addr = server.addr().to_string();

    // An actual body over the cap → 413.
    let big = "x".repeat(600);
    let (code, body) =
        http::http_request(&addr, "POST", "/v1/forecast", Some(&big)).unwrap();
    assert_eq!(code, 413, "{body}");

    // A hostile declared Content-Length with no body at all: refused
    // from the headers alone — nothing is read or allocated for it.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream
        .write_all(b"POST /v1/forecast HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: 999999999999\r\n\r\n")
        .unwrap();
    let mut buf = Vec::new();
    let (code, _) = read_one_response(&mut stream, &mut buf);
    assert_eq!(code, 413);

    // Oversized header section → 431.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let junk = "j".repeat(2000);
    stream
        .write_all(
            format!("GET /v1/healthz HTTP/1.1\r\nHost: t\r\nX-Junk: {junk}\r\n\
                     \r\n")
                .as_bytes())
        .unwrap();
    let mut buf = Vec::new();
    let (code, _) = read_one_response(&mut stream, &mut buf);
    assert_eq!(code, 431);

    // Unparseable Content-Length → 400, not a hang.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream
        .write_all(b"POST /v1/forecast HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: nope\r\n\r\n")
        .unwrap();
    let mut buf = Vec::new();
    let (code, _) = read_one_response(&mut stream, &mut buf);
    assert_eq!(code, 400);
}

#[test]
fn sharded_stack_routes_by_hash_and_aggregates_stats() {
    let sharded = ShardedStack::new();
    for label in ["alpha", "beta"] {
        let mut stack = ServingStack::new();
        stack
            .start_pool_native(FREQ, fresh_state(), ServiceOptions {
                workers: 1,
                ..Default::default()
            })
            .unwrap();
        sharded.add_shard(label, stack).unwrap();
    }
    let sharded = Arc::new(sharded);
    let server =
        HttpServer::start_sharded(Arc::clone(&sharded), "127.0.0.1:0")
            .unwrap();
    let addr = server.addr().to_string();

    // /healthz reports the ring.
    let (code, body) =
        http::http_request(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    let shards: Vec<String> = doc.get("shards").unwrap().as_arr().unwrap()
        .iter().map(|j| j.as_str().unwrap().to_string()).collect();
    assert_eq!(shards, vec!["alpha", "beta"]);

    // Route 40 distinct series ids; the router must agree with its own
    // published placement, and placement must be stable across calls.
    const N: usize = 40;
    let ids: Vec<String> = (0..N).map(|i| format!("series-{i}")).collect();
    let mut expect_alpha = 0u64;
    let mut expect_beta = 0u64;
    for id in &ids {
        let shard = sharded.shard_for(id).unwrap();
        assert_eq!(shard, sharded.shard_for(id).unwrap(),
                   "placement must be deterministic");
        match shard.as_str() {
            "alpha" => expect_alpha += 1,
            "beta" => expect_beta += 1,
            other => panic!("unknown shard {other}"),
        }
    }
    assert!(expect_alpha > 0 && expect_beta > 0,
            "40 keys all landed on one shard — ring is degenerate \
             (alpha={expect_alpha}, beta={expect_beta})");

    let mut client = HttpClient::connect(&addr).unwrap();
    for id in &ids {
        let reply = client
            .request("POST", "/v1/forecast", Some(&forecast_body(id)))
            .unwrap();
        assert_eq!(reply.code, 200, "{}", reply.body);
    }

    // Aggregate == exact sum of per-shard stats, and the per-shard split
    // matches the hash placement computed above.
    let agg = sharded.stats(FREQ).unwrap();
    assert_eq!(agg.requests, N as u64);
    let per_shard = sharded.shard_stats();
    let alpha = per_shard["alpha"][&FREQ].requests;
    let beta = per_shard["beta"][&FREQ].requests;
    assert_eq!(alpha + beta, agg.requests,
               "aggregate must equal the sum of shard stats");
    assert_eq!(alpha, expect_alpha);
    assert_eq!(beta, expect_beta);
    assert_eq!(agg.workers, 2, "worker counts sum across shards");

    // /v1/stats exposes the same aggregation over the wire: the
    // "serving" section is the fleet total, and the "shards" array
    // breaks it down per shard label.
    let reply = client.request("GET", "/v1/stats", None).unwrap();
    assert_eq!(reply.code, 200);
    let doc = Json::parse(&reply.body).unwrap();
    assert_eq!(doc.get("schema_version").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.get("serving").unwrap().get(FREQ.name()).unwrap()
                   .get("queue_accepted_total").unwrap()
                   .as_usize().unwrap(),
               N);
    let shard_rows = doc.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shard_rows.len(), 2);
    let alpha_row = shard_rows
        .iter()
        .find(|row| {
            row.get("shard").unwrap().as_str().unwrap() == "alpha"
        })
        .expect("alpha shard missing from /v1/stats shards");
    assert_eq!(alpha_row.get("serving").unwrap().get(FREQ.name()).unwrap()
                   .get("queue_accepted_total").unwrap()
                   .as_usize().unwrap() as u64,
               expect_alpha);

    // Drain protocol: removing a shard stops routing to it; traffic
    // keeps flowing to the survivor and the drained shard's accepted
    // work was already answered (we hold no pending requests here, so
    // dropping the Arc shuts it down cleanly).
    let drained = sharded.remove_shard("alpha").unwrap();
    drop(drained);
    assert_eq!(sharded.shard_labels(), vec!["beta"]);
    for id in ids.iter().take(10) {
        assert_eq!(sharded.shard_for(id).unwrap(), "beta");
        let reply = client
            .request("POST", "/v1/forecast", Some(&forecast_body(id)))
            .unwrap();
        assert_eq!(reply.code, 200,
                   "traffic must keep flowing after a shard drain: {}",
                   reply.body);
    }
    // The last shard is protected.
    assert!(sharded.remove_shard("beta").is_err());
}

// ---------------------------------------------------------------------
// Raw-socket response helpers (Content-Length framed, like the server).
// ---------------------------------------------------------------------

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read exactly the raw header section (through `\r\n\r\n`) into a
/// string, leaving any surplus (body bytes) in `buf`.
fn read_headers_raw(stream: &mut TcpStream, buf: &mut Vec<u8>) -> String {
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subsequence(buf, b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut tmp).expect("read");
        assert!(n > 0, "EOF before response headers completed");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8(buf[..header_end].to_vec()).unwrap();
    buf.drain(..header_end + 4);
    head
}

/// Finish reading one response whose headers are already in `head`;
/// returns (status, body).
fn read_one_response_from(head: &str, stream: &mut TcpStream,
                          buf: &mut Vec<u8>) -> (u16, String) {
    let code: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let content_length: usize = head
        .lines()
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse().ok())
        .expect("Content-Length");
    let mut tmp = [0u8; 4096];
    while buf.len() < content_length {
        let n = stream.read(&mut tmp).expect("read");
        assert!(n > 0, "EOF mid-body");
        buf.extend_from_slice(&tmp[..n]);
    }
    let body = String::from_utf8(buf[..content_length].to_vec()).unwrap();
    buf.drain(..content_length);
    (code, body)
}

/// Read one full Content-Length-framed response; surplus (the next
/// pipelined response) stays in `buf`.
fn read_one_response(stream: &mut TcpStream, buf: &mut Vec<u8>)
                     -> (u16, String) {
    let head = read_headers_raw(stream, buf);
    read_one_response_from(&head, stream, buf)
}
