//! Serving-stack integration tests: per-frequency worker pools,
//! generation-tagged model hot-swap under concurrent load, and the HTTP
//! front-end — all on the pure-Rust native backend.
//!
//! The hot-swap invariant under test: while reloads race live traffic,
//! **zero requests are dropped and every response is computed from one
//! coherent model generation** — a response tagged generation g must
//! equal the forecast that generation g's weights produce, never a blend
//! of two checkpoints.

use std::sync::mpsc;
use std::time::Duration;

use fast_esrnn::config::{Category, Frequency, TrainConfig};
use fast_esrnn::coordinator::{checkpoint, ModelState, ParamStore, Trainer};
use fast_esrnn::data::{generate, GenOptions, Series};
use fast_esrnn::forecast::{http, ForecastRequest, ForecastService,
                           HttpServer, ServiceOptions, ServingStack};
use fast_esrnn::hw::Primer;
use fast_esrnn::runtime::NativeBackend;
use fast_esrnn::util::json::Json;

const FREQ: Frequency = Frequency::Quarterly;
const HORIZON: usize = 8;

/// Train a small quarterly model; return its state.
fn trained_state() -> ModelState {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 600, ..Default::default() })
        .unwrap();
    let tc = TrainConfig {
        epochs: 1,
        batch_size: 16,
        patience: 50,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&backend, FREQ, &corpus, tc).unwrap();
    trainer.train(false).unwrap();
    trainer.state.clone()
}

/// A deterministically different model: every shared RNN weight scaled
/// by 10% — guaranteed to forecast differently from the original, so a
/// response mixing tensors from the two states cannot match either.
fn perturbed(state: &ModelState) -> ModelState {
    let mut out = state.clone();
    for (name, t) in out.tensors.iter_mut() {
        if name.starts_with("params.rnn.") {
            for v in t.data.iter_mut() {
                *v *= 1.10;
            }
        }
    }
    out
}

/// A request series the model never saw, long enough for the C=72 cut.
fn probe_series() -> Series {
    let corpus = generate(&GenOptions {
        scale: 600,
        seed: 9,
        freqs: Some(vec![FREQ]),
    })
    .unwrap();
    corpus
        .series
        .into_iter()
        .find(|s| s.len() >= 72)
        .expect("need one quarterly series ≥ 72 values")
}

/// Ground truth: what `state` forecasts for `probe`, computed on a
/// dedicated single-worker service.
fn expected_forecast(state: &ModelState, probe: &Series) -> Vec<f32> {
    let service =
        ForecastService::start_native(FREQ, state.clone(),
                                      ServiceOptions::default())
            .unwrap();
    let resp = service
        .handle
        .forecast(ForecastRequest {
            id: "probe".into(),
            values: probe.values.clone(),
            category: Category::Other,
        })
        .unwrap();
    resp.forecast
}

fn max_rel_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (x - y).abs() as f64;
            d / (x.abs().max(y.abs()).max(1e-6) as f64)
        })
        .fold(0.0, f64::max)
}

#[test]
fn hot_swap_under_load_keeps_every_response_coherent() {
    let state_a = trained_state();
    let state_b = perturbed(&state_a);
    let probe = probe_series();
    let expect_a = expected_forecast(&state_a, &probe);
    let expect_b = expected_forecast(&state_b, &probe);
    assert_eq!(expect_a.len(), HORIZON);
    // The two generations must be clearly distinguishable, or the
    // coherence check below would be vacuous.
    assert!(max_rel_diff(&expect_a, &expect_b) > 1e-2,
            "states A and B forecast too similarly to discriminate");

    let mut stack = ServingStack::new();
    stack
        .start_pool_native(FREQ, state_a.clone(), ServiceOptions {
            workers: 3,
            batch_window: Duration::from_millis(1),
            max_batch: 8,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(stack.generation(FREQ).unwrap(), 1);

    // 4 client threads × 30 sequential blocking forecasts of the probe.
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 30;
    let (res_tx, res_rx) = mpsc::channel::<(u64, Vec<f32>)>();
    let mut joins = Vec::new();
    for _ in 0..CLIENTS {
        let handle = stack.handle(FREQ).unwrap();
        let tx = res_tx.clone();
        let values = probe.values.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..PER_CLIENT {
                let resp = handle
                    .forecast(ForecastRequest {
                        id: format!("probe-{i}"),
                        values: values.clone(),
                        category: Category::Other,
                    })
                    .expect("request dropped during hot-swap");
                tx.send((resp.generation, resp.forecast)).unwrap();
            }
        }));
    }
    drop(res_tx);

    // Meanwhile: hot-swap B, A, B, … racing the live traffic. Odd
    // generations are A (the initial generation is 1), even are B.
    const RELOADS: usize = 8;
    for k in 0..RELOADS {
        std::thread::sleep(Duration::from_millis(10));
        let state = if k % 2 == 0 { state_b.clone() } else { state_a.clone() };
        let generation = stack.reload(FREQ, state).unwrap();
        assert_eq!(generation as usize, k + 2);
    }

    for j in joins {
        j.join().unwrap();
    }

    // Zero dropped: every submitted request came back Ok.
    let responses: Vec<(u64, Vec<f32>)> = res_rx.iter().collect();
    assert_eq!(responses.len(), CLIENTS * PER_CLIENT);

    // Coherence: a response tagged generation g must exactly match what
    // generation g's weights forecast — never a mix.
    let mut seen = std::collections::BTreeSet::new();
    for (generation, fc) in &responses {
        seen.insert(*generation);
        let expected = if generation % 2 == 1 { &expect_a } else { &expect_b };
        let diff = max_rel_diff(fc, expected);
        assert!(diff < 1e-4,
                "generation {generation} response diverges from its \
                 generation's forecast (rel diff {diff:.2e}) — incoherent \
                 model state");
    }
    assert!(seen.len() >= 2,
            "reloads never landed during traffic (only generations {seen:?} \
             observed) — increase PER_CLIENT");

    let st = stack.stats(FREQ).unwrap();
    assert_eq!(st.requests, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(st.rejected, 0);
    assert_eq!(st.reloads, RELOADS as u64);
    assert_eq!(st.generation, (RELOADS + 1) as u64);
    assert_eq!(st.workers, 3);
    assert!(st.total.count >= st.requests,
            "latency recorder missed requests");
}

#[test]
fn http_front_end_serves_forecasts_stats_health_and_reload() {
    let state_a = trained_state();
    let state_b = perturbed(&state_a);
    let probe = probe_series();
    let expect_a = expected_forecast(&state_a, &probe);
    let expect_b = expected_forecast(&state_b, &probe);

    // A binary checkpoint for B that the reload endpoint will load.
    let dir = std::env::temp_dir().join("fast_esrnn_serving_http");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_b = dir.join("b.bin");
    let store = dummy_store();
    checkpoint::save(&ckpt_b, FREQ.name(), &state_b, &store).unwrap();
    // A checkpoint labeled for another frequency: reload must refuse it.
    let ckpt_wrong = dir.join("wrong.bin");
    checkpoint::save(&ckpt_wrong, "monthly", &state_b, &store).unwrap();

    let mut stack = ServingStack::new();
    stack
        .start_pool_native(FREQ, state_a, ServiceOptions {
            workers: 2,
            ..Default::default()
        })
        .unwrap();
    let stack = std::sync::Arc::new(stack);
    let server = HttpServer::start(stack.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    // POST /v1/forecast — `freq` may be omitted with a single pool.
    let body = Json::obj(vec![
        ("id", Json::str("probe")),
        ("category", Json::str("Other")),
        ("values", Json::arr_f32(&probe.values)),
    ])
    .to_string();
    let (code, reply) =
        http::http_request(&addr, "POST", "/v1/forecast", Some(&body))
            .unwrap();
    assert_eq!(code, 200, "{reply}");
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("id").unwrap().as_str().unwrap(), "probe");
    assert_eq!(doc.get("freq").unwrap().as_str().unwrap(), "quarterly");
    assert_eq!(doc.get("generation").unwrap().as_usize().unwrap(), 1);
    let fc = doc.get("forecast").unwrap().as_f32_vec().unwrap();
    assert_eq!(fc.len(), HORIZON);
    assert!(max_rel_diff(&fc, &expect_a) < 1e-4,
            "HTTP forecast disagrees with the in-process service");

    // GET /v1/healthz
    let (code, reply) =
        http::http_request(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(doc.get("generations").unwrap().get("quarterly").unwrap()
                   .as_usize().unwrap(), 1);

    // POST /v1/reload — hot-swap to B from the binary checkpoint.
    let body = Json::obj(vec![
        ("checkpoint", Json::str(ckpt_b.display().to_string())),
    ])
    .to_string();
    let (code, reply) =
        http::http_request(&addr, "POST", "/v1/reload", Some(&body))
            .unwrap();
    assert_eq!(code, 200, "{reply}");
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("generation").unwrap().as_usize().unwrap(), 2);

    // The same request now answers from generation 2 with B's forecast.
    let body = Json::obj(vec![
        ("values", Json::arr_f32(&probe.values)),
    ])
    .to_string();
    let (code, reply) =
        http::http_request(&addr, "POST", "/v1/forecast", Some(&body))
            .unwrap();
    assert_eq!(code, 200, "{reply}");
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("generation").unwrap().as_usize().unwrap(), 2);
    let fc = doc.get("forecast").unwrap().as_f32_vec().unwrap();
    assert!(max_rel_diff(&fc, &expect_b) < 1e-4,
            "post-reload forecast is not generation 2's");

    // GET /v1/stats — schema version 1, metric-named fields.
    let (code, reply) =
        http::http_request(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("schema_version").unwrap().as_usize().unwrap(), 1);
    let q = doc.get("serving").unwrap().get("quarterly").unwrap();
    assert!(q.get("queue_accepted_total").unwrap().as_usize().unwrap() >= 2);
    assert_eq!(q.get("reloads_total").unwrap().as_usize().unwrap(), 1);
    assert!(q.get("request_total_seconds").unwrap().get("p95").unwrap()
                .as_f64().unwrap() >= 0.0);
    assert!(doc.get("http").unwrap().get("http_connections_total").unwrap()
                .as_usize().unwrap() >= 1);
    assert_eq!(doc.get("shards").unwrap().as_arr().unwrap().len(), 1);

    // Error paths: bad JSON, short history, wrong-frequency checkpoint,
    // unknown route, wrong method — all carrying the error envelope.
    let (code, reply) =
        http::http_request(&addr, "POST", "/v1/forecast", Some("{not json"))
            .unwrap();
    assert_eq!(code, 400);
    let err = Json::parse(&reply).unwrap();
    assert_eq!(err.get("error").unwrap().get("code").unwrap()
                   .as_str().unwrap(),
               "bad_request");

    let body = Json::obj(vec![
        ("values", Json::arr_f32(&[1.0, 2.0, 3.0])),
    ])
    .to_string();
    let (code, _) =
        http::http_request(&addr, "POST", "/v1/forecast", Some(&body))
            .unwrap();
    assert_eq!(code, 400, "short history must be rejected");

    let body = Json::obj(vec![
        ("checkpoint", Json::str(ckpt_wrong.display().to_string())),
    ])
    .to_string();
    let (code, reply) =
        http::http_request(&addr, "POST", "/v1/reload", Some(&body))
            .unwrap();
    assert_eq!(code, 400, "wrong-frequency checkpoint must be refused");
    assert!(reply.contains("monthly"), "{reply}");
    // The refused reload left the generation untouched.
    assert_eq!(stack.generation(FREQ).unwrap(), 2);

    let (code, reply) =
        http::http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(code, 404);
    assert_eq!(Json::parse(&reply).unwrap().get("error").unwrap()
                   .get("code").unwrap().as_str().unwrap(),
               "not_found");
    let (code, reply) =
        http::http_request(&addr, "DELETE", "/v1/forecast", None).unwrap();
    assert_eq!(code, 405);
    assert_eq!(Json::parse(&reply).unwrap().get("error").unwrap()
                   .get("code").unwrap().as_str().unwrap(),
               "method_not_allowed");
}

/// Any store works for serving checkpoints: `load_model_state` reads
/// only the shared model tensors.
fn dummy_store() -> ParamStore {
    let primers: Vec<Primer> = (0..2)
        .map(|_| Primer {
            alpha_logit: 0.0,
            gamma_logit: 0.0,
            gamma2_logit: 0.0,
            log_s_init: vec![0.0; 4],
        })
        .collect();
    ParamStore::from_primers(&primers, 4).unwrap()
}
