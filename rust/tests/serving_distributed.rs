//! Multi-process distributed-serving suite — the contract under test:
//!
//! * N real `HttpServer` *processes* joined into one ring as
//!   [`RemoteShard`]s serve real traffic through the replicated,
//!   hedged dispatch path;
//! * killing one shard process mid-traffic loses **nothing**: with
//!   R = 2 every request still completes Ok-or-[`QueueFull`] (the PR 5
//!   exact-accounting invariant, now across machines), and the health
//!   prober ejects the dead shard — the ejection counter fires;
//! * an ejected remote is readmitted after probation once its peer
//!   comes back, with placement unchanged (ejection is a routing mask,
//!   not a ring mutation).
//!
//! The shard processes are this same test binary re-executed with a
//! libtest filter selecting [`dist_shard_server_child`], which serves
//! until killed when `FESRNN_DIST_ADDR_FILE` names a file to publish
//! its listen address in (and is a no-op in a normal test run).

use std::process::{Child as OsChild, Command, Stdio};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use fast_esrnn::config::{Category, Frequency};
use fast_esrnn::coordinator::ModelState;
use fast_esrnn::forecast::{ForecastRequest, HttpOptions, HttpServer,
                           QueueFull, RemoteOptions, RemoteShard,
                           ServiceOptions, ServingStack, ShardClient,
                           ShardedStack};
use fast_esrnn::runtime::NativeBackend;

const FREQ: Frequency = Frequency::Quarterly;
const HORIZON: usize = 8;

fn fresh_state() -> ModelState {
    let backend = NativeBackend::new();
    ModelState::init(&backend, FREQ.name(), 42).unwrap()
}

/// A positive synthetic history long enough for the quarterly C=72 cut.
fn probe_values() -> Vec<f32> {
    (0..80)
        .map(|i| 100.0 + i as f32 * 0.5 + (i % 4) as f32 * 3.0)
        .collect()
}

fn request_for(id: &str) -> ForecastRequest {
    ForecastRequest {
        id: id.to_string(),
        values: probe_values(),
        category: Category::Other,
    }
}

/// Probe knobs tightened so ejection (2 failures × 50 ms) and
/// readmission (2 successes) are observable in test time.
fn fast_opts() -> RemoteOptions {
    RemoteOptions {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(10),
        pool_size: 4,
        probe_interval: Duration::from_millis(50),
        probe_timeout: Duration::from_millis(250),
        eject_after: 2,
        readmit_after: 2,
    }
}

fn start_local_server(addr: &str) -> anyhow::Result<HttpServer> {
    let mut stack = ServingStack::new();
    stack.start_pool_native(FREQ, fresh_state(), ServiceOptions {
        workers: 2,
        queue_limit: 256,
        ..Default::default()
    })?;
    let sharded = Arc::new(ShardedStack::single(Arc::new(stack))?);
    HttpServer::start_with(sharded, addr, HttpOptions::default())
}

/// The shard-process entrypoint: a no-op under a normal `cargo test`
/// run; when re-executed with `FESRNN_DIST_ADDR_FILE` set it starts a
/// real single-shard HTTP server, publishes its address, and serves
/// until the parent kills the process.
#[test]
fn dist_shard_server_child() {
    let Ok(path) = std::env::var("FESRNN_DIST_ADDR_FILE") else {
        return;
    };
    let server = start_local_server("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    // Write to a sibling then rename: the parent polls the file and
    // must never observe a half-written address.
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &addr).unwrap();
    std::fs::rename(&tmp, &path).unwrap();
    loop {
        thread::park(); // serve until killed
    }
}

/// A spawned shard process, killed (not leaked) on every test exit path.
struct ShardProc {
    proc: OsChild,
    addr: String,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        let _ = self.proc.kill();
        let _ = self.proc.wait();
    }
}

fn spawn_shard_process(tag: &str) -> ShardProc {
    let exe = std::env::current_exe().unwrap();
    let file = std::env::temp_dir()
        .join(format!("fesrnn-dist-{}-{tag}.addr", std::process::id()));
    let _ = std::fs::remove_file(&file);
    let proc = Command::new(exe)
        .args(["dist_shard_server_child", "--exact"])
        .env("FESRNN_DIST_ADDR_FILE", &file)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&file) {
            let s = s.trim().to_string();
            if s.contains(':') {
                break s;
            }
        }
        assert!(Instant::now() < deadline,
                "shard child `{tag}` never published an address");
        thread::sleep(Duration::from_millis(20));
    };
    let _ = std::fs::remove_file(&file);
    ShardProc { proc, addr }
}

fn wait_for<F: FnMut() -> bool>(what: &str, timeout: Duration, mut cond: F) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn kill_one_shard_mid_traffic_loses_nothing_and_ejects() {
    let mut shards: Vec<ShardProc> =
        (0..3).map(|i| spawn_shard_process(&format!("kill{i}"))).collect();

    let sharded = Arc::new(ShardedStack::new());
    for (i, sp) in shards.iter().enumerate() {
        let remote = RemoteShard::connect(&sp.addr, fast_opts()).unwrap();
        sharded.add_remote_shard(&format!("remote-{i}"), remote).unwrap();
    }
    sharded.set_replicas(2);

    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 40;
    const KILL_AT: usize = 8; // requests per client before the kill

    let barrier = Arc::new(std::sync::Barrier::new(CLIENTS + 1));
    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let sharded = Arc::clone(&sharded);
        let barrier = Arc::clone(&barrier);
        joins.push(thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            for i in 0..PER_CLIENT {
                if i == KILL_AT {
                    barrier.wait(); // all clients mid-stream → kill fires
                }
                let req = request_for(&format!("dist-{c}-{i}"));
                match sharded.forecast(FREQ, req) {
                    Ok(resp) => {
                        assert_eq!(resp.forecast.len(), HORIZON);
                        ok += 1;
                    }
                    // Backpressure is the one acceptable refusal — and
                    // it is *accounted*, exactly like single-process
                    // overload.
                    Err(e) if e.is::<QueueFull>() => shed += 1,
                    Err(e) => panic!(
                        "request dist-{c}-{i} was lost (neither served \
                         nor shed): {e:#}"),
                }
            }
            (ok, shed)
        }));
    }

    // Kill shard 0 while every client is mid-traffic. R = 2 means each
    // key has a live replica; failover + hedging must absorb the loss.
    // (The other two ShardProcs stay alive until the test ends.)
    barrier.wait();
    let _ = shards[0].proc.kill();
    let _ = shards[0].proc.wait();

    let (mut total_ok, mut total_shed) = (0u64, 0u64);
    for j in joins {
        let (ok, shed) = j.join().unwrap();
        total_ok += ok;
        total_shed += shed;
    }
    // The PR 5 exact-accounting invariant, across a process kill: every
    // submitted request was served or explicitly shed — zero lost.
    assert_eq!(total_ok + total_shed, (CLIENTS * PER_CLIENT) as u64);
    assert!(total_ok > 0, "no request succeeded at all");

    // The prober must notice the dead peer and fire the ejection
    // counter (2 consecutive failures at 50 ms probes → well inside
    // the deadline even on a loaded CI box).
    wait_for("the dead shard's ejection", Duration::from_secs(10), || {
        sharded
            .shard_health()
            .values()
            .any(|h| !h.healthy && h.ejections >= 1)
    });
    let rendered = sharded.registry().render();
    assert!(rendered.contains("fesrnn_remote_ejections_total"),
            "ejection counter missing from the registry render");
}

#[test]
fn ejected_remote_is_readmitted_after_probation() {
    let server = start_local_server("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let remote = RemoteShard::connect(&addr, fast_opts()).unwrap();
    assert!(ShardClient::healthy(&remote), "fresh remote must be healthy");

    // Peer goes away → consecutive probe failures → ejection.
    server.shutdown();
    drop(server);
    wait_for("ejection of the dead peer", Duration::from_secs(10),
             || !ShardClient::healthy(&remote));
    let h = ShardClient::health(&remote);
    assert_eq!(h.kind, "remote");
    assert!(h.ejections >= 1, "ejection transition was not counted");
    assert!(h.probe_failures >= 2, "consecutive failures not recorded");

    // Peer comes back on the *same* address → probation (2 clean
    // probes) → readmission. The listen port may sit in TIME_WAIT
    // briefly after the shutdown, so the rebind retries.
    let deadline = Instant::now() + Duration::from_secs(10);
    let _server2 = loop {
        match start_local_server(&addr) {
            Ok(s) => break s,
            Err(e) => {
                assert!(Instant::now() < deadline,
                        "could not rebind {addr}: {e:#}");
                thread::sleep(Duration::from_millis(100));
            }
        }
    };
    wait_for("readmission after probation", Duration::from_secs(10),
             || ShardClient::healthy(&remote));
    assert!(ShardClient::health(&remote).healthy);
}
