//! `/v1/metrics` conformance under live traffic — the observability
//! contract:
//!
//! * every scrape parses as valid Prometheus text exposition format
//!   (strict parser: `# TYPE` discipline, name charset, label escapes);
//! * counters (including histogram `_bucket`/`_count` series) are
//!   monotonically non-decreasing across scrapes taken while load is in
//!   flight;
//! * accounting closes exactly between the two surfaces:
//!   `accepted + shed == submitted` per `{shard, freq}`, and the
//!   `/v1/metrics` values equal the `/v1/stats` values;
//! * legacy unversioned paths are aliases: byte-identical payloads plus
//!   `Deprecation` / `Link` headers that the `/v1` routes do not carry;
//! * the resource-first series routes (`POST /v1/series/{id}/observe`,
//!   `GET /v1/series/{id}/forecast`, `GET /v1/series/{id}/state`) speak
//!   the typed DTO shapes with `unknown_series` / `stale_observation`
//!   envelope codes, and `POST /v1/forecast` is itself a deprecated
//!   alias of the series spelling — same payload, successor `Link`,
//!   alias-hit counter.
//!
//! Runs on the native backend with fresh weights (metric plumbing does
//! not depend on trained weights), one starved pool per shard so both
//! the accepted and the shed paths are exercised.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use fast_esrnn::config::Frequency;
use fast_esrnn::coordinator::ModelState;
use fast_esrnn::forecast::{HttpClient, HttpOptions, HttpServer,
                           ServiceOptions, ServingStack, ShardedStack};
use fast_esrnn::runtime::NativeBackend;
use fast_esrnn::telemetry::promtext::{self, Sample};
use fast_esrnn::util::json::Json;

const FREQ: Frequency = Frequency::Quarterly;
const SHARDS: [&str; 2] = ["alpha", "beta"];

fn fresh_state() -> ModelState {
    let backend = NativeBackend::new();
    ModelState::init(&backend, FREQ.name(), 42).unwrap()
}

/// A positive synthetic history long enough for the quarterly C=72 cut.
fn forecast_body(id: &str) -> String {
    let values: Vec<f32> = (0..80)
        .map(|i| 100.0 + i as f32 * 0.5 + (i % 4) as f32 * 3.0)
        .collect();
    Json::obj(vec![
        ("id", Json::str(id)),
        ("values", Json::arr_f32(&values)),
    ])
    .to_string()
}

/// Two starved shards behind one front-end: tiny queue so concurrent
/// clients force both 200s and 429s.
fn start_ring() -> (HttpServer, Arc<ShardedStack>) {
    let sharded = ShardedStack::new();
    for label in SHARDS {
        let mut stack = ServingStack::new();
        stack
            .start_pool_native(FREQ, fresh_state(), ServiceOptions {
                workers: 1,
                queue_limit: 2,
                batch_window: Duration::from_millis(1),
                max_batch: 1,
                ..Default::default()
            })
            .unwrap();
        sharded.add_shard(label, stack).unwrap();
    }
    let sharded = Arc::new(sharded);
    let server = HttpServer::start_with(
        Arc::clone(&sharded),
        "127.0.0.1:0",
        HttpOptions { conn_workers: 16, ..Default::default() },
    )
    .unwrap();
    (server, sharded)
}

/// Counter-valued samples (plain counters plus histogram `_bucket` /
/// `_count` series) keyed by name + sorted labels — the monotonicity
/// domain.
fn counter_map(samples: &[Sample]) -> BTreeMap<String, f64> {
    samples
        .iter()
        .filter(|s| {
            s.kind == "counter"
                || (s.kind == "histogram" && !s.name.ends_with("_sum"))
        })
        .map(|s| {
            let mut labels = s.labels.clone();
            labels.sort();
            (format!("{}{labels:?}", s.name), s.value)
        })
        .collect()
}

fn metric(samples: &[Sample], name: &str, shard: &str) -> f64 {
    promtext::value(samples, name,
                    &[("shard", shard), ("freq", FREQ.name())])
}

#[test]
fn metrics_scrapes_are_valid_monotonic_and_agree_with_stats() {
    let (server, _sharded) = start_ring();
    let addr = server.addr().to_string();

    // Saturating traffic until both outcomes (accept and shed) have
    // been observed on the wire.
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 15;
    let mut total_ok = 0u64;
    let mut total_shed = 0u64;
    let mut scrapes: Vec<Vec<Sample>> = Vec::new();
    let mut scraper = HttpClient::connect(&addr).unwrap();
    for round in 0..5 {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = HttpClient::connect(&addr).unwrap();
                let (mut ok, mut shed) = (0u64, 0u64);
                for i in 0..PER_CLIENT {
                    let body =
                        forecast_body(&format!("series-{}", (c * 5 + i) % 40));
                    let reply = client
                        .request("POST", "/v1/forecast", Some(&body))
                        .expect("request hung or connection died");
                    match reply.code {
                        200 => ok += 1,
                        429 => shed += 1,
                        other => panic!("got {other}: {}", reply.body),
                    }
                }
                (ok, shed)
            }));
        }
        // Scrape while that load is in flight: every line must parse,
        // and counters must never move backwards.
        for _ in 0..3 {
            let reply = scraper.request("GET", "/v1/metrics", None).unwrap();
            assert_eq!(reply.code, 200);
            assert_eq!(reply.header("content-type"),
                       Some("text/plain; version=0.0.4"));
            let samples = promtext::parse(&reply.body)
                .expect("scrape is not valid Prometheus text");
            scrapes.push(samples);
            std::thread::sleep(Duration::from_millis(20));
        }
        for j in joins {
            let (ok, shed) = j.join().expect("client thread panicked");
            total_ok += ok;
            total_shed += shed;
        }
        if total_ok > 0 && total_shed > 0 {
            break;
        }
        assert!(round < 4, "never observed both 200s and 429s");
    }
    assert!(total_ok > 0 && total_shed > 0);

    // A final quiescent scrape joins the monotonicity chain and anchors
    // the accounting checks below.
    let reply = scraper.request("GET", "/v1/metrics", None).unwrap();
    let final_samples = promtext::parse(&reply.body).unwrap();
    scrapes.push(final_samples);
    let last = scrapes.last().unwrap();

    for pair in scrapes.windows(2) {
        let (before, after) = (counter_map(&pair[0]), counter_map(&pair[1]));
        for (key, prev) in &before {
            let now = after.get(key).unwrap_or_else(|| {
                panic!("counter {key} disappeared between scrapes")
            });
            assert!(now >= prev,
                    "counter {key} went backwards: {prev} -> {now}");
        }
    }

    // Coverage: every surface ISSUE names must be present.
    for name in [
        "fesrnn_queue_depth",
        "fesrnn_queue_accepted_total",
        "fesrnn_queue_shed_total",
        "fesrnn_backend_spawns",
        "fesrnn_backend_scratch_bytes",
        "fesrnn_http_connections_total",
    ] {
        assert!(last.iter().any(|s| s.family == name),
                "metric family {name} missing from the exposition");
    }
    assert!(last.iter()
                .any(|s| s.name == "fesrnn_request_total_seconds_bucket"),
            "latency histogram buckets missing");

    // Accounting closes exactly, per shard and in total, and the two
    // surfaces agree. Traffic has fully drained (every client got its
    // response before join), so stats and the final scrape are stable.
    let reply = scraper.request("GET", "/v1/stats", None).unwrap();
    assert_eq!(reply.code, 200);
    let stats = Json::parse(&reply.body).unwrap();
    let (mut accepted_sum, mut shed_sum) = (0u64, 0u64);
    let shard_rows = stats.get("shards").unwrap().as_arr().unwrap();
    for shard in SHARDS {
        let submitted = metric(last, "fesrnn_queue_submitted_total", shard);
        let accepted = metric(last, "fesrnn_queue_accepted_total", shard);
        let shed = metric(last, "fesrnn_queue_shed_total", shard);
        assert_eq!(accepted + shed, submitted,
                   "[{shard}] accepted + shed != submitted");
        let row = shard_rows
            .iter()
            .find(|r| r.get("shard").unwrap().as_str().unwrap() == shard)
            .unwrap_or_else(|| panic!("shard {shard} missing from stats"));
        let serving = row.get("serving").unwrap().get(FREQ.name()).unwrap();
        assert_eq!(serving.get("queue_accepted_total").unwrap()
                       .as_f64().unwrap(),
                   accepted,
                   "[{shard}] /v1/stats disagrees with /v1/metrics");
        assert_eq!(serving.get("queue_shed_total").unwrap()
                       .as_f64().unwrap(),
                   shed);
        accepted_sum += accepted as u64;
        shed_sum += shed as u64;
    }
    assert_eq!(accepted_sum, total_ok);
    assert_eq!(shed_sum, total_shed);

    // Legacy paths are aliases: byte-identical payloads, plus the
    // deprecation headers only the legacy spelling carries. Legacy goes
    // FIRST: its own deprecation hit is counted before rendering, so
    // the /v1 follow-up sees the same counter values.
    for (legacy, v1) in [("/stats", "/v1/stats"),
                         ("/metrics", "/v1/metrics"),
                         ("/healthz", "/v1/healthz")] {
        let old = scraper.request("GET", legacy, None).unwrap();
        let new = scraper.request("GET", v1, None).unwrap();
        assert_eq!(old.code, 200);
        assert_eq!(new.code, 200);
        assert_eq!(old.body, new.body,
                   "{legacy} and {v1} must serve identical payloads");
        assert_eq!(old.header("deprecation"), Some("true"),
                   "{legacy} must be marked deprecated");
        assert_eq!(old.header("link"),
                   Some(format!("<{v1}>; rel=\"successor-version\"")
                            .as_str()),
                   "{legacy} must link its successor");
        assert_eq!(new.header("deprecation"), None,
                   "{v1} must not be marked deprecated");
    }
}

fn deprecated_hits(scraper: &mut HttpClient) -> f64 {
    let reply = scraper.request("GET", "/v1/metrics", None).unwrap();
    assert_eq!(reply.code, 200);
    let samples = promtext::parse(&reply.body).unwrap();
    promtext::value(&samples, "fesrnn_http_deprecated_requests_total", &[])
}

#[test]
fn series_routes_conform_and_v1_forecast_is_a_deprecated_alias() {
    let (server, _sharded) = start_ring();
    let addr = server.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // Observe: seed a series through the resource route. The stack
    // serves one frequency, so `freq` may be omitted from the body.
    let values: Vec<f32> =
        (0..16).map(|i| 100.0 + (i % 4) as f32 * 5.0).collect();
    let body = Json::obj(vec![
        ("t0", Json::num(0.0)),
        ("values", Json::arr_f32(&values)),
    ])
    .to_string();
    let reply = client
        .request("POST", "/v1/series/s-conf/observe", Some(&body))
        .unwrap();
    assert_eq!(reply.code, 200, "observe failed: {}", reply.body);
    let doc = Json::parse(&reply.body).unwrap();
    assert_eq!(doc.get("id").unwrap().as_str().unwrap(), "s-conf");
    assert_eq!(doc.get("freq").unwrap().as_str().unwrap(), FREQ.name());
    assert_eq!(doc.get("observed").unwrap().as_f64().unwrap(), 16.0);
    assert!(doc.get("new_series").unwrap().as_bool().unwrap());

    // Stateful forecast + state routes: typed shapes, no deprecation
    // headers, and the explicit `?freq=` spelling also resolves.
    let fc = client
        .request("GET", "/v1/series/s-conf/forecast", None)
        .unwrap();
    assert_eq!(fc.code, 200, "series forecast failed: {}", fc.body);
    assert_eq!(fc.header("deprecation"), None);
    let doc = Json::parse(&fc.body).unwrap();
    assert_eq!(doc.get("id").unwrap().as_str().unwrap(), "s-conf");
    assert_eq!(doc.get("forecast").unwrap().as_f32_vec().unwrap().len(),
               8);
    let st = client
        .request("GET",
                 &format!("/v1/series/s-conf/state?freq={}", FREQ.name()),
                 None)
        .unwrap();
    assert_eq!(st.code, 200, "series state failed: {}", st.body);
    assert_eq!(st.header("deprecation"), None);
    let doc = Json::parse(&st.body).unwrap();
    assert_eq!(doc.get("observed").unwrap().as_f64().unwrap(), 16.0);
    assert_eq!(doc.get("seasonality").unwrap().as_f32_vec().unwrap()
                   .len(),
               4);
    assert!(doc.get("seasonality2").unwrap().as_f32_vec().unwrap()
               .is_empty());

    // Typed envelope codes: an unseen id is `unknown_series` (404), a
    // rewound batch is `stale_observation` (409), a batch past the tip
    // is a plain 400 — all in the standard error envelope.
    let missing = client
        .request("GET", "/v1/series/nobody/forecast", None)
        .unwrap();
    assert_eq!(missing.code, 404);
    let env = Json::parse(&missing.body).unwrap();
    assert_eq!(env.get("error").unwrap().get("code").unwrap()
                  .as_str().unwrap(),
               "unknown_series");
    let stale_body = Json::obj(vec![
        ("t0", Json::num(3.0)),
        ("values", Json::arr_f32(&[1.0])),
    ])
    .to_string();
    let stale = client
        .request("POST", "/v1/series/s-conf/observe", Some(&stale_body))
        .unwrap();
    assert_eq!(stale.code, 409, "rewound observe: {}", stale.body);
    let env = Json::parse(&stale.body).unwrap();
    assert_eq!(env.get("error").unwrap().get("code").unwrap()
                  .as_str().unwrap(),
               "stale_observation");
    let gap_body = Json::obj(vec![
        ("t0", Json::num(500.0)),
        ("values", Json::arr_f32(&[1.0])),
    ])
    .to_string();
    let gap = client
        .request("POST", "/v1/series/s-conf/observe", Some(&gap_body))
        .unwrap();
    assert_eq!(gap.code, 400, "gapped observe: {}", gap.body);

    // Series routes are /v1-only — the unversioned spelling is NOT a
    // legacy alias (it never existed before the /v1 surface).
    let unversioned = client
        .request("GET", "/series/s-conf/state", None)
        .unwrap();
    assert_eq!(unversioned.code, 404);

    // `POST /v1/forecast` keeps serving the PR-8 contract but is now a
    // deprecated alias of the series spelling: same payload for the
    // same request, successor `Link`, and the alias-hit counter moves.
    let fbody = forecast_body("s-alias");
    let before = deprecated_hits(&mut client);
    let legacy = client
        .request("POST", "/v1/forecast", Some(&fbody))
        .unwrap();
    assert_eq!(legacy.code, 200, "legacy forecast: {}", legacy.body);
    assert_eq!(legacy.header("deprecation"), Some("true"),
               "POST /v1/forecast must be marked deprecated");
    assert_eq!(legacy.header("link"),
               Some("</v1/series/{id}/forecast>; \
                     rel=\"successor-version\""),
               "POST /v1/forecast must link its successor template");
    let successor = client
        .request("POST", "/v1/series/s-alias/forecast", Some(&fbody))
        .unwrap();
    assert_eq!(successor.code, 200, "successor: {}", successor.body);
    assert_eq!(successor.header("deprecation"), None);
    assert_eq!(legacy.body, successor.body,
               "the alias and the series route must serve identical \
                payloads");
    let after = deprecated_hits(&mut client);
    assert!(after >= before + 1.0,
            "alias hit was not counted: {before} -> {after}");
}
