//! Integration test for the backend contract: init → repeated train steps
//! → predict, driven purely through the manifest (no Trainer, no
//! artifacts). Runs on the native backend out of the box; the same flow
//! works unchanged against `PjrtBackend` because both honor the same
//! program/leaf naming.

use fast_esrnn::runtime::{Backend, HostTensor, Manifest, NativeBackend};

/// Synthetic positive series with mild seasonality for smoke runs.
fn toy_batch(b: usize, c: usize, s: usize) -> Vec<f32> {
    let mut y = Vec::with_capacity(b * c);
    for i in 0..b {
        for t in 0..c {
            let seas = if s > 1 {
                1.0 + 0.2 * ((t % s) as f32 / s as f32 - 0.5)
            } else {
                1.0
            };
            let trend = 100.0 + i as f32 * 3.0 + t as f32 * 0.5;
            y.push(trend * seas);
        }
    }
    y
}

fn roundtrip(freq: &str, b: usize) {
    let backend = NativeBackend::new();
    let m = backend.manifest().clone();
    let batches = m.available_batches(freq, "train_step");
    assert!(batches.contains(&b), "no {freq} train_step program for b={b}");
    let cfg = m.config(freq).unwrap().clone();

    // 1. init: PRNG seed -> RNN weights, keyed by leaf name.
    let rnn = backend.execute_init(freq, 42).expect("init");
    assert!(rnn.iter().any(|(n, _)| n.starts_with("rnn.cells.0")));

    // 2. Assemble the full state map the manifest wants. Dual configs
    //    (§8.2 hourly) add `gamma2_logit` and widen the packed block.
    let mut state: std::collections::HashMap<String, HostTensor> =
        rnn.into_iter().map(|(n, t)| (format!("params.{n}"), t)).collect();
    // Per-series params (neutral init) + matching Adam slots.
    let width = cfg.seasonality + cfg.seasonality2;
    let mut series = vec![
        ("alpha_logit", vec![b], vec![-0.5f32; b]),
        ("gamma_logit", vec![b], vec![-1.0f32; b]),
        ("log_s_init", vec![b, width], vec![0.0f32; b * width]),
    ];
    if cfg.seasonality2 > 0 {
        series.push(("gamma2_logit", vec![b], vec![-1.0f32; b]));
    }
    for (name, shape, data) in series {
        state.insert(format!("params.series.{name}"),
                     HostTensor::new(shape.clone(), data).unwrap());
    }
    let param_keys: Vec<String> = state.keys().cloned().collect();
    for k in &param_keys {
        let z = HostTensor::zeros(state[k].shape.clone());
        state.insert(k.replace("params.", "opt.m."), z.clone());
        state.insert(k.replace("params.", "opt.v."), z);
    }
    state.insert("opt.step".into(), HostTensor::scalar(0.0));

    // 3. Batch data.
    let name = Manifest::program_name(freq, b, "train_step");
    let y = HostTensor::new(vec![b, cfg.length],
                            toy_batch(b, cfg.length, cfg.seasonality)).unwrap();
    let mut cat = vec![0.0f32; b * 6];
    for i in 0..b {
        cat[i * 6 + i % 6] = 1.0;
    }
    let cat = HostTensor::new(vec![b, 6], cat).unwrap();
    let mask = HostTensor::new(vec![b], vec![1.0; b]).unwrap();
    let lr = HostTensor::scalar(1e-3);

    // 4. Run a few steps; state outputs feed the next step's inputs.
    let mut losses = Vec::new();
    for _ in 0..5 {
        let outs = backend
            .execute_named(&name, &mut |spec| {
                Ok(match spec.name.as_str() {
                    "data.y" => &y,
                    "data.cat" => &cat,
                    "data.mask" => &mask,
                    "lr" => &lr,
                    other => state
                        .get(other)
                        .unwrap_or_else(|| panic!("missing state `{other}`")),
                })
            })
            .expect("train step");
        let mut loss = f32::NAN;
        for (n, t) in outs {
            if n == "loss" {
                loss = t.data[0];
            } else {
                state.insert(n, t);
            }
        }
        assert!(loss.is_finite(), "loss must be finite");
        losses.push(loss);
    }
    assert!(losses[4] < losses[0],
            "{freq} loss should fall over 5 steps: {losses:?}");
    // The step counter advanced with the optimizer.
    assert_eq!(state["opt.step"].data[0], 5.0);

    // 5. Forecasts come out positive and finite.
    let pname = Manifest::program_name(freq, b, "predict");
    let outs = backend
        .execute_named(&pname, &mut |spec| {
            Ok(match spec.name.as_str() {
                "data.y" => &y,
                "data.cat" => &cat,
                other => state
                    .get(other)
                    .unwrap_or_else(|| panic!("missing state `{other}`")),
            })
        })
        .expect("predict");
    assert_eq!(outs.len(), 1);
    let fc = &outs[0].1;
    assert_eq!(fc.shape, vec![b, cfg.horizon]);
    assert!(fc.data.iter().all(|v| v.is_finite() && *v > 0.0),
            "forecasts must be positive/finite");
}

#[test]
fn yearly_init_then_train_steps_reduce_loss() {
    roundtrip("yearly", 16);
}

#[test]
fn quarterly_roundtrip_small_batch() {
    roundtrip("quarterly", 8);
}

#[test]
fn hourly_roundtrip_dual_seasonality() {
    // §8.2: the full init → train → predict contract over the native
    // hourly dual program, driven purely through manifest names.
    roundtrip("hourly", 4);
}

#[test]
fn shape_mismatch_is_rejected() {
    let backend = NativeBackend::new();
    let bad = HostTensor::new(vec![2, 3], vec![0.0; 6]).unwrap();
    let err = backend.execute_named("yearly_b1_predict", &mut |_| Ok(&bad));
    assert!(err.is_err(), "wrong-shaped input must be rejected");
}

#[test]
fn unknown_program_is_rejected() {
    // weekly has no ES-RNN network at all (§8.5 future work), so its
    // programs are absent from every manifest.
    let backend = NativeBackend::new();
    let t = HostTensor::scalar(0.0);
    assert!(backend.execute_named("weekly_b4_train_step", &mut |_| Ok(&t)).is_err());
    assert!(backend.execute_named("nope", &mut |_| Ok(&t)).is_err());
}
