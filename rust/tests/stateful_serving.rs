//! Stateful-serving suite — the contract under test:
//!
//! * **Observe ≡ refilter.** Feeding a series to the service in
//!   several `observe` batches and asking for its stateful forecast
//!   gives the same answer as running the batch ES filter over the
//!   full concatenated history with the same seed rings — to 1e-4,
//!   for the single-seasonality path, the §8.2 hourly dual path, and
//!   the lane-vectorized kernels (three independent derivations of
//!   one number).
//! * **Crash safety.** A writer killed mid-append leaves a torn slab
//!   tail; reopening truncates the tear, loses nothing older, and the
//!   recovered state keeps advancing exactly like an uninterrupted
//!   one.
//! * **Exact accounting under sharding.** Interleaved observes and
//!   forecasts across a 2-shard ring are each counted on exactly one
//!   shard: per-shard `observe_requests` sum to the number issued,
//!   stale rejections are typed and tallied, and R = 2 replica
//!   fan-outs are accounted asynchronously without double-counting
//!   the synchronous primary.

use std::fs;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use fast_esrnn::config::Frequency;
use fast_esrnn::coordinator::ModelState;
use fast_esrnn::forecast::api::{ObservationGap, StaleObservation,
                                UnknownSeries};
use fast_esrnn::forecast::{ServiceOptions, ServingStack, ShardedStack};
use fast_esrnn::hw;
use fast_esrnn::runtime::NativeBackend;
use fast_esrnn::simd::{Lanes, LANES};

const FREQ: Frequency = Frequency::Quarterly;
const S1: usize = 4;
const HORIZON: usize = 8;

/// Positive quarterly series: trend × planted seasonal pattern.
fn qgen(t: usize) -> f32 {
    let pattern = [0.8f32, 1.1, 1.25, 0.9];
    (100.0 + 0.5 * t as f32) * pattern[t % 4]
}

/// Positive hourly series with both a daily and a weekly cycle (§8.2).
fn hgen(t: usize) -> f32 {
    let day = (t % 24) as f32 / 24.0;
    let week = (t % 168) as f32 / 168.0;
    (50.0 + 0.05 * t as f32)
        * (1.0 + 0.3 * (std::f32::consts::TAU * day).sin())
        * (1.0 + 0.1 * (std::f32::consts::TAU * week).sin())
}

fn fresh_state(freq: Frequency) -> ModelState {
    let backend = NativeBackend::new();
    ModelState::init(&backend, freq.name(), 42).unwrap()
}

fn single_stack(freq: Frequency, state_dir: Option<PathBuf>)
                -> ServingStack {
    let mut stack = ServingStack::new();
    stack
        .start_pool_native(freq, fresh_state(freq), ServiceOptions {
            workers: 1,
            queue_limit: 64,
            state_dir,
            ..Default::default()
        })
        .unwrap();
    stack
}

/// 1e-4 agreement per the acceptance contract (relative above 1.0).
fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-4 * w.abs().max(1.0);
        assert!((g - w).abs() <= tol,
                "{what}[{i}]: got {g}, want {w} (tol {tol})");
    }
}

/// The lane-vectorized oracle: marshal the series into lane 0 of the
/// SoA layout (remaining lanes padded with 1.0), run the lanes kernel,
/// and read the Holt-Winters forecast back out of lane 0.
fn lanes_forecast_single(full: &[f32], rings: &[f32], horizon: usize)
                         -> Vec<f32> {
    let c = full.len();
    let s = rings.len();
    let mut y = vec![1.0f32; c * LANES];
    for (t, &v) in full.iter().enumerate() {
        y[t * LANES] = v;
    }
    let mut s_init = vec![1.0f32; s * LANES];
    for (p, &r) in rings.iter().enumerate() {
        s_init[p * LANES] = r;
    }
    let (levels, seas) = hw::es_filter_lanes(
        &y, c, Lanes::splat(hw::INIT_ALPHA), Lanes::splat(hw::INIT_GAMMA),
        &s_init, s);
    let l = levels[(c - 1) * LANES];
    (0..horizon).map(|h| l * seas[(c + h % s) * LANES]).collect()
}

/// Dual-seasonality variant of [`lanes_forecast_single`].
fn lanes_forecast_dual(full: &[f32], idx1: &[f32], idx2: &[f32],
                       horizon: usize) -> Vec<f32> {
    let c = full.len();
    let (s1, s2) = (idx1.len(), idx2.len());
    let mut y = vec![1.0f32; c * LANES];
    for (t, &v) in full.iter().enumerate() {
        y[t * LANES] = v;
    }
    let mut i1 = vec![1.0f32; s1 * LANES];
    for (p, &r) in idx1.iter().enumerate() {
        i1[p * LANES] = r;
    }
    let mut i2 = vec![1.0f32; s2 * LANES];
    for (p, &r) in idx2.iter().enumerate() {
        i2[p * LANES] = r;
    }
    let (levels, seas1, seas2) = hw::es_dual_filter_lanes(
        &y, c, Lanes::splat(hw::INIT_ALPHA), Lanes::splat(hw::INIT_GAMMA),
        Lanes::splat(hw::INIT_GAMMA), &i1, s1, &i2, s2);
    let l = levels[(c - 1) * LANES];
    (0..horizon)
        .map(|h| {
            l * seas1[(c + h % s1) * LANES] * seas2[(c + h % s2) * LANES]
        })
        .collect()
}

#[test]
fn observe_then_forecast_matches_the_extended_history_oracle() {
    let stack = single_stack(FREQ, None);
    let id = "Q-oracle";
    let batch1: Vec<f32> = (0..48).map(qgen).collect();
    let batch2: Vec<f32> = (48..68).map(qgen).collect();
    let batch3: Vec<f32> = (68..77).map(qgen).collect();

    let o1 = stack.observe(FREQ, id, &batch1, Some(0)).unwrap();
    assert!(o1.new_series);
    assert_eq!(o1.observed, 48);
    let o2 = stack.observe(FREQ, id, &batch2, Some(48)).unwrap();
    assert!(!o2.new_series);
    assert_eq!(o2.observed, 68);
    // t0 is optional: an untagged batch appends at the current tip.
    let o3 = stack.observe(FREQ, id, &batch3, None).unwrap();
    assert_eq!(o3.observed, 77);

    // Scalar oracle: the seed rings come from the *first* batch (the
    // service never sees the later batches at seed time), then the
    // batch filter runs over the full concatenated history.
    let full: Vec<f32> = (0..77).map(qgen).collect();
    let rings = hw::seasonal_indices(&batch1, S1);
    let out = hw::es_filter(&full, hw::INIT_ALPHA, hw::INIT_GAMMA, &rings);
    let oracle = hw::es_forecast(&out, S1, HORIZON);

    let served = stack.series_forecast(FREQ, id).unwrap();
    assert_eq!(served.forecast.len(), HORIZON);
    assert_close(&served.forecast, &oracle,
                 "stateful forecast vs extended-history oracle");

    // Lane-vectorized oracle: same numbers out of the SIMD kernel.
    let lanes_fc = lanes_forecast_single(&full, &rings, HORIZON);
    assert_close(&served.forecast, &lanes_fc,
                 "stateful forecast vs lane-vectorized oracle");

    // The state route exposes exactly the record the forecast used.
    let rec = stack.series_record(FREQ, id).unwrap();
    assert_eq!(rec.state.observed, 77);
    assert_eq!(rec.state.ring1.len(), S1);
    assert!(rec.state.ring2.is_empty());
    assert_eq!(rec.generation, served.generation);
    assert_eq!(rec.state.forecast(HORIZON), served.forecast);

    // The t0 write guard is typed: a rewound batch is stale (409), a
    // batch past the tip is a gap (400), an unseen id is unknown (404).
    let stale = stack.observe(FREQ, id, &[qgen(5)], Some(5)).unwrap_err();
    assert!(stale.is::<StaleObservation>(), "want StaleObservation: {stale:#}");
    let gap = stack.observe(FREQ, id, &[qgen(200)], Some(200)).unwrap_err();
    assert!(gap.is::<ObservationGap>(), "want ObservationGap: {gap:#}");
    let unknown = stack.series_forecast(FREQ, "never-observed").unwrap_err();
    assert!(unknown.is::<UnknownSeries>(), "want UnknownSeries: {unknown:#}");

    // Repeat read is served from the cache; the counters agree with
    // everything this test just did.
    let again = stack.series_forecast(FREQ, id).unwrap();
    assert_eq!(again.forecast, served.forecast);
    let stats = stack.stats(FREQ).unwrap();
    assert_eq!(stats.observe_requests, 5); // 3 applied + stale + gap
    assert_eq!(stats.observe_new_series, 1);
    assert_eq!(stats.observe_stale, 1);
    assert_eq!(stats.state_series, 1);
    assert!(stats.state_cache_hits >= 1, "repeat read missed the cache");
}

#[test]
fn hourly_dual_observe_matches_the_dual_filter_and_lanes_oracles() {
    const S1H: usize = 24;
    const S2H: usize = 168;
    const H: usize = 48;
    let stack = single_stack(Frequency::Hourly, None);
    let id = "H-oracle";
    let batch1: Vec<f32> = (0..400).map(hgen).collect();
    let batch2: Vec<f32> = (400..500).map(hgen).collect();

    stack.observe(Frequency::Hourly, id, &batch1, None).unwrap();
    let o = stack.observe(Frequency::Hourly, id, &batch2, Some(400))
                 .unwrap();
    assert_eq!(o.observed, 500);

    // Dual-seasonality oracle, seeded exactly like the service: the
    // primary cycle is decomposed first, then the residual.
    let full: Vec<f32> = (0..500).map(hgen).collect();
    let idx1 = hw::seasonal_indices(&batch1, S1H);
    let residual: Vec<f32> = batch1
        .iter()
        .enumerate()
        .map(|(t, v)| v / idx1[t % S1H].max(1e-6))
        .collect();
    let idx2 = hw::seasonal_indices(&residual, S2H);
    let (levels, seas1, seas2) = hw::es_dual_filter(
        &full, hw::INIT_ALPHA, hw::INIT_GAMMA, hw::INIT_GAMMA, &idx1,
        &idx2);
    let c = levels.len();
    let l = levels[c - 1];
    let oracle: Vec<f32> = (0..H)
        .map(|h| l * seas1[c + h % S1H] * seas2[c + h % S2H])
        .collect();

    let served = stack.series_forecast(Frequency::Hourly, id).unwrap();
    assert_eq!(served.forecast.len(), H);
    assert_close(&served.forecast, &oracle,
                 "hourly dual stateful forecast vs dual-filter oracle");

    let lanes_fc = lanes_forecast_dual(&full, &idx1, &idx2, H);
    assert_close(&served.forecast, &lanes_fc,
                 "hourly dual stateful forecast vs lane-vectorized oracle");

    let rec = stack.series_record(Frequency::Hourly, id).unwrap();
    assert_eq!(rec.state.observed, 500);
    assert_eq!(rec.state.ring1.len(), S1H);
    assert_eq!(rec.state.ring2.len(), S2H);
}

#[test]
fn state_survives_a_kill_mid_write_and_a_process_restart() {
    let dir = std::env::temp_dir()
        .join(format!("fesrnn-stateful-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    let id = "Q-durable";
    let batch1: Vec<f32> = (0..48).map(qgen).collect();
    let batch2: Vec<f32> = (48..60).map(qgen).collect();

    let first = {
        let stack = single_stack(FREQ, Some(dir.clone()));
        stack.observe(FREQ, id, &batch1, None).unwrap();
        stack.observe(FREQ, id, &batch2, None).unwrap();
        stack.series_forecast(FREQ, id).unwrap().forecast
    }; // the stack drop is the process going away

    // A writer killed mid-append leaves a torn half-record at the tail.
    let slab = dir.join(FREQ.name()).join("state.slab");
    assert!(slab.exists(),
            "durable slab missing at {}", slab.display());
    let mut bytes = fs::read(&slab).unwrap();
    bytes.extend_from_slice(&[0xEE; 17]);
    fs::write(&slab, &bytes).unwrap();

    // Restart: the tear is truncated, the intact state is bit-exact.
    let stack = single_stack(FREQ, Some(dir.clone()));
    let rec = stack.series_record(FREQ, id).unwrap();
    assert_eq!(rec.state.observed, 60);
    assert_eq!(stack.series_forecast(FREQ, id).unwrap().forecast, first,
               "recovered forecast drifted from the pre-crash one");

    // The recovered state advances exactly like an uninterrupted one:
    // the t0 guard proves the tip survived, the oracle proves the
    // rings did.
    let batch3: Vec<f32> = (60..70).map(qgen).collect();
    stack.observe(FREQ, id, &batch3, Some(60)).unwrap();
    let full: Vec<f32> = (0..70).map(qgen).collect();
    let rings = hw::seasonal_indices(&batch1, S1);
    let out = hw::es_filter(&full, hw::INIT_ALPHA, hw::INIT_GAMMA, &rings);
    let oracle = hw::es_forecast(&out, S1, HORIZON);
    assert_close(&stack.series_forecast(FREQ, id).unwrap().forecast,
                 &oracle, "post-recovery forecast vs oracle");
    drop(stack);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn two_shard_interleaving_keeps_exact_accounting() {
    let sharded = ShardedStack::new();
    sharded.add_shard("a", single_stack(FREQ, None)).unwrap();
    sharded.add_shard("b", single_stack(FREQ, None)).unwrap();

    const SERIES: usize = 60;
    const ROUNDS: usize = 3;
    let ids: Vec<String> =
        (0..SERIES).map(|i| format!("acct-{i}")).collect();
    let mut mirrors: Vec<hw::EsState> = Vec::new();

    // Seed every series, keeping a scalar mirror of the expected state.
    for id in &ids {
        let batch: Vec<f32> = (0..16).map(qgen).collect();
        let o = sharded.observe(FREQ, id, &batch, Some(0)).unwrap();
        assert!(o.new_series);
        mirrors.push(hw::es_state_seed(&batch, S1, 0));
    }

    // Interleave observes and forecasts across both shards; every
    // forecast must match the scalar mirror no matter which shard the
    // id hashed to.
    for round in 0..ROUNDS {
        for (i, id) in ids.iter().enumerate() {
            let t = 16 + round * 4;
            let batch: Vec<f32> =
                (t..t + 4).map(|u| qgen(u + i)).collect();
            let o = sharded.observe(FREQ, id, &batch, Some(t as u64))
                           .unwrap();
            assert!(!o.new_series);
            assert_eq!(o.observed, (t + 4) as u64);
            mirrors[i].advance(&batch, hw::INIT_ALPHA, hw::INIT_GAMMA,
                               hw::INIT_GAMMA);
            let served = sharded.series_forecast(FREQ, id).unwrap();
            assert_close(&served.forecast, &mirrors[i].forecast(HORIZON),
                         "sharded stateful forecast vs scalar mirror");
        }
    }

    // Rewound batches are refused with the typed 409 — and tallied.
    for id in ids.iter().take(10) {
        let err = sharded.observe(FREQ, id, &[qgen(1)], Some(3))
                         .unwrap_err();
        assert!(err.is::<StaleObservation>(),
                "want StaleObservation: {err:#}");
    }
    let err = sharded.series_forecast(FREQ, "acct-missing").unwrap_err();
    assert!(err.is::<UnknownSeries>(), "want UnknownSeries: {err:#}");

    // Exact accounting: every observe issued landed on exactly one
    // shard — the per-shard counters sum to the number issued, with
    // no fan-out inflation at R = 1.
    let issued = (SERIES * (1 + ROUNDS) + 10) as u64;
    let per_shard = sharded.shard_stats();
    assert_eq!(per_shard.len(), 2);
    let mut sum = 0u64;
    for (label, by_freq) in &per_shard {
        let st = by_freq.get(&FREQ).unwrap();
        assert!(st.observe_requests > 0,
                "shard `{label}` saw no observes — the ring is not \
                 spreading keys");
        sum += st.observe_requests;
    }
    assert_eq!(sum, issued);
    let agg = sharded.stats(FREQ).unwrap();
    assert_eq!(agg.observe_requests, issued);
    assert_eq!(agg.observe_new_series, SERIES as u64);
    assert_eq!(agg.observe_stale, 10);
    assert_eq!(agg.state_series, SERIES as u64);
    assert_eq!(sharded.observe_fanouts(), 0,
               "R = 1 must not fan out observes");

    // R = 2: the primary applies synchronously (counted above the
    // ring), the replica asynchronously — both eventually appear in
    // the pool counters, and the fan-out counter is exact.
    sharded.set_replicas(2);
    for i in 0..5 {
        let id = format!("fan-{i}");
        let batch: Vec<f32> = (0..8).map(qgen).collect();
        sharded.observe(FREQ, &id, &batch, None).unwrap();
    }
    assert_eq!(sharded.observe_fanouts(), 5);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let agg = sharded.stats(FREQ).unwrap();
        if agg.observe_requests == issued + 10 {
            break;
        }
        assert!(Instant::now() < deadline,
                "async observe fan-outs never landed: {} of {} observes \
                 accounted", agg.observe_requests, issued + 10);
        thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(sharded.observe_fanout_errors(), 0,
               "local replica fan-outs must not fail");
}
