//! End-to-end integration tests over the full three-layer stack:
//! corpus → primer → train step → evaluation → checkpoint → serving.
//!
//! Everything here runs on the pure-Rust [`NativeBackend`] — no
//! artifacts, no XLA, stock `cargo test` — including the §8.2 hourly
//! dual-seasonality (24h×168h) model. Only the §8.4 penalty variants
//! remain PJRT-artifact-only (exercised by the feature-gated module
//! below when artifacts are present).

use fast_esrnn::config::{Frequency, TrainConfig};
use fast_esrnn::coordinator::{checkpoint, EvalSplit, Trainer};
use fast_esrnn::data::{generate, GenOptions};
use fast_esrnn::forecast::{ForecastRequest, ForecastService, ServiceOptions};
use fast_esrnn::runtime::{Backend, NativeBackend};

fn tiny_config(epochs: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        batch_size: 16,
        learning_rate: 1e-3,
        patience: 50, // no early stop in smoke runs
        ..Default::default()
    }
}

#[test]
fn quarterly_train_loss_falls_and_eval_is_sane() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 400, ..Default::default() }).unwrap();
    let mut trainer =
        Trainer::new(&backend, Frequency::Quarterly, &corpus, tiny_config(4))
            .unwrap();
    let report = trainer.train(false).unwrap();
    assert_eq!(report.epochs_run, 4);
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    assert!(report.epoch_losses[3] < report.epoch_losses[0],
            "loss should fall: {:?}", report.epoch_losses);

    let val = trainer.evaluate(EvalSplit::Validation).unwrap();
    let test = trainer.evaluate(EvalSplit::Test).unwrap();
    for r in [&val, &test] {
        assert!(r.smape.is_finite() && r.smape > 0.0 && r.smape < 200.0);
        assert!(r.mase.is_finite() && r.mase > 0.0);
        assert_eq!(r.count, trainer.series_count());
    }
    // Every forecast positive & finite.
    let fcs = trainer.forecasts(true).unwrap();
    assert_eq!(fcs.len(), trainer.series_count());
    for fc in &fcs {
        assert_eq!(fc.len(), 8);
        assert!(fc.iter().all(|v| v.is_finite() && *v > 0.0));
    }
}

#[test]
fn yearly_nonseasonal_path_trains() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() }).unwrap();
    let mut trainer =
        Trainer::new(&backend, Frequency::Yearly, &corpus, tiny_config(2))
            .unwrap();
    let report = trainer.train(false).unwrap();
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    let test = trainer.evaluate(EvalSplit::Test).unwrap();
    assert!(test.smape.is_finite());
    // Yearly is non-seasonal: trained gamma/s_init must remain at the
    // primer values (gradient is structurally zero through the ES layer;
    // Adam gets exactly-zero grads, so the update is 0/(0+eps) = 0).
    let (_, g0, s0) = trainer.store.series_params(0);
    assert!((g0 - fast_esrnn::hw::primer(&[1.0; 36], 1).gamma_logit).abs() < 0.2,
            "gamma_logit moved on non-seasonal data: {g0}");
    assert_eq!(s0.len(), 1);
}

#[test]
fn monthly_smoke() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 800, ..Default::default() }).unwrap();
    let mut trainer =
        Trainer::new(&backend, Frequency::Monthly, &corpus, tiny_config(1))
            .unwrap();
    let report = trainer.train(false).unwrap();
    assert!(report.epoch_losses[0].is_finite());
    let fcs = trainer.forecasts(false).unwrap();
    assert!(fcs.iter().all(|fc| fc.len() == 18
                           && fc.iter().all(|v| v.is_finite() && *v > 0.0)));
}

#[test]
fn checkpoint_roundtrip_preserves_forecasts() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 400, ..Default::default() }).unwrap();
    let mut t1 =
        Trainer::new(&backend, Frequency::Quarterly, &corpus, tiny_config(2))
            .unwrap();
    t1.train(false).unwrap();
    let before = t1.forecasts(true).unwrap();

    let tmp = std::env::temp_dir().join("fast_esrnn_pipeline_ckpt.json");
    checkpoint::save(&tmp, "quarterly", &t1.state, &t1.store).unwrap();

    let mut t2 =
        Trainer::new(&backend, Frequency::Quarterly, &corpus, tiny_config(2))
            .unwrap();
    let freq = checkpoint::load(&tmp, &mut t2.state, &mut t2.store).unwrap();
    assert_eq!(freq, "quarterly");
    let after = t2.forecasts(true).unwrap();
    assert_eq!(before.len(), after.len());
    for (a, b) in before.iter().zip(&after) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0),
                    "forecast drifted after checkpoint reload: {x} vs {y}");
        }
    }
}

#[test]
fn trained_model_beats_untrained_on_validation() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 300, ..Default::default() }).unwrap();
    let mut trainer =
        Trainer::new(&backend, Frequency::Quarterly, &corpus, tiny_config(6))
            .unwrap();
    let before = trainer.evaluate(EvalSplit::Validation).unwrap().smape;
    trainer.train(false).unwrap();
    let after = trainer.evaluate(EvalSplit::Validation).unwrap().smape;
    assert!(after < before,
            "training should improve val sMAPE: {before:.3} -> {after:.3}");
}

#[test]
fn forecast_service_serves_batched_requests() {
    let state = {
        let backend = NativeBackend::new();
        let corpus = generate(&GenOptions { scale: 400, ..Default::default() }).unwrap();
        let mut trainer = Trainer::new(&backend, Frequency::Quarterly, &corpus,
                                       tiny_config(1)).unwrap();
        trainer.train(false).unwrap();
        trainer.state.clone()
    };
    let service = ForecastService::start_native(
        Frequency::Quarterly, state,
        ServiceOptions { max_batch: 16, ..Default::default() }).unwrap();

    let corpus = generate(&GenOptions { scale: 300, seed: 9,
                                        freqs: Some(vec![Frequency::Quarterly]) })
        .unwrap();
    let mut rxs = Vec::new();
    let mut sent = 0;
    for s in &corpus.series {
        if s.len() < 72 || sent >= 40 {
            continue;
        }
        rxs.push(service.handle.submit(ForecastRequest {
            id: s.id.clone(),
            values: s.values.clone(),
            category: s.category,
        }).unwrap());
        sent += 1;
    }
    assert!(sent >= 10, "need enough demo series, got {sent}");
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.forecast.len(), 8);
        assert!(resp.forecast.iter().all(|v| v.is_finite() && *v > 0.0));
    }
    let st = service.handle.stats().unwrap();
    assert_eq!(st.requests, sent as u64);
    assert!(st.batches >= 1);

    // Too-short request is rejected, not crashed.
    let err = service.handle.forecast(ForecastRequest {
        id: "short".into(),
        values: vec![1.0; 10],
        category: fast_esrnn::config::Category::Other,
    });
    assert!(err.is_err());
}

#[test]
fn es_program_matches_rust_filter() {
    // Cross-layer numeric pin: the backend's ES program must agree with
    // the pure-Rust Holt-Winters mirror to float tolerance (the same
    // check the PJRT artifacts get from `make artifacts` + this test
    // under `--features pjrt`).
    let backend = NativeBackend::new();
    let m = backend.manifest().clone();
    for freq in ["quarterly", "monthly", "yearly", "daily"] {
        let name = format!("{freq}_b8_es");
        let cfg = m.config(freq).unwrap().clone();
        let (b, c, s) = (8usize, cfg.length, cfg.seasonality);
        let mut rng = fast_esrnn::util::rng::Rng::new(33);
        let mut y = Vec::with_capacity(b * c);
        let mut alpha_logit = Vec::new();
        let mut gamma_logit = Vec::new();
        let mut log_s_init = Vec::new();
        for _ in 0..b {
            y.extend(fast_esrnn::util::prop::gen_positive_series(&mut rng, c, s));
            alpha_logit.push(rng.uniform(-2.0, 2.0) as f32);
            gamma_logit.push(rng.uniform(-3.0, 0.0) as f32);
            for _ in 0..s {
                log_s_init.push(rng.uniform(-0.3, 0.3) as f32);
            }
        }
        use fast_esrnn::runtime::HostTensor;
        let inputs = std::collections::HashMap::from([
            ("data.y".to_string(),
             HostTensor::new(vec![b, c], y.clone()).unwrap()),
            ("data.alpha_logit".to_string(),
             HostTensor::new(vec![b], alpha_logit.clone()).unwrap()),
            ("data.gamma_logit".to_string(),
             HostTensor::new(vec![b], gamma_logit.clone()).unwrap()),
            ("data.log_s_init".to_string(),
             HostTensor::new(vec![b, s], log_s_init.clone()).unwrap()),
        ]);
        let outs = backend.execute_named(&name, &mut |spec| {
            inputs.get(&spec.name)
                .ok_or_else(|| anyhow::anyhow!("missing {}", spec.name))
        }).unwrap();
        let levels = &outs[0].1;
        let seas = &outs[1].1;
        for i in 0..b {
            let alpha = fast_esrnn::hw::sigmoid(alpha_logit[i]);
            let (gamma, s_init): (f32, Vec<f32>) = if s > 1 {
                (fast_esrnn::hw::sigmoid(gamma_logit[i]),
                 log_s_init[i * s..(i + 1) * s].iter().map(|v| v.exp()).collect())
            } else {
                (0.0, vec![1.0])
            };
            let mirror = fast_esrnn::hw::es_filter(
                &y[i * c..(i + 1) * c], alpha, gamma, &s_init);
            for t in 0..c {
                let a = levels.data[i * c + t];
                let r = mirror.levels[t];
                assert!((a - r).abs() <= 1e-3 * r.abs().max(1.0),
                        "{freq} series {i} level[{t}]: backend {a} vs rust {r}");
            }
            for t in 0..c + s {
                let a = seas.data[i * (c + s) + t];
                let r = mirror.seas[t];
                assert!((a - r).abs() <= 1e-3 * r.abs().max(1.0),
                        "{freq} series {i} seas[{t}]: backend {a} vs rust {r}");
            }
        }
    }
}

#[test]
fn daily_extension_trains() {
    // §8.5: daily (quarterly-structured network, S = 7).
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 200, ..Default::default() }).unwrap();
    let tc = TrainConfig { epochs: 1, batch_size: 16, patience: 50,
                           ..Default::default() };
    let mut trainer =
        Trainer::new(&backend, fast_esrnn::config::Frequency::Daily, &corpus,
                     tc).unwrap();
    let report = trainer.train(false).unwrap();
    assert!(report.epoch_losses[0].is_finite());
    let fcs = trainer.forecasts(true).unwrap();
    assert!(fcs.iter().all(|fc| fc.len() == 14
                           && fc.iter().all(|v| v.is_finite() && *v > 0.0)));
}

#[test]
fn hourly_dual_seasonality_trains_natively() {
    // §8.2: the hourly 24h×168h dual-seasonality model now runs on the
    // pure-Rust backend end-to-end — primer (dual decomposition) →
    // train_step (coupled ES backward, gamma2 + packed [24|168] leaves)
    // → evaluation → refit forecasts — with no `--features pjrt`.
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 100, ..Default::default() }).unwrap();
    let tc = TrainConfig { epochs: 2, batch_size: 4, patience: 50,
                           ..Default::default() };
    let mut trainer =
        Trainer::new(&backend, Frequency::Hourly, &corpus, tc).unwrap();
    assert!(trainer.series_count() >= 2);
    // 192-wide packed seasonality + gamma2 present in the store.
    let (_, _, s) = trainer.store.series_params(0);
    assert_eq!(s.len(), 192);

    let report = trainer.train(false).unwrap();
    assert_eq!(report.epochs_run, 2);
    assert!(report.epoch_losses.iter().all(|l| l.is_finite()));

    let val = trainer.evaluate(EvalSplit::Validation).unwrap();
    let test = trainer.evaluate(EvalSplit::Test).unwrap();
    for r in [&val, &test] {
        assert!(r.smape.is_finite() && r.smape > 0.0 && r.smape < 200.0);
        assert!(r.mase.is_finite() && r.mase > 0.0);
        assert_eq!(r.count, trainer.series_count());
    }
    // Refit forecasts (phase-rotated per seasonal component: the H = 48
    // shift is 0 mod 24 but 48 mod 168) are positive and finite.
    let fcs = trainer.forecasts(true).unwrap();
    assert_eq!(fcs.len(), trainer.series_count());
    for fc in &fcs {
        assert_eq!(fc.len(), 48);
        assert!(fc.iter().all(|v| v.is_finite() && *v > 0.0));
    }
}

#[test]
fn backend_stats_accumulate() {
    let backend = NativeBackend::new();
    let corpus = generate(&GenOptions { scale: 800, ..Default::default() }).unwrap();
    let mut trainer =
        Trainer::new(&backend, Frequency::Quarterly, &corpus, tiny_config(1))
            .unwrap();
    trainer.train(false).unwrap();
    let st = backend.stats();
    assert!(st.executions > 0);
    assert!(st.execute_secs > 0.0);
    assert_eq!(st.compiles, 0, "native backend never compiles");
}

/// PJRT-artifact-only flows (§8.2 hourly dual seasonality, §8.4 penalty
/// variants). These need `--features pjrt` *and* a built `artifacts/`
/// dir (`make artifacts`); they skip gracefully when artifacts are
/// absent, exactly like the pre-refactor suite.
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use fast_esrnn::runtime::PjrtBackend;

    fn artifacts_backend() -> Option<PjrtBackend> {
        let dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return None;
        }
        match PjrtBackend::load(&dir) {
            Ok(b) => Some(b),
            Err(e) => {
                // Stubbed xla bindings: compile coverage only.
                eprintln!("skipping: PJRT backend unavailable ({e:#})");
                None
            }
        }
    }

    #[test]
    fn hourly_dual_seasonality_trains() {
        let Some(backend) = artifacts_backend() else { return };
        let corpus = generate(&GenOptions { scale: 100, ..Default::default() }).unwrap();
        let tc = TrainConfig { epochs: 2, batch_size: 4, patience: 50,
                               ..Default::default() };
        let mut trainer =
            Trainer::new(&backend, Frequency::Hourly, &corpus, tc).unwrap();
        assert!(trainer.series_count() >= 2);
        // 192-wide packed seasonality + gamma2 present in the store.
        let (_, _, s) = trainer.store.series_params(0);
        assert_eq!(s.len(), 192);
        let report = trainer.train(false).unwrap();
        assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
        let test = trainer.evaluate(EvalSplit::Test).unwrap();
        assert!(test.smape.is_finite() && test.smape < 200.0);
    }

    #[test]
    fn penalties_variant_trains_via_model_key() {
        let Some(backend) = artifacts_backend() else { return };
        let corpus = generate(&GenOptions { scale: 400, ..Default::default() }).unwrap();
        let tc = TrainConfig {
            model_key: Some("quarterly_pen".into()),
            epochs: 2,
            batch_size: 64,
            patience: 50,
            ..Default::default()
        };
        let mut trainer =
            Trainer::new(&backend, Frequency::Quarterly, &corpus, tc).unwrap();
        let report = trainer.train(false).unwrap();
        assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
        let val = trainer.evaluate(EvalSplit::Validation).unwrap();
        assert!(val.smape.is_finite());
    }
}
