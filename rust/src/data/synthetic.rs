//! Synthetic M4-like corpus generator (DESIGN.md §Substitutions).
//!
//! The M4 dataset is not redistributable into this offline environment, so
//! we generate a corpus whose *structure* matches what the paper's code
//! paths care about:
//!
//! * counts by frequency × category scaled from Table 2 (default 1/100);
//! * variable series lengths whose distribution tracks Table 3 (so the
//!   §5.2 equalization genuinely discards short series);
//! * strictly positive values with multiplicative seasonality, damped
//!   trend, category-specific noise/structure (so per-series Holt-Winters
//!   parameters have something real to learn and the Table 6 category
//!   breakdown is meaningful);
//! * fully deterministic given a seed.

use anyhow::{anyhow, Result};

use crate::config::{Category, Frequency, ALL_CATEGORIES};
use crate::data::types::{Corpus, Series};
use crate::util::rng::Rng;

/// Paper Table 2: series counts by frequency × category
/// (Demographic, Finance, Industry, Macro, Micro, Other).
pub const TABLE2_COUNTS: [(Frequency, [usize; 6]); 6] = [
    (Frequency::Yearly, [1_088, 6_519, 3_716, 3_903, 6_538, 1_236]),
    (Frequency::Quarterly, [1_858, 5_305, 4_637, 5_315, 6_020, 865]),
    (Frequency::Monthly, [5_728, 10_987, 10_017, 10_016, 10_975, 277]),
    (Frequency::Weekly, [24, 164, 6, 41, 112, 12]),
    (Frequency::Daily, [10, 1_559, 422, 127, 1_476, 633]),
    (Frequency::Hourly, [0, 0, 0, 0, 0, 414]),
];

/// Paper Table 3: per-frequency length statistics (mean, std, min, max).
/// Used to sample realistic series lengths.
pub const TABLE3_LENGTHS: [(Frequency, f64, f64, usize, usize); 6] = [
    (Frequency::Yearly, 25.0, 24.0, 7, 829),
    (Frequency::Quarterly, 84.0, 51.0, 8, 858),
    (Frequency::Monthly, 198.0, 137.0, 24, 2_776),
    (Frequency::Weekly, 1_009.0, 707.0, 67, 2_584),
    (Frequency::Daily, 2_343.0, 1_756.0, 79, 9_905),
    (Frequency::Hourly, 805.0, 127.0, 652, 912),
];

/// Generator options.
#[derive(Debug, Clone)]
pub struct GenOptions {
    /// Divide Table 2 counts by this (ceil, min 1 where nonzero).
    pub scale: usize,
    pub seed: u64,
    /// Restrict to these frequencies (None = all six).
    pub freqs: Option<Vec<Frequency>>,
}

impl Default for GenOptions {
    fn default() -> Self {
        Self { scale: 100, seed: 20190603, freqs: None }
    }
}

/// Table 3 length statistics for `freq` — a descriptive error (instead
/// of a panic) when the frequency has no row, so a [`TABLE2_COUNTS`] /
/// [`TABLE3_LENGTHS`] drift (e.g. a new `Frequency` variant added to one
/// table only) surfaces as a diagnosable failure.
fn length_params(freq: Frequency) -> Result<(f64, f64, usize, usize)> {
    TABLE3_LENGTHS
        .iter()
        .find(|r| r.0 == freq)
        .map(|row| (row.1, row.2, row.3, row.4))
        .ok_or_else(|| anyhow!("no Table 3 length statistics for frequency \
                                `{}` — add a TABLE3_LENGTHS row for it",
                               freq.name()))
}

/// Sample a series length approximating the Table 3 distribution
/// (lognormal matched to mean/std, clamped to [min, max]).
fn sample_length(rng: &mut Rng, freq: Frequency) -> Result<usize> {
    let (mean, std, min, max) = length_params(freq)?;
    // Lognormal moment matching: if X ~ LN(mu, s), E=exp(mu+s²/2),
    // Var=(exp(s²)-1)E².
    let cv2 = (std / mean).powi(2);
    let s2 = (1.0 + cv2).ln();
    let mu = mean.ln() - s2 / 2.0;
    let x = (mu + s2.sqrt() * rng.normal()).exp();
    Ok((x.round() as usize).clamp(min, max))
}

/// Category-specific structure. Tuned so categories *differ*: this is what
/// makes the Table 6 per-category sMAPE breakdown non-degenerate.
struct CatProfile {
    seas_amp: (f64, f64),   // multiplicative seasonal amplitude range
    trend: (f64, f64),      // per-step growth rate range
    noise: (f64, f64),      // relative noise sigma range
    walk: f64,              // random-walk (geometric) weight
    shock_prob: f64,        // chance of level shifts / promotions
}

fn profile(cat: Category) -> CatProfile {
    match cat {
        Category::Demographic => CatProfile {
            seas_amp: (0.02, 0.10), trend: (0.000, 0.004),
            noise: (0.005, 0.02), walk: 0.05, shock_prob: 0.02,
        },
        Category::Finance => CatProfile {
            seas_amp: (0.00, 0.08), trend: (-0.002, 0.006),
            noise: (0.02, 0.08), walk: 0.6, shock_prob: 0.10,
        },
        Category::Industry => CatProfile {
            seas_amp: (0.10, 0.35), trend: (-0.002, 0.005),
            noise: (0.02, 0.06), walk: 0.2, shock_prob: 0.08,
        },
        Category::Macro => CatProfile {
            seas_amp: (0.03, 0.15), trend: (0.000, 0.005),
            noise: (0.01, 0.03), walk: 0.15, shock_prob: 0.04,
        },
        Category::Micro => CatProfile {
            seas_amp: (0.10, 0.40), trend: (-0.003, 0.008),
            noise: (0.03, 0.10), walk: 0.25, shock_prob: 0.12,
        },
        Category::Other => CatProfile {
            seas_amp: (0.00, 0.25), trend: (-0.003, 0.006),
            noise: (0.02, 0.08), walk: 0.3, shock_prob: 0.06,
        },
    }
}

/// Generate one series. Errors when `freq` has no Table 3 length row.
pub fn gen_series(rng: &mut Rng, id: String, freq: Frequency,
                  cat: Category) -> Result<Series> {
    let n = sample_length(rng, freq)?;
    let p = profile(cat);
    let period = freq.seasonality();

    let base = (rng.uniform(2.0, 9.0)).exp(); // lognormal base level
    let trend = rng.uniform(p.trend.0, p.trend.1);
    let damp = rng.uniform(0.97, 1.0); // damped trend factor
    let noise = rng.uniform(p.noise.0, p.noise.1);
    let amp = rng.uniform(p.seas_amp.0, p.seas_amp.1);
    let phase = rng.uniform(0.0, std::f64::consts::TAU);
    // Secondary harmonic makes seasonality non-sinusoidal (HW must adapt).
    let amp2 = amp * rng.uniform(0.0, 0.6);
    // §8.2: hourly series carry a second, weekly (168h) cycle.
    let period_w = if freq == Frequency::Hourly { 168usize } else { 0 };
    let amp_w = if period_w > 0 { rng.uniform(0.05, 0.25) } else { 0.0 };
    let phase_w = rng.uniform(0.0, std::f64::consts::TAU);

    let mut level = base;
    let mut drift = trend;
    let mut values = Vec::with_capacity(n);
    for t in 0..n {
        // Damped trend + random-walk component on the level.
        drift *= damp;
        level *= 1.0 + drift;
        if p.walk > 0.0 {
            level *= 1.0 + p.walk * noise * rng.normal();
        }
        if rng.chance(p.shock_prob / 10.0) {
            // Rare regime shift.
            level *= rng.uniform(0.85, 1.2);
        }
        let mut seas = if period > 1 {
            let w = std::f64::consts::TAU * (t % period) as f64 / period as f64;
            1.0 + amp * (w + phase).sin() + amp2 * (2.0 * w + phase).cos()
        } else {
            1.0
        };
        if period_w > 0 {
            let w = std::f64::consts::TAU * (t % period_w) as f64
                / period_w as f64;
            seas *= 1.0 + amp_w * (w + phase_w).sin();
        }
        let shock = if rng.chance(p.shock_prob) {
            rng.uniform(0.92, 1.12)
        } else {
            1.0
        };
        let eps = 1.0 + noise * rng.normal();
        let v = (level * seas.max(0.05) * shock * eps.max(0.05)).max(1e-3);
        values.push(v as f32);
    }
    Ok(Series { id, freq, category: cat, values })
}

/// Generate the whole corpus per `GenOptions`. Errors (instead of
/// panicking mid-generation) when a requested frequency has no Table 3
/// length statistics.
pub fn generate(opts: &GenOptions) -> Result<Corpus> {
    let mut rng = Rng::new(opts.seed);
    let mut series = Vec::new();
    for (freq, counts) in TABLE2_COUNTS {
        if let Some(fs) = &opts.freqs {
            if !fs.contains(&freq) {
                continue;
            }
        }
        for (ci, &count) in counts.iter().enumerate() {
            let cat = ALL_CATEGORIES[ci];
            let scaled = if count == 0 {
                0
            } else {
                (count + opts.scale - 1) / opts.scale
            };
            for k in 0..scaled {
                let id = format!("{}-{}-{:05}",
                                 freq.name(), cat.name().to_lowercase(), k);
                let mut srng = rng.fork((ci * 1_000_003 + k) as u64);
                series.push(gen_series(&mut srng, id, freq, cat)?);
            }
        }
    }
    Ok(Corpus::new(series))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let opts = GenOptions { scale: 1000, ..Default::default() };
        let a = generate(&opts).unwrap();
        let b = generate(&opts).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.series.iter().zip(&b.series) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.values, y.values);
        }
    }

    #[test]
    fn counts_scale_from_table2() {
        let opts = GenOptions { scale: 100, ..Default::default() };
        let c = generate(&opts).unwrap();
        let t = c.count_table();
        // yearly demographic: ceil(1088/100) = 11
        assert_eq!(t[&(Frequency::Yearly, Category::Demographic)], 11);
        // monthly finance: ceil(10987/100) = 110
        assert_eq!(t[&(Frequency::Monthly, Category::Finance)], 110);
        // hourly rows only exist for Other
        assert!(t.get(&(Frequency::Hourly, Category::Macro)).is_none());
        assert_eq!(t[&(Frequency::Hourly, Category::Other)], 5);
    }

    #[test]
    fn values_positive_and_lengths_in_range() {
        let opts = GenOptions { scale: 200, ..Default::default() };
        let c = generate(&opts).unwrap();
        assert!(!c.is_empty());
        for s in &c.series {
            let (_, _, min, max) = length_params(s.freq).unwrap();
            assert!(s.len() >= min && s.len() <= max,
                    "{}: len {} outside [{min}, {max}]", s.id, s.len());
            assert!(s.values.iter().all(|v| *v > 0.0), "{} has nonpositive", s.id);
        }
    }

    #[test]
    fn length_distribution_tracks_table3_roughly() {
        let mut rng = Rng::new(7);
        let lens: Vec<usize> = (0..4000)
            .map(|_| sample_length(&mut rng, Frequency::Monthly).unwrap())
            .collect();
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        // Clamping skews the moments; just require the right ballpark.
        assert!((120.0..280.0).contains(&mean), "mean {mean}");
        assert!(*lens.iter().min().unwrap() >= 24);
    }

    #[test]
    fn seasonal_categories_show_seasonality() {
        // Industry (strong amp) should autocorrelate at the period lag
        // much more than Finance-without-seasonality on average.
        let mut rng = Rng::new(99);
        let s = gen_series(&mut rng, "x".into(), Frequency::Monthly,
                           Category::Industry)
            .unwrap();
        let v: Vec<f64> = s.values.iter().map(|x| (*x as f64).ln()).collect();
        let d: Vec<f64> = v.windows(2).map(|w| w[1] - w[0]).collect();
        let lag = 12;
        let n = d.len() - lag;
        let mean = d.iter().sum::<f64>() / d.len() as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..n {
            num += (d[i] - mean) * (d[i + lag] - mean);
        }
        for x in &d {
            den += (x - mean) * (x - mean);
        }
        let ac = num / den;
        assert!(ac > 0.1, "expected seasonal autocorrelation, got {ac}");
    }
}
