//! Corpus summary statistics — regenerates the *shape* of paper Tables 2
//! and 3 for our synthetic corpus (`fast-esrnn data-gen --report`).

use std::fmt::Write as _;

use crate::config::{ALL_CATEGORIES, ALL_FREQS};
use crate::data::types::Corpus;

/// Five-number-ish summary of series lengths (paper Table 3 columns).
#[derive(Debug, Clone, PartialEq)]
pub struct LengthStats {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: usize,
    pub q25: usize,
    pub median: usize,
    pub q75: usize,
    pub max: usize,
}

pub fn length_stats(lengths: &[usize]) -> Option<LengthStats> {
    if lengths.is_empty() {
        return None;
    }
    let mut v = lengths.to_vec();
    v.sort_unstable();
    let n = v.len();
    let mean = v.iter().sum::<usize>() as f64 / n as f64;
    let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    let q = |p: f64| v[(((n - 1) as f64) * p).round() as usize];
    Some(LengthStats {
        count: n,
        mean,
        std: var.sqrt(),
        min: v[0],
        q25: q(0.25),
        median: q(0.5),
        q75: q(0.75),
        max: v[n - 1],
    })
}

/// Render the Table 2 analogue (counts by frequency × category).
pub fn render_count_table(corpus: &Corpus) -> String {
    let t = corpus.count_table();
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:>12} {:>9} {:>9} {:>9} {:>9} {:>7} {:>8}",
                     "Frequency", "Demographic", "Finance", "Industry",
                     "Macro", "Micro", "Other", "Total");
    let mut grand = 0usize;
    for f in ALL_FREQS {
        let row: Vec<usize> = ALL_CATEGORIES
            .iter()
            .map(|c| *t.get(&(f, *c)).unwrap_or(&0))
            .collect();
        let total: usize = row.iter().sum();
        if total == 0 {
            continue;
        }
        grand += total;
        let _ = writeln!(out, "{:<10} {:>12} {:>9} {:>9} {:>9} {:>9} {:>7} {:>8}",
                         f.name(), row[0], row[1], row[2], row[3], row[4],
                         row[5], total);
    }
    let _ = writeln!(out, "{:<10} {:>81}", "Total", grand);
    out
}

/// Render the Table 3 analogue (length stats per frequency).
pub fn render_length_table(corpus: &Corpus) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:>6} {:>8} {:>8} {:>5} {:>5} {:>5} {:>5} {:>6}",
                     "Frequency", "count", "mean", "std", "min", "25%", "50%",
                     "75%", "max");
    for f in ALL_FREQS {
        if let Some(st) = length_stats(&corpus.lengths(f)) {
            let _ = writeln!(out,
                "{:<10} {:>6} {:>8.1} {:>8.1} {:>5} {:>5} {:>5} {:>5} {:>6}",
                f.name(), st.count, st.mean, st.std, st.min, st.q25,
                st.median, st.q75, st.max);
        }
    }
    out
}

/// Data retention after §5.2 equalization, per frequency.
pub fn retention_report(corpus: &Corpus) -> String {
    use crate::config::{NetworkConfig, MODELED_FREQS};
    use crate::data::split::split_corpus;
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:>6} {:>9} {:>10}", "Frequency", "kept",
                     "discarded", "retention");
    for f in MODELED_FREQS {
        let cfg = NetworkConfig::for_freq(f).unwrap();
        if let Ok(set) = split_corpus(corpus, &cfg) {
            let _ = writeln!(out, "{:<10} {:>6} {:>9} {:>9.1}%", f.name(),
                             set.series.len(), set.discarded,
                             100.0 * set.retention());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Category, Frequency};
    use crate::data::types::Series;

    #[test]
    fn stats_of_known_sequence() {
        let st = length_stats(&[10, 20, 30, 40, 50]).unwrap();
        assert_eq!(st.min, 10);
        assert_eq!(st.median, 30);
        assert_eq!(st.max, 50);
        assert!((st.mean - 30.0).abs() < 1e-12);
        assert!(length_stats(&[]).is_none());
    }

    #[test]
    fn tables_render_nonempty() {
        let corpus = Corpus::new(vec![Series {
            id: "a".into(),
            freq: Frequency::Monthly,
            category: Category::Micro,
            values: vec![1.0; 120],
        }]);
        let t2 = render_count_table(&corpus);
        assert!(t2.contains("monthly"));
        let t3 = render_length_table(&corpus);
        assert!(t3.contains("120"));
    }
}
