//! Series-length equalization (§5.2) and train/val/test splits (Eqs. 7–8).
//!
//! The paper fixes every series of a frequency to length C (72 for Q/M),
//! discarding shorter series, and holds out the last two horizons:
//!
//! ```text
//! Train[N-O*2-C .. N-O*2-1],  Val[N-O*2 .. N-O-1],  Test[N-O .. N]   (Eq. 8)
//! ```
//!
//! We expose BOTH alignments: `fit` (train window, val next — used during
//! training/early stopping) and `refit` (window shifted forward by H so the
//! model sees the val region; its forecast scores against test).

use anyhow::{bail, Result};

use crate::config::NetworkConfig;
use crate::data::types::{Corpus, Series};

/// One equalized series, ready for the coordinator.
#[derive(Debug, Clone)]
pub struct SplitSeries {
    pub id: String,
    pub category_onehot: [f32; 6],
    pub category_index: usize,
    /// C values ending right before the validation block (Eq. 8 Train).
    pub train: Vec<f32>,
    /// H values following `train` (Eq. 8 Val).
    pub val: Vec<f32>,
    /// C values ending right before the test block (train shifted by H).
    pub refit: Vec<f32>,
    /// Final H values (Eq. 8 Test).
    pub test: Vec<f32>,
    /// In-sample history *before* the test block (for MASE scaling).
    pub insample_len: usize,
    /// Naive-seasonal scale for MASE, computed over the full pre-test
    /// history (M4 convention).
    pub mase_scale: f32,
}

/// Result of equalizing one frequency's slice of a corpus.
#[derive(Debug, Clone)]
pub struct SplitSet {
    pub series: Vec<SplitSeries>,
    pub discarded: usize,
    pub total: usize,
}

impl SplitSet {
    /// Paper §5.2 "data retention" after thresholding.
    pub fn retention(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.series.len() as f64 / self.total as f64
    }
}

/// MASE denominator: mean absolute seasonal-naive error over the
/// in-sample portion (M4 definition).
fn mase_scale(insample: &[f32], period: usize) -> f32 {
    let m = period.max(1);
    if insample.len() <= m {
        return 1.0;
    }
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for t in m..insample.len() {
        acc += (insample[t] - insample[t - m]).abs() as f64;
        n += 1;
    }
    if n == 0 || acc == 0.0 {
        1.0
    } else {
        (acc / n as f64) as f32
    }
}

/// Split one raw series per Eq. 8. Returns None if too short (§5.2).
pub fn split_series(s: &Series, cfg: &NetworkConfig) -> Option<SplitSeries> {
    let c = cfg.length;
    let h = cfg.horizon;
    let n = s.len();
    if n < c + 2 * h {
        return None;
    }
    let test_start = n - h;
    let val_start = n - 2 * h;
    let train_start = val_start - c;
    let refit_start = test_start - c;
    Some(SplitSeries {
        id: s.id.clone(),
        category_onehot: s.category_onehot(),
        category_index: s.category.index(),
        train: s.values[train_start..val_start].to_vec(),
        val: s.values[val_start..test_start].to_vec(),
        refit: s.values[refit_start..test_start].to_vec(),
        test: s.values[test_start..].to_vec(),
        insample_len: test_start,
        mase_scale: mase_scale(&s.values[..test_start], cfg.seasonality),
    })
}

/// Equalize + split every series of `cfg.freq` in the corpus.
pub fn split_corpus(corpus: &Corpus, cfg: &NetworkConfig) -> Result<SplitSet> {
    let pool = corpus.by_freq(cfg.freq);
    let total = pool.len();
    if total == 0 {
        bail!("corpus has no {} series", cfg.freq.name());
    }
    let mut series = Vec::new();
    for s in pool {
        if let Some(sp) = split_series(s, cfg) {
            series.push(sp);
        }
    }
    let discarded = total - series.len();
    Ok(SplitSet { series, discarded, total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Category, Frequency};

    fn cfg() -> NetworkConfig {
        NetworkConfig::for_freq(Frequency::Quarterly).unwrap()
    }

    fn series(n: usize) -> Series {
        Series {
            id: "t".into(),
            freq: Frequency::Quarterly,
            category: Category::Macro,
            values: (0..n).map(|i| i as f32 + 1.0).collect(),
        }
    }

    #[test]
    fn split_windows_line_up_with_eq8() {
        let cfg = cfg(); // C=72, H=8
        let s = series(100);
        let sp = split_series(&s, &cfg).unwrap();
        assert_eq!(sp.train.len(), 72);
        assert_eq!(sp.val.len(), 8);
        assert_eq!(sp.test.len(), 8);
        assert_eq!(sp.refit.len(), 72);
        // Contiguity: train ends where val starts, val ends where test starts.
        assert_eq!(*sp.train.last().unwrap() + 1.0, sp.val[0]);
        assert_eq!(*sp.val.last().unwrap() + 1.0, sp.test[0]);
        // refit = last C values before test (so it *contains* val).
        assert_eq!(*sp.refit.last().unwrap(), *sp.val.last().unwrap());
        assert_eq!(sp.insample_len, 92);
    }

    #[test]
    fn short_series_discarded() {
        let cfg = cfg();
        assert!(split_series(&series(87), &cfg).is_none()); // < 72+16
        assert!(split_series(&series(88), &cfg).is_some()); // == 72+16
    }

    #[test]
    fn split_corpus_counts_discards() {
        let corpus = Corpus::new(vec![series(87), series(90), series(120)]);
        let set = split_corpus(&corpus, &cfg()).unwrap();
        assert_eq!(set.total, 3);
        assert_eq!(set.series.len(), 2);
        assert_eq!(set.discarded, 1);
        assert!((set.retention() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mase_scale_of_linear_series() {
        // y_t = t+1, period 4: |y_t - y_{t-4}| = 4 everywhere.
        let s = series(92);
        let sc = mase_scale(&s.values, 4);
        assert!((sc - 4.0).abs() < 1e-6);
        // Degenerate short series fall back to 1.
        assert_eq!(mase_scale(&[1.0, 2.0], 4), 1.0);
    }
}
