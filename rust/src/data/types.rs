//! Core dataset types: series and corpus.

use std::collections::BTreeMap;

use crate::config::{Category, Frequency};

/// One univariate time series (strictly positive values, M4-style).
#[derive(Debug, Clone)]
pub struct Series {
    pub id: String,
    pub freq: Frequency,
    pub category: Category,
    pub values: Vec<f32>,
}

impl Series {
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// One-hot category encoding (paper §5.3).
    pub fn category_onehot(&self) -> [f32; 6] {
        let mut v = [0.0; 6];
        v[self.category.index()] = 1.0;
        v
    }
}

/// A collection of series across frequencies/categories.
#[derive(Debug, Clone, Default)]
pub struct Corpus {
    pub series: Vec<Series>,
}

impl Corpus {
    pub fn new(series: Vec<Series>) -> Self {
        Self { series }
    }

    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    pub fn by_freq(&self, freq: Frequency) -> Vec<&Series> {
        self.series.iter().filter(|s| s.freq == freq).collect()
    }

    /// Count table keyed by (frequency, category) — the shape of paper
    /// Table 2.
    pub fn count_table(&self) -> BTreeMap<(Frequency, Category), usize> {
        let mut t = BTreeMap::new();
        for s in &self.series {
            *t.entry((s.freq, s.category)).or_insert(0) += 1;
        }
        t
    }

    /// Series lengths for one frequency (input to Table 3 stats).
    pub fn lengths(&self, freq: Frequency) -> Vec<usize> {
        self.series
            .iter()
            .filter(|s| s.freq == freq)
            .map(|s| s.len())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(freq: Frequency, cat: Category, n: usize) -> Series {
        Series {
            id: format!("{}-{}-{}", freq.name(), cat.name(), n),
            freq,
            category: cat,
            values: vec![1.0; n],
        }
    }

    #[test]
    fn onehot_puts_one_in_category_slot() {
        let s = mk(Frequency::Monthly, Category::Finance, 5);
        let oh = s.category_onehot();
        assert_eq!(oh.iter().sum::<f32>(), 1.0);
        assert_eq!(oh[Category::Finance.index()], 1.0);
    }

    #[test]
    fn count_table_groups() {
        let c = Corpus::new(vec![
            mk(Frequency::Yearly, Category::Macro, 10),
            mk(Frequency::Yearly, Category::Macro, 12),
            mk(Frequency::Monthly, Category::Micro, 80),
        ]);
        let t = c.count_table();
        assert_eq!(t[&(Frequency::Yearly, Category::Macro)], 2);
        assert_eq!(t[&(Frequency::Monthly, Category::Micro)], 1);
        assert_eq!(c.lengths(Frequency::Yearly), vec![10, 12]);
    }
}
