//! Corpus persistence: a simple CSV-ish line format
//! (`id,freq,category,v1,v2,...`) so generated corpora can be saved,
//! inspected and re-loaded without regeneration.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Category, Frequency};
use crate::data::types::{Corpus, Series};

pub fn save(corpus: &Corpus, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for s in &corpus.series {
        write!(w, "{},{},{}", s.id, s.freq.name(), s.category.name())?;
        for v in &s.values {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let r = BufReader::new(f);
    let mut series = Vec::new();
    for (ln, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut it = line.split(',');
        let id = it.next().unwrap_or_default().to_string();
        let freq = Frequency::parse(it.next().unwrap_or_default())
            .with_context(|| format!("line {}", ln + 1))?;
        let category = Category::parse(it.next().unwrap_or_default())
            .with_context(|| format!("line {}", ln + 1))?;
        let values: Vec<f32> = it
            .map(|t| t.parse::<f32>()
                 .with_context(|| format!("line {}: bad value `{t}`", ln + 1)))
            .collect::<Result<_>>()?;
        if values.is_empty() {
            bail!("line {}: series `{id}` has no values", ln + 1);
        }
        series.push(Series { id, freq, category, values });
    }
    Ok(Corpus::new(series))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let corpus = Corpus::new(vec![
            Series {
                id: "m-1".into(),
                freq: Frequency::Monthly,
                category: Category::Micro,
                values: vec![1.5, 2.25, 3.0],
            },
            Series {
                id: "y-1".into(),
                freq: Frequency::Yearly,
                category: Category::Macro,
                values: vec![10.0, 20.0],
            },
        ]);
        let dir = std::env::temp_dir().join("fast_esrnn_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.csv");
        save(&corpus, &path).unwrap();
        let re = load(&path).unwrap();
        assert_eq!(re.len(), 2);
        assert_eq!(re.series[0].values, vec![1.5, 2.25, 3.0]);
        assert_eq!(re.series[1].freq, Frequency::Yearly);
        assert_eq!(re.series[1].category, Category::Macro);
    }

    #[test]
    fn rejects_bad_rows() {
        let dir = std::env::temp_dir().join("fast_esrnn_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "id,monthly,Micro,1.0,oops\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "id,blah,Micro,1.0\n").unwrap();
        assert!(load(&path).is_err());
    }
}
