//! Corpus persistence.
//!
//! Two formats live here:
//!
//! * The repo's own line format (`id,freq,category,v1,v2,...`) via
//!   [`save`]/[`load`] — compact, self-describing, used for generated
//!   corpora.
//! * The **real M4 competition layout** via [`M4CsvReader`]: one CSV
//!   per frequency (`Monthly-train.csv`, `Hourly-test.csv`, …) with a
//!   `V1,V2,...` header, a quoted series id in the first cell, and
//!   ragged series lengths padded with trailing empty cells. At M4
//!   scale (100k series, ~400 MB of monthly training data) whole-file
//!   `Vec` materialization is the wrong shape — the reader streams one
//!   [`Series`] at a time, so callers can feed a store or a pool
//!   without ever holding the corpus in memory.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, BufWriter, Lines, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Category, Frequency};
use crate::data::types::{Corpus, Series};

pub fn save(corpus: &Corpus, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    for s in &corpus.series {
        write!(w, "{},{},{}", s.id, s.freq.name(), s.category.name())?;
        for v in &s.values {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let r = BufReader::new(f);
    let mut series = Vec::new();
    for (ln, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut it = line.split(',');
        let id = it.next().unwrap_or_default().to_string();
        let freq = Frequency::parse(it.next().unwrap_or_default())
            .with_context(|| format!("line {}", ln + 1))?;
        let category = Category::parse(it.next().unwrap_or_default())
            .with_context(|| format!("line {}", ln + 1))?;
        let values: Vec<f32> = it
            .map(|t| t.parse::<f32>()
                 .with_context(|| format!("line {}: bad value `{t}`", ln + 1)))
            .collect::<Result<_>>()?;
        if values.is_empty() {
            bail!("line {}: series `{id}` has no values", ln + 1);
        }
        series.push(Series { id, freq, category, values });
    }
    Ok(Corpus::new(series))
}

/// Streaming reader over one M4-layout CSV: yields each row as a
/// [`Series`] without materializing the file.
///
/// Layout rules enforced (each violation is a descriptive error naming
/// the source and 1-based line):
///
/// * A header row (`V1,V2,...`) fixes the column budget; a data row
///   with more cells than the header is **ragged**.
/// * The first cell is the series id (M4 quotes it — quotes are
///   stripped); a repeated id is a **duplicate-id** error, caught
///   streaming via an id set (bounded: ids only, never values).
/// * Values run until the first empty cell; a non-empty cell *after*
///   an empty one is a hole — also reported as ragged, since
///   downstream ES seeding assumes contiguous history.
/// * A row with no values at all is an error.
///
/// M4 CSVs carry no category column (that lives in `M4-info.csv`), so
/// every yielded series gets [`Category::Other`].
pub struct M4CsvReader<R> {
    lines: Lines<R>,
    /// Display name for errors (path, or a caller-supplied tag).
    source: String,
    freq: Frequency,
    /// Cell budget fixed by the header row.
    columns: usize,
    /// 1-based line of the most recently read row.
    line: usize,
    seen: HashSet<String>,
}

impl M4CsvReader<BufReader<std::fs::File>> {
    /// Open an M4 CSV, inferring the frequency from the file name
    /// (`Monthly-train.csv` → [`Frequency::Monthly`] — the M4
    /// convention of `<Frequency>-<split>.csv`).
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow!("{}: not a named file", path.display()))?;
        let freq_name = stem.split('-').next().unwrap_or(stem);
        let freq = Frequency::parse(freq_name).with_context(|| {
            format!("{}: cannot infer the frequency from the file name \
                     (expected `<Frequency>-<split>.csv`, e.g. \
                     Monthly-train.csv)", path.display())
        })?;
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::from_reader(BufReader::new(f), freq,
                          path.display().to_string())
    }
}

impl<R: BufRead> M4CsvReader<R> {
    /// Wrap an already-open reader (tests, decompression pipes). Reads
    /// and validates the header row immediately.
    pub fn from_reader(reader: R, freq: Frequency, source: String)
                       -> Result<Self> {
        let mut lines = reader.lines();
        let header = lines
            .next()
            .ok_or_else(|| anyhow!("{source}: empty file — expected a \
                                    V1,V2,... header row"))?
            .with_context(|| format!("{source}: reading the header row"))?;
        let cells: Vec<&str> = split_cells(&header).collect();
        // The id column header is `V1` in the official files; accept
        // anything non-numeric so hand-rolled fixtures work too, but
        // insist on at least one value column.
        if cells.len() < 2 {
            bail!("{source}: header row has {} column(s) — an M4 file \
                   needs an id column plus value columns", cells.len());
        }
        Ok(Self {
            lines,
            source,
            freq,
            columns: cells.len(),
            line: 1,
            seen: HashSet::new(),
        })
    }

    pub fn freq(&self) -> Frequency {
        self.freq
    }

    /// Parse one data row into a [`Series`].
    fn parse_row(&mut self, row: &str) -> Result<Series> {
        let (source, line) = (&self.source, self.line);
        let mut cells = split_cells(row);
        let id = cells
            .next()
            .filter(|c| !c.is_empty())
            .ok_or_else(|| anyhow!("{source} line {line}: row has no \
                                    series id"))?
            .to_string();
        if !self.seen.insert(id.clone()) {
            bail!("{source} line {line}: duplicate series id `{id}` — \
                   each M4 row must be a distinct series");
        }
        let mut values = Vec::new();
        let mut padding = false;
        let mut cell_count = 1usize;
        for cell in cells {
            cell_count += 1;
            if cell_count > self.columns {
                bail!("{source} line {line}: series `{id}` has {cell_count} \
                       cells but the header declares {} columns — ragged \
                       row", self.columns);
            }
            if cell.is_empty() {
                padding = true;
                continue;
            }
            if padding {
                bail!("{source} line {line}: series `{id}` has a value \
                       after an empty cell — ragged row (history must be \
                       contiguous)");
            }
            let v: f32 = cell.parse().map_err(|_| {
                anyhow!("{source} line {line}: series `{id}` has a \
                         non-numeric value `{cell}`")
            })?;
            values.push(v);
        }
        if values.is_empty() {
            bail!("{source} line {line}: series `{id}` has no values");
        }
        Ok(Series {
            id,
            freq: self.freq,
            category: Category::Other,
            values,
        })
    }
}

impl<R: BufRead> Iterator for M4CsvReader<R> {
    type Item = Result<Series>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            let row = match self.lines.next()? {
                Ok(r) => r,
                Err(e) => {
                    return Some(Err(anyhow::Error::new(e).context(format!(
                        "{} line {}: read error", self.source,
                        self.line + 1))));
                }
            };
            self.line += 1;
            if row.trim().is_empty() {
                continue;
            }
            return Some(self.parse_row(&row));
        }
    }
}

/// Split one CSV row into cells, trimming the CR of CRLF files and the
/// double quotes M4 wraps ids (and sometimes values) in. M4 cells never
/// contain embedded commas, so a plain split is exact here.
fn split_cells(row: &str) -> impl Iterator<Item = &str> {
    row.trim_end_matches('\r')
        .split(',')
        .map(|c| c.trim().trim_matches('"'))
}

/// Convenience for small files: stream [`M4CsvReader::open`] into a
/// [`Corpus`]. At full M4 scale prefer iterating the reader directly.
pub fn load_m4(path: impl AsRef<Path>) -> Result<Corpus> {
    let series: Vec<Series> =
        M4CsvReader::open(path)?.collect::<Result<_>>()?;
    Ok(Corpus::new(series))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let corpus = Corpus::new(vec![
            Series {
                id: "m-1".into(),
                freq: Frequency::Monthly,
                category: Category::Micro,
                values: vec![1.5, 2.25, 3.0],
            },
            Series {
                id: "y-1".into(),
                freq: Frequency::Yearly,
                category: Category::Macro,
                values: vec![10.0, 20.0],
            },
        ]);
        let dir = std::env::temp_dir().join("fast_esrnn_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.csv");
        save(&corpus, &path).unwrap();
        let re = load(&path).unwrap();
        assert_eq!(re.len(), 2);
        assert_eq!(re.series[0].values, vec![1.5, 2.25, 3.0]);
        assert_eq!(re.series[1].freq, Frequency::Yearly);
        assert_eq!(re.series[1].category, Category::Macro);
    }

    #[test]
    fn rejects_bad_rows() {
        let dir = std::env::temp_dir().join("fast_esrnn_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "id,monthly,Micro,1.0,oops\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "id,blah,Micro,1.0\n").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn m4_reader_streams_the_competition_layout() {
        // Quoted ids, CRLF line endings, ragged lengths padded with
        // trailing empty cells — the shape of the official files.
        let csv = "\"V1\",\"V2\",\"V3\",\"V4\",\"V5\"\r\n\
                   \"Q1\",1.0,2.0,3.0,4.0\r\n\
                   \r\n\
                   \"Q2\",5.5,6.5,,\r\n";
        let mut r = M4CsvReader::from_reader(
            std::io::Cursor::new(csv), Frequency::Quarterly,
            "test".to_string())
            .unwrap();
        assert_eq!(r.freq(), Frequency::Quarterly);
        let a = r.next().unwrap().unwrap();
        assert_eq!(a.id, "Q1");
        assert_eq!(a.values, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.freq, Frequency::Quarterly);
        assert_eq!(a.category, Category::Other);
        let b = r.next().unwrap().unwrap();
        assert_eq!(b.id, "Q2");
        assert_eq!(b.values, vec![5.5, 6.5]);
        assert!(r.next().is_none());
    }

    #[test]
    fn m4_reader_rejects_ragged_and_duplicate_rows() {
        let open = |csv: &str| {
            M4CsvReader::from_reader(
                std::io::Cursor::new(csv.to_string()), Frequency::Monthly,
                "m.csv".to_string())
                .unwrap()
        };
        // Duplicate id, named with its line.
        let mut r = open("V1,V2,V3\nM1,1,2\nM1,3,4\n");
        assert!(r.next().unwrap().is_ok());
        let e = format!("{:#}", r.next().unwrap().unwrap_err());
        assert!(e.contains("duplicate series id `M1`")
                && e.contains("line 3"), "{e}");
        // More cells than the header declares.
        let mut r = open("V1,V2,V3\nM3,1,2,3\n");
        let e = format!("{:#}", r.next().unwrap().unwrap_err());
        assert!(e.contains("ragged"), "{e}");
        // A value after an empty cell (a hole in the history).
        let mut r = open("V1,V2,V3,V4\nM4,1,,2\n");
        let e = format!("{:#}", r.next().unwrap().unwrap_err());
        assert!(e.contains("ragged") && e.contains("empty cell"), "{e}");
        // Non-numeric value / empty series.
        let mut r = open("V1,V2\nM5,abc\n");
        assert!(r.next().unwrap().is_err());
        let mut r = open("V1,V2\nM6,,\n");
        let e = format!("{:#}", r.next().unwrap().unwrap_err());
        assert!(e.contains("no values"), "{e}");
    }

    #[test]
    fn m4_open_infers_frequency_from_the_file_name() {
        let dir = std::env::temp_dir().join("fast_esrnn_m4_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("Yearly-train.csv");
        std::fs::write(&path, "V1,V2,V3\nY1,10,20\nY2,30,\n").unwrap();
        let corpus = load_m4(&path).unwrap();
        assert_eq!(corpus.len(), 2);
        assert_eq!(corpus.series[0].freq, Frequency::Yearly);
        assert_eq!(corpus.series[1].values, vec![30.0]);
        // A name that encodes no frequency is a descriptive error.
        let bad = dir.join("notes.csv");
        std::fs::write(&bad, "V1,V2\nY1,1\n").unwrap();
        let e = format!("{:#}", load_m4(&bad).unwrap_err());
        assert!(e.contains("cannot infer the frequency"), "{e}");
    }
}
