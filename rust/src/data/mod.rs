//! Dataset pipeline: corpus types, the synthetic M4-like generator
//! (Tables 2–3), length equalization + splits (§5.2, Eqs. 7–8), summary
//! statistics and CSV persistence.

pub mod csv;
pub mod split;
pub mod stats;
pub mod synthetic;
pub mod types;

pub use csv::{load_m4, M4CsvReader};
pub use split::{split_corpus, split_series, SplitSeries, SplitSet};
pub use synthetic::{generate, GenOptions};
pub use types::{Corpus, Series};
