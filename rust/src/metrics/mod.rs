//! Forecast accuracy metrics (paper §3.5 + M4 conventions): sMAPE, MASE,
//! OWA, pinball — plus aggregation helpers used by the Table 4/6 benches.

use std::collections::BTreeMap;

/// Symmetric Mean Absolute Percentage Error, in percent (M4 definition):
/// `200/h * Σ |y - ŷ| / (|y| + |ŷ|)`.
pub fn smape(forecast: &[f32], actual: &[f32]) -> f64 {
    assert_eq!(forecast.len(), actual.len());
    assert!(!forecast.is_empty());
    let mut acc = 0.0f64;
    for (f, a) in forecast.iter().zip(actual) {
        let denom = (f.abs() + a.abs()) as f64;
        if denom > 0.0 {
            acc += 200.0 * (f - a).abs() as f64 / denom;
        }
    }
    acc / forecast.len() as f64
}

/// Mean Absolute Scaled Error. `scale` is the in-sample mean absolute
/// seasonal-naive error (see `data::split::mase_scale`).
pub fn mase(forecast: &[f32], actual: &[f32], scale: f32) -> f64 {
    assert_eq!(forecast.len(), actual.len());
    assert!(!forecast.is_empty());
    let scale = if scale > 0.0 { scale as f64 } else { 1.0 };
    let mae: f64 = forecast
        .iter()
        .zip(actual)
        .map(|(f, a)| (f - a).abs() as f64)
        .sum::<f64>()
        / forecast.len() as f64;
    mae / scale
}

/// Pinball (quantile) loss at `tau` — the training surrogate (§3.5).
pub fn pinball(forecast: &[f32], actual: &[f32], tau: f64) -> f64 {
    assert_eq!(forecast.len(), actual.len());
    assert!(!forecast.is_empty());
    let mut acc = 0.0f64;
    for (f, a) in forecast.iter().zip(actual) {
        let d = (a - f) as f64;
        acc += (tau * d).max((tau - 1.0) * d);
    }
    acc / forecast.len() as f64
}

/// Overall Weighted Average relative to a benchmark method (M4):
/// `OWA = 0.5 * (sMAPE/sMAPE_bench + MASE/MASE_bench)`.
pub fn owa(smape_m: f64, mase_m: f64, smape_bench: f64, mase_bench: f64) -> f64 {
    0.5 * (smape_m / smape_bench + mase_m / mase_bench)
}

/// Streaming accumulator for per-group metric means (Table 4 / Table 6).
#[derive(Debug, Default, Clone)]
pub struct MetricAccumulator {
    groups: BTreeMap<String, (f64, f64, usize)>, // (Σ smape, Σ mase, n)
}

impl MetricAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, group: &str, smape_v: f64, mase_v: f64) {
        let e = self.groups.entry(group.to_string()).or_insert((0.0, 0.0, 0));
        e.0 += smape_v;
        e.1 += mase_v;
        e.2 += 1;
    }

    pub fn count(&self, group: &str) -> usize {
        self.groups.get(group).map(|e| e.2).unwrap_or(0)
    }

    pub fn mean_smape(&self, group: &str) -> Option<f64> {
        self.groups.get(group).and_then(|(s, _, n)| {
            (*n > 0).then(|| s / *n as f64)
        })
    }

    pub fn mean_mase(&self, group: &str) -> Option<f64> {
        self.groups.get(group).and_then(|(_, m, n)| {
            (*n > 0).then(|| m / *n as f64)
        })
    }

    pub fn groups(&self) -> Vec<&str> {
        self.groups.keys().map(|s| s.as_str()).collect()
    }

    /// Series-weighted overall mean across selected groups.
    pub fn weighted_smape(&self, groups: &[&str]) -> Option<f64> {
        let mut acc = 0.0;
        let mut n = 0usize;
        for g in groups {
            if let Some((s, _, c)) = self.groups.get(*g) {
                acc += s;
                n += c;
            }
        }
        (n > 0).then(|| acc / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smape_perfect_forecast_is_zero() {
        assert_eq!(smape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn smape_known_value() {
        // |10-8|/(10+8)*200 = 22.22...
        let v = smape(&[10.0], &[8.0]);
        assert!((v - 200.0 * 2.0 / 18.0).abs() < 1e-9);
        // symmetric
        assert!((smape(&[8.0], &[10.0]) - v).abs() < 1e-12);
    }

    #[test]
    fn smape_bounded_0_200() {
        let v = smape(&[1.0], &[-1.0]);
        assert!((v - 200.0).abs() < 1e-9);
    }

    #[test]
    fn mase_scales_by_naive_error() {
        // forecast off by 2 everywhere, naive scale 4 -> 0.5
        let v = mase(&[3.0, 5.0], &[1.0, 3.0], 4.0);
        assert!((v - 0.5).abs() < 1e-9);
        // degenerate scale falls back to 1
        assert_eq!(mase(&[2.0], &[1.0], 0.0), 1.0);
    }

    #[test]
    fn pinball_asymmetry() {
        // under-forecast penalized by tau, over-forecast by 1-tau
        let under = pinball(&[0.0], &[1.0], 0.48); // d=1 -> 0.48
        let over = pinball(&[1.0], &[0.0], 0.48); // d=-1 -> 0.52
        assert!((under - 0.48).abs() < 1e-9);
        assert!((over - 0.52).abs() < 1e-9);
    }

    #[test]
    fn owa_of_benchmark_is_one() {
        assert!((owa(12.0, 1.5, 12.0, 1.5) - 1.0).abs() < 1e-12);
        assert!(owa(6.0, 0.75, 12.0, 1.5) < 1.0);
    }

    #[test]
    fn accumulator_means_and_weights() {
        let mut acc = MetricAccumulator::new();
        acc.add("Finance", 10.0, 1.0);
        acc.add("Finance", 20.0, 2.0);
        acc.add("Macro", 30.0, 3.0);
        assert_eq!(acc.mean_smape("Finance"), Some(15.0));
        assert_eq!(acc.mean_mase("Macro"), Some(3.0));
        assert_eq!(acc.count("Finance"), 2);
        assert_eq!(acc.weighted_smape(&["Finance", "Macro"]), Some(20.0));
        assert_eq!(acc.mean_smape("Nope"), None);
    }
}
