//! Checkpointing: persist and restore a trained model (shared RNN state +
//! the per-series parameter store) as JSON.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::store::ParamStore;
use crate::coordinator::trainer::ModelState;
use crate::runtime::HostTensor;
use crate::util::json::Json;

/// Serialize (state, store) to a JSON file.
pub fn save(path: impl AsRef<Path>, freq: &str, state: &ModelState,
            store: &ParamStore) -> Result<()> {
    let mut tensors = Vec::new();
    let mut names: Vec<&String> = state.tensors.keys().collect();
    names.sort();
    for name in names {
        let t = &state.tensors[name];
        tensors.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            ("shape", Json::arr_usize(&t.shape)),
            ("data", Json::arr_f32(&t.data)),
        ]));
    }
    let mut series = Vec::new();
    for (name, width, values) in store.export() {
        series.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("width", Json::num(width as f64)),
            ("data", Json::arr_f32(&values)),
        ]));
    }
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("freq", Json::str(freq)),
        ("n_series", Json::num(store.n as f64)),
        ("seasonality", Json::num(store.seasonality as f64)),
        ("model", Json::Arr(tensors)),
        ("series_store", Json::Arr(series)),
    ]);
    std::fs::write(path.as_ref(), doc.to_string())
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Restore into an existing (state, store) pair; shapes must match.
pub fn load(path: impl AsRef<Path>, state: &mut ModelState,
            store: &mut ParamStore) -> Result<String> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let doc = Json::parse(&text)?;
    if doc.get("version")?.as_usize()? != 1 {
        bail!("unsupported checkpoint version");
    }
    if doc.get("n_series")?.as_usize()? != store.n {
        bail!("checkpoint has {} series, store has {}",
              doc.get("n_series")?.as_usize()?, store.n);
    }
    for t in doc.get("model")?.as_arr()? {
        let name = t.get("name")?.as_str()?.to_string();
        let shape = t.get("shape")?.as_usize_vec()?;
        let data = t.get("data")?.as_f32_vec()?;
        state.tensors.insert(name, HostTensor::new(shape, data)?);
    }
    let mut entries = Vec::new();
    for e in doc.get("series_store")?.as_arr()? {
        entries.push((
            e.get("name")?.as_str()?.to_string(),
            e.get("width")?.as_usize()?,
            e.get("data")?.as_f32_vec()?,
        ));
    }
    store.import(&entries)?;
    Ok(doc.get("freq")?.as_str()?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Primer;
    use std::collections::HashMap;

    #[test]
    fn roundtrip() {
        let mut state = ModelState { tensors: HashMap::new() };
        state.tensors.insert(
            "params.rnn.w".into(),
            HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        );
        state.tensors.insert("opt.step".into(), HostTensor::scalar(7.0));
        let primers: Vec<Primer> = (0..3)
            .map(|i| Primer {
                alpha_logit: i as f32,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.1, 0.2],
            })
            .collect();
        let store = ParamStore::from_primers(&primers, 2).unwrap();

        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        save(&path, "quarterly", &state, &store).unwrap();

        let mut state2 = ModelState { tensors: HashMap::new() };
        let mut store2 = ParamStore::from_primers(&primers, 2).unwrap();
        // clobber store2 so load must restore it
        let t = HostTensor::new(vec![1], vec![-9.0]).unwrap();
        store2.scatter("params.series.alpha_logit", &[1], &[true], &t).unwrap();

        let freq = load(&path, &mut state2, &mut store2).unwrap();
        assert_eq!(freq, "quarterly");
        assert_eq!(state2.tensors["params.rnn.w"].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(state2.step(), 7.0);
        assert_eq!(store2.series_params(1).0, 1.0); // restored, not -9
    }

    #[test]
    fn size_mismatch_rejected() {
        let primers: Vec<Primer> = (0..2)
            .map(|_| Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0],
            })
            .collect();
        let state = ModelState { tensors: HashMap::new() };
        let store = ParamStore::from_primers(&primers, 1).unwrap();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        save(&path, "yearly", &state, &store).unwrap();

        let bigger: Vec<Primer> = (0..5)
            .map(|_| Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0],
            })
            .collect();
        let mut state2 = ModelState { tensors: HashMap::new() };
        let mut store2 = ParamStore::from_primers(&bigger, 1).unwrap();
        assert!(load(&path, &mut state2, &mut store2).is_err());
    }
}
