//! Checkpointing: persist and restore a trained model (shared RNN state +
//! the per-series parameter store) in two formats:
//!
//! * **JSON** (version 1) — human-friendly, diffable, the original
//!   format;
//! * **compact binary** — `FESRNNCK` magic + format version + a leaf
//!   table (name, shape, little-endian f32 data per leaf). Roughly 4–5×
//!   smaller than the JSON text and loses no precision to float→text
//!   round-trips, which matters once serving hot-swaps reload
//!   checkpoints on a live stack.
//!
//! [`save`] picks the format by extension (`.bin` → binary, anything
//! else JSON); [`load`] and [`load_model_state`] sniff the magic bytes so
//! either format loads regardless of file name.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::store::ParamStore;
use crate::coordinator::trainer::ModelState;
use crate::runtime::HostTensor;
use crate::util::json::Json;

/// First 8 bytes of every binary checkpoint.
pub const BINARY_MAGIC: [u8; 8] = *b"FESRNNCK";
/// Current binary format version (independent of the JSON `version`).
pub const BINARY_VERSION: u32 = 1;

/// Serialize (state, store); format chosen by extension (`.bin` →
/// binary, anything else the JSON format).
pub fn save(path: impl AsRef<Path>, freq: &str, state: &ModelState,
            store: &ParamStore) -> Result<()> {
    if path.as_ref().extension().is_some_and(|e| e == "bin") {
        save_binary(path, freq, state, store)
    } else {
        save_json(path, freq, state, store)
    }
}

/// Restore into an existing (state, store) pair; shapes must match. The
/// format is sniffed from the magic bytes, not the file name. Returns
/// the frequency the checkpoint was trained for.
pub fn load(path: impl AsRef<Path>, state: &mut ModelState,
            store: &mut ParamStore) -> Result<String> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.starts_with(&BINARY_MAGIC) {
        load_binary_bytes(&bytes, state, store)
    } else {
        let text = std::str::from_utf8(&bytes)
            .with_context(|| format!("{} is neither binary (no magic) nor \
                                      UTF-8 JSON", path.as_ref().display()))?;
        load_json_text(text, state, store)
    }
}

/// Load only the shared model tensors (RNN weights + optimizer leaves)
/// from either format — what a serving hot-swap needs: no parameter
/// store sizing, no training-corpus coupling. Returns
/// `(freq, ModelState)`.
pub fn load_model_state(path: impl AsRef<Path>) -> Result<(String, ModelState)> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut state = ModelState { tensors: HashMap::new() };
    if bytes.starts_with(&BINARY_MAGIC) {
        let (mut c, freq, _n_series) = parse_binary_header(&bytes)?;
        parse_binary_tensors(&mut c, &mut state)?;
        Ok((freq, state))
    } else {
        let text = std::str::from_utf8(&bytes)
            .with_context(|| format!("{} is neither binary (no magic) nor \
                                      UTF-8 JSON", path.as_ref().display()))?;
        let doc = Json::parse(text)?;
        check_json_version(&doc)?;
        insert_json_tensors(&doc, &mut state)?;
        Ok((doc.get("freq")?.as_str()?.to_string(), state))
    }
}

/// [`load_model_state`] plus a frequency guard: bails when the
/// checkpoint's recorded frequency differs from `freq`. The one place
/// hot-swap frequency validation lives — the single-stack and sharded
/// reload paths both call this, so they can never drift apart.
pub fn load_model_state_for(path: impl AsRef<Path>, freq: &str)
                            -> Result<ModelState> {
    let (ckpt_freq, state) = load_model_state(&path)?;
    if ckpt_freq != freq {
        bail!("checkpoint {} was trained for `{ckpt_freq}`, not `{freq}`",
              path.as_ref().display());
    }
    Ok(state)
}

/// The per-series ES-state sidecar path for a checkpoint: the same file
/// name with `.state` appended (`ckpt.bin` → `ckpt.bin.state`), so the
/// pair travels together through copies/renames that keep extensions.
/// Written by `ServingStack::export_state_sidecar`, merged on
/// `reload_checkpoint` when present; a checkpoint without one reloads
/// exactly as before.
pub fn state_sidecar_path(ckpt: &Path) -> std::path::PathBuf {
    let mut os = ckpt.as_os_str().to_os_string();
    os.push(".state");
    std::path::PathBuf::from(os)
}

// ------------------------------ JSON ------------------------------

/// Serialize (state, store) to the JSON format.
pub fn save_json(path: impl AsRef<Path>, freq: &str, state: &ModelState,
                 store: &ParamStore) -> Result<()> {
    let mut tensors = Vec::new();
    let mut names: Vec<&String> = state.tensors.keys().collect();
    names.sort();
    for name in names {
        let t = &state.tensors[name];
        tensors.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            ("shape", Json::arr_usize(&t.shape)),
            ("data", Json::arr_f32(&t.data)),
        ]));
    }
    let mut series = Vec::new();
    for (name, width, values) in store.export() {
        series.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("width", Json::num(width as f64)),
            ("data", Json::arr_f32(&values)),
        ]));
    }
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("freq", Json::str(freq)),
        ("n_series", Json::num(store.n as f64)),
        ("seasonality", Json::num(store.seasonality as f64)),
        ("model", Json::Arr(tensors)),
        ("series_store", Json::Arr(series)),
    ]);
    std::fs::write(path.as_ref(), doc.to_string())
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

fn check_json_version(doc: &Json) -> Result<()> {
    if doc.get("version")?.as_usize()? != 1 {
        bail!("unsupported checkpoint version");
    }
    Ok(())
}

fn insert_json_tensors(doc: &Json, state: &mut ModelState) -> Result<()> {
    for t in doc.get("model")?.as_arr()? {
        let name = t.get("name")?.as_str()?.to_string();
        let shape = t.get("shape")?.as_usize_vec()?;
        let data = t.get("data")?.as_f32_vec()?;
        state.tensors.insert(name, HostTensor::new(shape, data)?);
    }
    Ok(())
}

fn load_json_text(text: &str, state: &mut ModelState,
                  store: &mut ParamStore) -> Result<String> {
    let doc = Json::parse(text)?;
    check_json_version(&doc)?;
    if doc.get("n_series")?.as_usize()? != store.n {
        bail!("checkpoint has {} series, store has {}",
              doc.get("n_series")?.as_usize()?, store.n);
    }
    insert_json_tensors(&doc, state)?;
    let mut entries = Vec::new();
    for e in doc.get("series_store")?.as_arr()? {
        entries.push((
            e.get("name")?.as_str()?.to_string(),
            e.get("width")?.as_usize()?,
            e.get("data")?.as_f32_vec()?,
        ));
    }
    store.import(&entries)?;
    Ok(doc.get("freq")?.as_str()?.to_string())
}

// ----------------------------- binary -----------------------------
//
// Layout (all integers little-endian, strings u32-length-prefixed UTF-8):
//
//   [0..8)   magic  "FESRNNCK"
//   u32      format version (= 1)
//   str      freq
//   u64      n_series
//   u64      seasonality (S1)
//   u64      seasonality2 (S2; 0 for single-seasonality models)
//   u32      model tensor count
//     per tensor: str name, u32 rank, u64×rank dims, f32×∏dims data
//   u32      series-store entry count
//     per entry: str name, u64 width, u64 value count, f32×count data

/// Serialize (state, store) to the compact binary format.
pub fn save_binary(path: impl AsRef<Path>, freq: &str, state: &ModelState,
                   store: &ParamStore) -> Result<()> {
    let mut out = Vec::new();
    out.extend_from_slice(&BINARY_MAGIC);
    put_u32(&mut out, BINARY_VERSION);
    put_str(&mut out, freq);
    put_u64(&mut out, store.n as u64);
    put_u64(&mut out, store.seasonality as u64);
    put_u64(&mut out, store.seasonality2 as u64);
    let mut names: Vec<&String> = state.tensors.keys().collect();
    names.sort();
    put_u32(&mut out, names.len() as u32);
    for name in names {
        let t = &state.tensors[name];
        put_str(&mut out, name);
        put_u32(&mut out, t.shape.len() as u32);
        for &d in &t.shape {
            put_u64(&mut out, d as u64);
        }
        put_f32s(&mut out, &t.data);
    }
    let entries = store.export();
    put_u32(&mut out, entries.len() as u32);
    for (name, width, values) in &entries {
        put_str(&mut out, name);
        put_u64(&mut out, *width as u64);
        put_u64(&mut out, values.len() as u64);
        put_f32s(&mut out, values);
    }
    std::fs::write(path.as_ref(), out)
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

fn load_binary_bytes(bytes: &[u8], state: &mut ModelState,
                     store: &mut ParamStore) -> Result<String> {
    let (mut c, freq, n_series) = parse_binary_header(bytes)?;
    if n_series != store.n {
        bail!("checkpoint has {n_series} series, store has {}", store.n);
    }
    parse_binary_tensors(&mut c, state)?;
    let n_entries = c.u32()? as usize;
    let mut entries = Vec::with_capacity(n_entries);
    for _ in 0..n_entries {
        let name = c.str()?;
        let width = c.usize64()?;
        let count = c.usize64()?;
        entries.push((name, width, c.f32s(count)?));
    }
    store.import(&entries)?;
    Ok(freq)
}

/// Validate magic + version, read the header fields; the returned cursor
/// is positioned at the model tensor count.
fn parse_binary_header(bytes: &[u8]) -> Result<(Cursor<'_>, String, usize)> {
    if !bytes.starts_with(&BINARY_MAGIC) {
        bail!("not a binary checkpoint (bad magic)");
    }
    let mut c = Cursor { b: bytes, i: BINARY_MAGIC.len() };
    let version = c.u32()?;
    if version != BINARY_VERSION {
        bail!("unsupported binary checkpoint version {version} \
               (this build reads version {BINARY_VERSION})");
    }
    let freq = c.str()?;
    let n_series = c.usize64()?;
    let _seasonality = c.usize64()?;
    let _seasonality2 = c.usize64()?;
    Ok((c, freq, n_series))
}

fn parse_binary_tensors(c: &mut Cursor<'_>, state: &mut ModelState)
                        -> Result<()> {
    let n_tensors = c.u32()? as usize;
    for _ in 0..n_tensors {
        let name = c.str()?;
        let rank = c.u32()? as usize;
        let mut shape = Vec::with_capacity(rank.min(16));
        for _ in 0..rank {
            shape.push(c.usize64()?);
        }
        let count = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| anyhow!("tensor `{name}`: shape {shape:?} \
                                    overflows"))?;
        let data = c.f32s(count)?;
        state.tensors.insert(name, HostTensor::new(shape, data)?);
    }
    Ok(())
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    out.reserve(4 * v.len());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader; every method errors (instead of
/// panicking) on truncated or oversized input.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow!("truncated binary checkpoint at byte \
                                    {} (wanted {n} more)", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6],
                               b[7]]))
    }

    fn usize64(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?)
            .map_err(|_| anyhow!("binary checkpoint field exceeds usize"))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)
            .context("binary checkpoint string is not UTF-8")?
            .to_string())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow!("f32 run of {n} overflows"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Primer;
    use std::collections::HashMap;

    fn demo_pair() -> (ModelState, ParamStore) {
        let mut state = ModelState { tensors: HashMap::new() };
        state.tensors.insert(
            "params.rnn.w".into(),
            HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        );
        state.tensors.insert("opt.step".into(), HostTensor::scalar(7.0));
        let primers: Vec<Primer> = (0..3)
            .map(|i| Primer {
                alpha_logit: i as f32,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.1, 0.2],
            })
            .collect();
        (state, ParamStore::from_primers(&primers, 2).unwrap())
    }

    fn fresh_pair() -> (ModelState, ParamStore) {
        let (_, store) = demo_pair();
        (ModelState { tensors: HashMap::new() }, store)
    }

    #[test]
    fn roundtrip() {
        let (state, store) = demo_pair();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        save(&path, "quarterly", &state, &store).unwrap();

        let (mut state2, mut store2) = fresh_pair();
        // clobber store2 so load must restore it
        let t = HostTensor::new(vec![1], vec![-9.0]).unwrap();
        store2.scatter("params.series.alpha_logit", &[1], &[true], &t).unwrap();

        let freq = load(&path, &mut state2, &mut store2).unwrap();
        assert_eq!(freq, "quarterly");
        assert_eq!(state2.tensors["params.rnn.w"].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(state2.step(), 7.0);
        assert_eq!(store2.series_params(1).0, 1.0); // restored, not -9
    }

    #[test]
    fn binary_roundtrip_matches_json() {
        let (state, store) = demo_pair();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let json_path = dir.join("ckpt.json");
        let bin_path = dir.join("ckpt.bin");
        save(&json_path, "quarterly", &state, &store).unwrap();
        save(&bin_path, "quarterly", &state, &store).unwrap();

        // The .bin file really is the binary format, and it is smaller.
        let raw = std::fs::read(&bin_path).unwrap();
        assert!(raw.starts_with(&BINARY_MAGIC));
        let json_len = std::fs::metadata(&json_path).unwrap().len();
        assert!((raw.len() as u64) < json_len,
                "binary ({} B) should beat JSON ({} B)", raw.len(), json_len);

        // Both load back to identical state + store.
        let (mut sj, mut stj) = fresh_pair();
        let (mut sb, mut stb) = fresh_pair();
        assert_eq!(load(&json_path, &mut sj, &mut stj).unwrap(), "quarterly");
        assert_eq!(load(&bin_path, &mut sb, &mut stb).unwrap(), "quarterly");
        assert_eq!(sj.tensors.len(), sb.tensors.len());
        for (name, t) in &sj.tensors {
            assert_eq!(t, &sb.tensors[name], "tensor `{name}` differs");
        }
        assert_eq!(stj.export(), stb.export());
    }

    #[test]
    fn load_model_state_from_both_formats() {
        let (state, store) = demo_pair();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_lms");
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["m.json", "m.bin"] {
            let path = dir.join(name);
            save(&path, "monthly", &state, &store).unwrap();
            let (freq, loaded) = load_model_state(&path).unwrap();
            assert_eq!(freq, "monthly");
            assert_eq!(loaded.tensors["params.rnn.w"].data,
                       vec![1.0, 2.0, 3.0, 4.0]);
            assert_eq!(loaded.tensors.len(), state.tensors.len());
        }
    }

    #[test]
    fn binary_rejects_truncation_and_bad_version() {
        let (state, store) = demo_pair();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        save(&path, "yearly", &state, &store).unwrap();
        let raw = std::fs::read(&path).unwrap();

        // Truncated: must error, not panic.
        let cut = dir.join("cut.bin");
        std::fs::write(&cut, &raw[..raw.len() / 2]).unwrap();
        let (mut s, mut st) = fresh_pair();
        assert!(load(&cut, &mut s, &mut st).is_err());

        // Future version: descriptive error.
        let mut bumped = raw.clone();
        bumped[8] = 0xFF;
        let vpath = dir.join("v255.bin");
        std::fs::write(&vpath, &bumped).unwrap();
        let (mut s, mut st) = fresh_pair();
        let err = load(&vpath, &mut s, &mut st).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn binary_decoder_survives_256_byte_mutations() {
        let (state, store) = demo_pair();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_fuzz");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        save_binary(&path, "quarterly", &state, &store).unwrap();
        let valid = std::fs::read(&path).unwrap();

        // Sanity: the unmutated bytes decode.
        let (mut s0, mut p0) = fresh_pair();
        assert!(load_binary_bytes(&valid, &mut s0, &mut p0).is_ok());

        let mut rng = crate::util::rng::Rng::new(4242);
        for case in 0..256 {
            let mutant: Vec<u8> = if case % 2 == 0 {
                // Truncation: every proper prefix must fail cleanly —
                // the parser consumes exactly the declared lengths, so a
                // shorter buffer always leaves some field unreadable.
                valid[..rng.below(valid.len())].to_vec()
            } else {
                // Header corruption: flip a byte of the version or
                // freq-length field. The decoder must reject these
                // (wrong version / shifted reads), never trust them.
                let mut m = valid.clone();
                m[8 + rng.below(8)] ^= (1 + rng.below(255)) as u8;
                m
            };
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let (mut state2, mut store2) = fresh_pair();
                    load_binary_bytes(&mutant, &mut state2, &mut store2)
                        .map(|_| ())
                }));
            match outcome {
                Ok(r) => assert!(
                    r.is_err(),
                    "mutation case {case} decoded successfully"),
                Err(_) => panic!("decoder panicked on mutation case {case}"),
            }
        }
    }

    #[test]
    fn size_mismatch_rejected() {
        let primers: Vec<Primer> = (0..2)
            .map(|_| Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0],
            })
            .collect();
        let state = ModelState { tensors: HashMap::new() };
        let store = ParamStore::from_primers(&primers, 1).unwrap();
        let dir = std::env::temp_dir().join("fast_esrnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();

        let bigger: Vec<Primer> = (0..5)
            .map(|_| Primer {
                alpha_logit: 0.0,
                gamma_logit: 0.0,
                gamma2_logit: 0.0,
                log_s_init: vec![0.0],
            })
            .collect();
        for name in ["ckpt.json", "ckpt.bin"] {
            let path = dir.join(name);
            save(&path, "yearly", &state, &store).unwrap();
            let mut state2 = ModelState { tensors: HashMap::new() };
            let mut store2 = ParamStore::from_primers(&bigger, 1).unwrap();
            assert!(load(&path, &mut state2, &mut store2).is_err(),
                    "{name} should reject a 5-series store");
        }
    }
}
