//! Training driver: the epoch loop that joins the per-series parameter
//! store, the batch scheduler and the backend's train-step program.
//!
//! One `Trainer` owns one frequency's model (paper §3: each frequency has
//! its own network). The loop is the paper's §3.3 procedure: classical
//! primer → joint gradient training of {RNN weights, per-series HW
//! parameters} → holdout evaluation, with LR drops and early stopping on
//! validation sMAPE. The trainer is backend-agnostic: it talks to any
//! [`Backend`] (native CPU by default, PJRT artifacts with the `pjrt`
//! feature) purely through manifest program/leaf names.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::config::{Frequency, NetworkConfig, TrainConfig, ALL_CATEGORIES};
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::store::ParamStore;
use crate::data::{split_corpus, Corpus, SplitSet};
use crate::hw;
use crate::metrics::{mase, smape, MetricAccumulator};
use crate::runtime::{execute_with_maps, Backend, HostTensor, Manifest};
use crate::telemetry::Telemetry;
use crate::util::rng::Rng;

/// Host-side model state: shared RNN weights and their Adam moments plus
/// the global step counter — everything in the train-step signature that
/// is NOT per-series or per-batch.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub tensors: HashMap<String, HostTensor>,
}

impl ModelState {
    /// Initialize from the backend's per-frequency `init` program.
    pub fn init(backend: &dyn Backend, freq: &str, seed: u64) -> Result<Self> {
        let rnn = backend.execute_init(freq, seed)?;
        let mut tensors = HashMap::new();
        for (name, t) in rnn {
            // `name` comes back as e.g. `rnn.cells.0.w`.
            tensors.insert(format!("opt.m.{name}"),
                           HostTensor::zeros(t.shape.clone()));
            tensors.insert(format!("opt.v.{name}"),
                           HostTensor::zeros(t.shape.clone()));
            tensors.insert(format!("params.{name}"), t);
        }
        tensors.insert("opt.step".into(), HostTensor::scalar(0.0));
        Ok(Self { tensors })
    }

    pub fn step(&self) -> f32 {
        self.tensors.get("opt.step").map(|t| t.data[0]).unwrap_or(0.0)
    }
}

/// Which holdout to score against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSplit {
    /// Forecast from the training window, score against the val block.
    Validation,
    /// Forecast from the refit window (shifted by H), score against test.
    Test,
}

/// Aggregated evaluation result.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub split: &'static str,
    pub count: usize,
    pub smape: f64,
    pub mase: f64,
    pub per_category: MetricAccumulator,
}

impl EvalReport {
    pub fn category_smape(&self, cat: &str) -> Option<f64> {
        self.per_category.mean_smape(cat)
    }
}

/// Full training-run record (feeds EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub freq: String,
    pub epochs_run: usize,
    pub epoch_losses: Vec<f32>,
    pub val_smape: Vec<f64>,
    pub best_epoch: usize,
    pub train_secs: f64,
    pub steps: usize,
    pub series: usize,
}

/// The per-frequency training coordinator.
pub struct Trainer<'e> {
    backend: &'e dyn Backend,
    pub freq: Frequency,
    pub net: NetworkConfig,
    pub set: SplitSet,
    pub store: ParamStore,
    pub state: ModelState,
    batcher: Batcher,
    pub opts: TrainConfig,
    pub telemetry: Telemetry,
    lr: f32,
    train_name: String,
    model_key: String,
    predict_batches: Vec<usize>,
}

impl<'e> Trainer<'e> {
    /// Build a trainer: equalize + split the corpus, prime the store,
    /// initialize RNN weights via the backend's `init` program.
    pub fn new(backend: &'e dyn Backend, freq: Frequency, corpus: &Corpus,
               opts: TrainConfig) -> Result<Self> {
        let net = NetworkConfig::for_freq(freq)?;
        // Model key: usually the frequency name; ablation variants (e.g.
        // "quarterly_pen", §8.4) share the frequency's shapes but bake
        // different loss terms into their artifacts.
        let key = opts
            .model_key
            .clone()
            .unwrap_or_else(|| freq.name().to_string());
        let mcfg = backend.manifest().config(&key)?;
        net.check_manifest(mcfg)?;

        let avail = backend.manifest().available_batches(&key, "train_step");
        if !avail.contains(&opts.batch_size) {
            bail!("no {key} train_step program for batch size {} (have {:?}); \
                   for PJRT, re-run `make artifacts` with --batch-sizes",
                  opts.batch_size, avail);
        }
        let set = split_corpus(corpus, &net)
            .with_context(|| format!("splitting {} corpus", freq.name()))?;
        if set.series.is_empty() {
            bail!("no {} series survive §5.2 equalization (need length ≥ {})",
                  freq.name(), net.min_series_length());
        }

        // §3.3 primer: classical seasonality decomposition per series
        // (dual decomposition for §8.2 configs), with a small jitter for
        // symmetry breaking.
        let mut rng = Rng::new(opts.seed ^ 0x5eed);
        let primers: Vec<hw::Primer> = set
            .series
            .iter()
            .map(|s| hw::primer_jittered(&s.train, net.seasonality,
                                         net.seasonality2, &mut rng))
            .collect();
        let store = ParamStore::from_primers_dual(
            &primers, net.seasonality, net.seasonality2)?;
        let state = ModelState::init(backend, &key, opts.seed)?;
        let batcher = Batcher::new(set.series.len(), opts.batch_size, opts.seed);

        // All available predict batch sizes: evaluation uses a greedy
        // mixed-size cover (§Perf) to minimize padded compute.
        let predict_batches = backend.manifest().available_batches(&key, "predict");
        if predict_batches.is_empty() {
            bail!("no predict programs for {key}");
        }

        let lr = opts.learning_rate;
        let train_name =
            Manifest::program_name(&key, opts.batch_size, "train_step");
        Ok(Self {
            backend,
            freq,
            net,
            set,
            store,
            state,
            batcher,
            opts,
            telemetry: Telemetry::new(),
            lr,
            train_name,
            model_key: key,
            predict_batches,
        })
    }

    pub fn series_count(&self) -> usize {
        self.set.series.len()
    }

    pub fn current_lr(&self) -> f32 {
        self.lr
    }

    /// Assemble the batch data tensors (y, category one-hot, mask).
    fn batch_data(&self, batch: &Batch, refit: bool) -> Result<HashMap<String, HostTensor>> {
        let b = batch.indices.len();
        let c = self.net.length;
        let mut y = Vec::with_capacity(b * c);
        let mut cat = Vec::with_capacity(b * 6);
        for &i in &batch.indices {
            let s = &self.set.series[i];
            y.extend_from_slice(if refit { &s.refit } else { &s.train });
            cat.extend_from_slice(&s.category_onehot);
        }
        let mut map = HashMap::with_capacity(4);
        map.insert("data.y".into(), HostTensor::new(vec![b, c], y)?);
        map.insert("data.cat".into(), HostTensor::new(vec![b, 6], cat)?);
        map.insert("data.mask".into(), HostTensor::new(vec![b], batch.mask_f32())?);
        Ok(map)
    }

    /// One optimizer step over one batch; returns the loss.
    pub fn train_step_batch(&mut self, batch: &Batch) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let mut inputs = self.batch_data(batch, false)?;
        inputs.extend(self.store.gather_batch(&batch.indices)?);
        inputs.insert("lr".into(), HostTensor::scalar(self.lr));
        self.telemetry.add_time("assemble", t0.elapsed().as_secs_f64());

        let outs = {
            let t1 = std::time::Instant::now();
            let outs = execute_with_maps(self.backend, &self.train_name,
                                         &inputs, &self.state.tensors)?;
            self.telemetry.add_time("train_step", t1.elapsed().as_secs_f64());
            outs
        };

        let t2 = std::time::Instant::now();
        let mut loss = f32::NAN;
        for (name, tensor) in outs {
            if name == "loss" {
                loss = tensor.data[0];
            } else if ParamStore::owns(&name) {
                self.store
                    .scatter(&name, &batch.indices, &batch.valid, &tensor)?;
            } else {
                self.state.tensors.insert(name, tensor);
            }
        }
        self.telemetry.add_time("writeback", t2.elapsed().as_secs_f64());
        if !loss.is_finite() {
            bail!("non-finite loss at step {} ({})", self.state.step(),
                  self.train_name);
        }
        Ok(loss)
    }

    /// One full epoch; returns mean batch loss.
    pub fn run_epoch(&mut self) -> Result<f32> {
        let batches = self.batcher.epoch();
        if batches.is_empty() {
            // Guard the mean below: 0/0 would silently report NaN loss.
            bail!("no batches scheduled for {} — the batcher produced an \
                   empty epoch (0 series?)", self.freq.name());
        }
        let mut acc = 0.0f64;
        for batch in &batches {
            acc += self.train_step_batch(batch)? as f64;
        }
        self.telemetry.incr("steps", batches.len() as u64);
        Ok((acc / batches.len() as f64) as f32)
    }

    /// Batched forecasts for every series (train or refit window).
    pub fn forecasts(&mut self, refit: bool) -> Result<Vec<Vec<f32>>> {
        let n = self.set.series.len();
        let h = self.net.horizon;
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(n);
        // The refit window starts H later than the train window the
        // per-series seasonality was learned on — rotate its phase(s)
        // by the raw time shift (the store mods per component).
        let rot = if refit { self.net.horizon } else { 0 };
        for batch in Batcher::greedy_cover(n, &self.predict_batches) {
            let name = Manifest::program_name(&self.model_key,
                                              batch.indices.len(), "predict");
            let mut inputs = self.batch_data(&batch, refit)?;
            inputs.extend(self.store.gather_batch_rotated(&batch.indices, rot)?);
            let t0 = std::time::Instant::now();
            let outs = execute_with_maps(self.backend, &name, &inputs,
                                         &self.state.tensors)?;
            self.telemetry.add_time("predict", t0.elapsed().as_secs_f64());
            let fc = &outs[0].1;
            for (slot, &valid) in batch.valid.iter().enumerate() {
                if valid {
                    out.push(fc.data[slot * h..(slot + 1) * h].to_vec());
                }
            }
        }
        Ok(out)
    }

    /// Score the model against a holdout block.
    pub fn evaluate(&mut self, split: EvalSplit) -> Result<EvalReport> {
        let refit = split == EvalSplit::Test;
        let forecasts = self.forecasts(refit)?;
        let mut per_category = MetricAccumulator::new();
        let (mut s_acc, mut m_acc) = (0.0f64, 0.0f64);
        for (i, fc) in forecasts.iter().enumerate() {
            let sp = &self.set.series[i];
            let actual = if refit { &sp.test } else { &sp.val };
            let s = smape(fc, actual);
            let m = mase(fc, actual, sp.mase_scale);
            s_acc += s;
            m_acc += m;
            per_category.add(ALL_CATEGORIES[sp.category_index].name(), s, m);
        }
        let n = forecasts.len();
        if n == 0 {
            // Guard the means below: 0/0 would propagate NaN sMAPE/MASE
            // into the early-stopping comparison and reports.
            bail!("evaluate({}): no forecasts produced for {} — empty \
                   series set", if refit { "test" } else { "val" },
                  self.freq.name());
        }
        Ok(EvalReport {
            split: if refit { "test" } else { "val" },
            count: n,
            smape: s_acc / n as f64,
            mase: m_acc / n as f64,
            per_category,
        })
    }

    /// The full §3.3 training loop with LR schedule and early stopping.
    pub fn train(&mut self, verbose: bool) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut epoch_losses = Vec::new();
        let mut val_smape = Vec::new();
        let mut best = (0usize, f64::INFINITY);
        for epoch in 0..self.opts.epochs {
            if self.opts.lr_drop_epochs.contains(&epoch) {
                self.lr *= self.opts.lr_decay;
            }
            let loss = self.run_epoch()?;
            epoch_losses.push(loss);
            let report = self.evaluate(EvalSplit::Validation)?;
            val_smape.push(report.smape);
            if verbose {
                println!(
                    "  [{}] epoch {:>2}: loss {:.5}  val sMAPE {:.3}  lr {:.2e}",
                    self.freq.name(), epoch, loss, report.smape, self.lr);
            }
            if report.smape < best.1 {
                best = (epoch, report.smape);
            } else if epoch - best.0 >= self.opts.patience {
                if verbose {
                    println!("  [{}] early stop at epoch {epoch} \
                              (best {} @ {:.3})",
                             self.freq.name(), best.0, best.1);
                }
                break;
            }
        }
        Ok(TrainReport {
            freq: self.freq.name().into(),
            epochs_run: epoch_losses.len(),
            epoch_losses,
            val_smape,
            best_epoch: best.0,
            train_secs: t0.elapsed().as_secs_f64(),
            steps: self.telemetry.counter("steps") as usize,
            series: self.set.series.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_split_flags() {
        assert_ne!(EvalSplit::Validation, EvalSplit::Test);
    }

    #[test]
    fn model_state_step_default() {
        let s = ModelState { tensors: HashMap::new() };
        assert_eq!(s.step(), 0.0);
    }
}
