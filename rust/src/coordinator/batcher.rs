//! Batch scheduler: shuffled fixed-size batches over the series pool.
//!
//! Artifact shapes are static, so every batch must be exactly `batch_size`
//! wide; the final partial batch is padded by repeating earlier indices
//! with `valid = false`, which zeroes their loss contribution in-graph
//! (via `data.mask`) and suppresses their scatter on the way out.

use crate::util::rng::Rng;

/// One scheduled batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Series indices, length = batch_size (may repeat for padding).
    pub indices: Vec<usize>,
    /// valid[i] == false marks a padded slot.
    pub valid: Vec<bool>,
}

impl Batch {
    pub fn mask_f32(&self) -> Vec<f32> {
        self.valid.iter().map(|&v| if v { 1.0 } else { 0.0 }).collect()
    }

    pub fn real_count(&self) -> usize {
        self.valid.iter().filter(|v| **v).count()
    }
}

/// Epoch-oriented scheduler.
#[derive(Debug)]
pub struct Batcher {
    n: usize,
    batch_size: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch_size: usize, seed: u64) -> Self {
        assert!(n > 0 && batch_size > 0);
        Self { n, batch_size, rng: Rng::new(seed) }
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.n.div_ceil(self.batch_size)
    }

    /// Produce one shuffled epoch of batches.
    pub fn epoch(&mut self) -> Vec<Batch> {
        let mut order: Vec<usize> = (0..self.n).collect();
        self.rng.shuffle(&mut order);
        let mut out = Vec::with_capacity(self.batches_per_epoch());
        for chunk in order.chunks(self.batch_size) {
            let mut indices = chunk.to_vec();
            let mut valid = vec![true; chunk.len()];
            // Pad the tail batch by cycling the epoch's own order.
            let mut fill = 0usize;
            while indices.len() < self.batch_size {
                indices.push(order[fill % order.len()]);
                valid.push(false);
                fill += 1;
            }
            out.push(Batch { indices, valid });
        }
        out
    }

    /// Deterministic, unshuffled cover of `0..n` (for evaluation passes).
    pub fn sequential(n: usize, batch_size: usize) -> Vec<Batch> {
        let order: Vec<usize> = (0..n).collect();
        let mut out = Vec::new();
        for chunk in order.chunks(batch_size) {
            let mut indices = chunk.to_vec();
            let mut valid = vec![true; chunk.len()];
            while indices.len() < batch_size {
                indices.push(0);
                valid.push(false);
            }
            out.push(Batch { indices, valid });
        }
        out
    }

    /// Greedy mixed-size cover of `0..n` using the compiled batch sizes
    /// (§Perf): pick the largest artifact that fits the remainder, so
    /// e.g. n = 82 with sizes {1, 16, 64, 256} becomes 64 + 16 + 1 + 1
    /// (zero padded slots) instead of one 256-wide call that wastes 68%
    /// of its compute on padding. Falls back to the smallest artifact ≥
    /// remainder (padded) when no exact fit exists.
    pub fn greedy_cover(n: usize, available: &[usize]) -> Vec<Batch> {
        assert!(!available.is_empty());
        let mut sizes = available.to_vec();
        sizes.sort_unstable();
        let mut out = Vec::new();
        let mut next = 0usize;
        while next < n {
            let remaining = n - next;
            let size = sizes
                .iter()
                .rev()
                .copied()
                .find(|s| *s <= remaining)
                // no artifact fits under the remainder: take the smallest
                // one that covers it and pad
                .unwrap_or_else(|| {
                    sizes.iter().copied().find(|s| *s >= remaining).unwrap()
                });
            let real = size.min(remaining);
            let mut indices: Vec<usize> = (next..next + real).collect();
            let mut valid = vec![true; real];
            while indices.len() < size {
                indices.push(0);
                valid.push(false);
            }
            out.push(Batch { indices, valid });
            next += real;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_every_series_exactly_once() {
        let mut b = Batcher::new(103, 16, 1);
        let batches = b.epoch();
        assert_eq!(batches.len(), 7);
        let mut seen = HashSet::new();
        let mut real = 0;
        for batch in &batches {
            assert_eq!(batch.indices.len(), 16);
            for (i, &idx) in batch.indices.iter().enumerate() {
                if batch.valid[i] {
                    assert!(seen.insert(idx), "series {idx} scheduled twice");
                    real += 1;
                }
            }
        }
        assert_eq!(real, 103);
        assert_eq!(seen.len(), 103);
    }

    #[test]
    fn partial_batch_is_padded_and_masked() {
        let mut b = Batcher::new(5, 4, 2);
        let batches = b.epoch();
        assert_eq!(batches.len(), 2);
        let tail = &batches[1];
        assert_eq!(tail.real_count(), 1);
        assert_eq!(tail.mask_f32().iter().sum::<f32>(), 1.0);
        assert_eq!(tail.indices.len(), 4);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut b = Batcher::new(64, 8, 3);
        let e1: Vec<usize> = b.epoch().iter().flat_map(|x| x.indices.clone()).collect();
        let e2: Vec<usize> = b.epoch().iter().flat_map(|x| x.indices.clone()).collect();
        assert_ne!(e1, e2, "epochs should be differently shuffled");
    }

    #[test]
    fn sequential_is_ordered() {
        let batches = Batcher::sequential(6, 4);
        assert_eq!(batches[0].indices, vec![0, 1, 2, 3]);
        assert_eq!(batches[1].indices[..2], [4, 5]);
        assert!(!batches[1].valid[2] && !batches[1].valid[3]);
    }

    #[test]
    fn exact_multiple_has_no_padding() {
        let mut b = Batcher::new(32, 8, 4);
        for batch in b.epoch() {
            assert_eq!(batch.real_count(), 8);
        }
    }
}
