//! Per-series parameter store — the paper's N × (2 + S) Holt-Winters
//! parameters (§3.3) plus their Adam moments.
//!
//! This is the coordination half of the paper's vectorization trick: the
//! artifact's train step sees per-series parameters as batch-dim tensor
//! slices; the store owns the *full* N-series tables on the host, gathers
//! the slices for each scheduled batch, and scatters the updated values
//! back after the step. Padded slots of a partial batch are never
//! scattered, so duplicate indices cannot clobber real parameters.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::hw::Primer;
use crate::runtime::HostTensor;

/// One per-series parameter table (value + Adam m/v), `width` floats per
/// series, laid out row-major `[n, width]`.
#[derive(Debug, Clone)]
struct Table {
    width: usize,
    value: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Table {
    fn new(n: usize, width: usize) -> Self {
        Self {
            width,
            value: vec![0.0; n * width],
            m: vec![0.0; n * width],
            v: vec![0.0; n * width],
        }
    }

    fn gather(&self, idx: &[usize], part: Part) -> Vec<f32> {
        let src = match part {
            Part::Value => &self.value,
            Part::M => &self.m,
            Part::V => &self.v,
        };
        let mut out = Vec::with_capacity(idx.len() * self.width);
        for &i in idx {
            out.extend_from_slice(&src[i * self.width..(i + 1) * self.width]);
        }
        out
    }

    fn scatter(&mut self, idx: &[usize], valid: &[bool], part: Part,
               data: &[f32]) {
        let dst = match part {
            Part::Value => &mut self.value,
            Part::M => &mut self.m,
            Part::V => &mut self.v,
        };
        for (slot, &i) in idx.iter().enumerate() {
            if !valid[slot] {
                continue;
            }
            dst[i * self.width..(i + 1) * self.width]
                .copy_from_slice(&data[slot * self.width..(slot + 1) * self.width]);
        }
    }
}

#[derive(Clone, Copy)]
enum Part {
    Value,
    M,
    V,
}

/// Parse a state leaf name into (table, part):
/// `params.series.alpha_logit` → (alpha, Value);
/// `opt.m.series.log_s_init`  → (s_init, M); etc.
fn parse_name(name: &str) -> Option<(&str, Part)> {
    if let Some(rest) = name.strip_prefix("params.series.") {
        Some((rest, Part::Value))
    } else if let Some(rest) = name.strip_prefix("opt.m.series.") {
        Some((rest, Part::M))
    } else if let Some(rest) = name.strip_prefix("opt.v.series.") {
        Some((rest, Part::V))
    } else {
        None
    }
}

/// The store: full-corpus tables for alpha/gamma logits and log initial
/// seasonality.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub n: usize,
    /// Primary seasonality width S1.
    pub seasonality: usize,
    /// §8.2 secondary seasonality width S2 (0 = single).
    pub seasonality2: usize,
    alpha: Table,
    gamma: Table,
    gamma2: Table,
    s_init: Table,
}

impl ParamStore {
    /// Initialize from per-series classical primers (§3.3).
    /// `seasonality` is the packed width S1 (+ S2 for §8.2 dual configs —
    /// use [`Self::from_primers_dual`] to record the split).
    pub fn from_primers(primers: &[Primer], seasonality: usize) -> Result<Self> {
        Self::from_primers_dual(primers, seasonality, 0)
    }

    /// Dual-seasonality constructor: the seasonality block packs
    /// `[S1 | S2]` per series and the refit rotation treats each
    /// component separately.
    pub fn from_primers_dual(primers: &[Primer], s1: usize, s2: usize)
                             -> Result<Self> {
        let n = primers.len();
        if n == 0 {
            bail!("empty primer list");
        }
        let width = s1 + s2;
        let mut store = Self {
            n,
            seasonality: s1,
            seasonality2: s2,
            alpha: Table::new(n, 1),
            gamma: Table::new(n, 1),
            gamma2: Table::new(n, 1),
            s_init: Table::new(n, width),
        };
        for (i, p) in primers.iter().enumerate() {
            if p.log_s_init.len() != width {
                bail!("primer {i}: {} seasonality values, expected {width}",
                      p.log_s_init.len());
            }
            store.alpha.value[i] = p.alpha_logit;
            store.gamma.value[i] = p.gamma_logit;
            store.gamma2.value[i] = p.gamma2_logit;
            store.s_init.value[i * width..(i + 1) * width]
                .copy_from_slice(&p.log_s_init);
        }
        Ok(store)
    }

    fn table(&self, key: &str) -> Option<&Table> {
        match key {
            "alpha_logit" => Some(&self.alpha),
            "gamma_logit" => Some(&self.gamma),
            "gamma2_logit" => Some(&self.gamma2),
            "log_s_init" => Some(&self.s_init),
            _ => None,
        }
    }

    fn table_mut(&mut self, key: &str) -> Option<&mut Table> {
        match key {
            "alpha_logit" => Some(&mut self.alpha),
            "gamma_logit" => Some(&mut self.gamma),
            "gamma2_logit" => Some(&mut self.gamma2),
            "log_s_init" => Some(&mut self.s_init),
            _ => None,
        }
    }

    /// Is this state-leaf name owned by the store?
    pub fn owns(name: &str) -> bool {
        parse_name(name).is_some()
    }

    /// Gather batch slices for every (table × part) combination, keyed by
    /// the manifest leaf names.
    pub fn gather_batch(&self, idx: &[usize]) -> Result<HashMap<String, HostTensor>> {
        self.gather_batch_rotated(idx, 0)
    }

    /// Like [`Self::gather_batch`] but rotates each series' initial
    /// seasonality left by a *time shift* of `rot` steps.
    ///
    /// Needed when forecasting from a window whose start is shifted by a
    /// non-multiple of the period relative to the training window (the
    /// Eq. 8 refit window shifts by H, and e.g. monthly H = 18 ≡ 6 mod
    /// S = 12): `log_s_init[k]` was learned for train-window phase k, so
    /// the shifted window must read phase (k + shift) mod S. For dual
    /// configs each packed component rotates by `rot` mod its own period.
    pub fn gather_batch_rotated(&self, idx: &[usize], rot: usize)
                                -> Result<HashMap<String, HostTensor>> {
        for &i in idx {
            if i >= self.n {
                bail!("series index {i} out of range (n={})", self.n);
            }
        }
        let b = idx.len();
        let mut out = HashMap::with_capacity(9);
        for (key, tbl) in [("alpha_logit", &self.alpha),
                           ("gamma_logit", &self.gamma),
                           ("gamma2_logit", &self.gamma2),
                           ("log_s_init", &self.s_init)] {
            // alpha/gamma are rank-1 [B]; log_s_init is always rank-2
            // [B, S], including the non-seasonal S = 1 case.
            let shape = if key == "log_s_init" {
                vec![b, tbl.width]
            } else {
                vec![b]
            };
            for (prefix, part) in [("params.series.", Part::Value),
                                   ("opt.m.series.", Part::M),
                                   ("opt.v.series.", Part::V)] {
                let mut data = tbl.gather(idx, part);
                if key == "log_s_init" && rot > 0 {
                    let (s1, s2) = (self.seasonality, self.seasonality2);
                    let (r1, r2) = (rot % s1.max(1),
                                    if s2 > 0 { rot % s2 } else { 0 });
                    if r1 > 0 || r2 > 0 {
                        for row in data.chunks_mut(tbl.width) {
                            row[..s1].rotate_left(r1);
                            if s2 > 0 {
                                row[s1..].rotate_left(r2);
                            }
                        }
                    }
                }
                out.insert(format!("{prefix}{key}"),
                           HostTensor::new(shape.clone(), data)?);
            }
        }
        Ok(out)
    }

    /// Scatter one updated batch tensor back. `valid[slot] == false`
    /// (padding) slots are ignored. Unknown names are an error — the
    /// caller routes only store-owned names here.
    pub fn scatter(&mut self, name: &str, idx: &[usize], valid: &[bool],
                   tensor: &HostTensor) -> Result<()> {
        let Some((key, part)) = parse_name(name) else {
            bail!("`{name}` is not a per-series leaf");
        };
        let width = {
            let Some(tbl) = self.table(key) else {
                bail!("unknown per-series table `{key}`");
            };
            tbl.width
        };
        if tensor.data.len() != idx.len() * width {
            bail!("scatter `{name}`: tensor has {} elems, batch needs {}",
                  tensor.data.len(), idx.len() * width);
        }
        self.table_mut(key).unwrap().scatter(idx, valid, part, &tensor.data);
        Ok(())
    }

    /// Read one series' effective smoothing parameters (for inspection).
    /// The seasonality vector is the full packed block (`S1 + S2` wide
    /// for §8.2 dual configs).
    pub fn series_params(&self, i: usize) -> (f32, f32, Vec<f32>) {
        let w = self.s_init.width;
        (
            self.alpha.value[i],
            self.gamma.value[i],
            self.s_init.value[i * w..(i + 1) * w].to_vec(),
        )
    }

    /// Total host memory of the store in floats (3 parts × 3 tables).
    pub fn float_count(&self) -> usize {
        3 * (self.alpha.value.len() + self.gamma.value.len()
             + self.s_init.value.len())
    }

    /// Flat export for checkpointing: (name, width, values).
    pub fn export(&self) -> Vec<(String, usize, Vec<f32>)> {
        let mut out = Vec::new();
        for (key, tbl) in [("alpha_logit", &self.alpha),
                           ("gamma_logit", &self.gamma),
                           ("gamma2_logit", &self.gamma2),
                           ("log_s_init", &self.s_init)] {
            out.push((format!("value.{key}"), tbl.width, tbl.value.clone()));
            out.push((format!("m.{key}"), tbl.width, tbl.m.clone()));
            out.push((format!("v.{key}"), tbl.width, tbl.v.clone()));
        }
        out
    }

    /// Restore from `export` output.
    pub fn import(&mut self, entries: &[(String, usize, Vec<f32>)]) -> Result<()> {
        for (name, _width, values) in entries {
            let (part_s, key) = name
                .split_once('.')
                .ok_or_else(|| anyhow::anyhow!("bad store entry `{name}`"))?;
            let tbl = self
                .table_mut(key)
                .ok_or_else(|| anyhow::anyhow!("unknown table `{key}`"))?;
            let dst = match part_s {
                "value" => &mut tbl.value,
                "m" => &mut tbl.m,
                "v" => &mut tbl.v,
                _ => bail!("bad part `{part_s}`"),
            };
            if dst.len() != values.len() {
                bail!("store entry `{name}`: {} values, expected {}",
                      values.len(), dst.len());
            }
            dst.copy_from_slice(values);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn primers(n: usize, s: usize) -> Vec<Primer> {
        (0..n)
            .map(|i| Primer {
                alpha_logit: i as f32,
                gamma_logit: -(i as f32),
                gamma2_logit: 0.0,
                log_s_init: (0..s).map(|k| (i * 10 + k) as f32).collect(),
            })
            .collect()
    }

    #[test]
    fn gather_pulls_correct_rows() {
        let store = ParamStore::from_primers(&primers(5, 3), 3).unwrap();
        let g = store.gather_batch(&[4, 0, 2]).unwrap();
        assert_eq!(g["params.series.alpha_logit"].data, vec![4.0, 0.0, 2.0]);
        assert_eq!(g["params.series.log_s_init"].shape, vec![3, 3]);
        assert_eq!(g["params.series.log_s_init"].data[0..3], [40.0, 41.0, 42.0]);
        // fresh Adam moments start at zero
        assert!(g["opt.m.series.alpha_logit"].data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn scatter_respects_padding_mask() {
        let mut store = ParamStore::from_primers(&primers(4, 1), 1).unwrap();
        // batch = [1, 2, 1] where slot 2 is padding duplicating series 1
        let idx = [1usize, 2, 1];
        let valid = [true, true, false];
        let t = HostTensor::new(vec![3], vec![100.0, 200.0, 999.0]).unwrap();
        store.scatter("params.series.alpha_logit", &idx, &valid, &t).unwrap();
        assert_eq!(store.series_params(1).0, 100.0); // not clobbered by 999
        assert_eq!(store.series_params(2).0, 200.0);
        assert_eq!(store.series_params(0).0, 0.0);
    }

    #[test]
    fn adam_moments_roundtrip() {
        let mut store = ParamStore::from_primers(&primers(3, 2), 2).unwrap();
        let idx = [0usize, 2];
        let valid = [true, true];
        let t = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        store.scatter("opt.v.series.log_s_init", &idx, &valid, &t).unwrap();
        let g = store.gather_batch(&[2]).unwrap();
        assert_eq!(g["opt.v.series.log_s_init"].data, vec![3.0, 4.0]);
    }

    #[test]
    fn ownership_and_errors() {
        assert!(ParamStore::owns("params.series.alpha_logit"));
        assert!(ParamStore::owns("opt.m.series.log_s_init"));
        assert!(!ParamStore::owns("params.rnn.cells.0.w"));
        assert!(!ParamStore::owns("opt.step"));
        let store = ParamStore::from_primers(&primers(2, 1), 1).unwrap();
        assert!(store.gather_batch(&[5]).is_err());
        let mut store = store;
        let bad = HostTensor::new(vec![1], vec![0.0]).unwrap();
        assert!(store
            .scatter("params.rnn.cells.0.w", &[0], &[true], &bad)
            .is_err());
    }

    #[test]
    fn export_import_roundtrip() {
        let mut a = ParamStore::from_primers(&primers(4, 2), 2).unwrap();
        let t = HostTensor::new(vec![1], vec![7.5]).unwrap();
        a.scatter("params.series.gamma_logit", &[3], &[true], &t).unwrap();
        let dump = a.export();
        let mut b = ParamStore::from_primers(&primers(4, 2), 2).unwrap();
        b.import(&dump).unwrap();
        assert_eq!(b.series_params(3).1, 7.5);
        assert_eq!(b.float_count(), a.float_count());
    }

    #[test]
    fn primer_width_mismatch_rejected() {
        assert!(ParamStore::from_primers(&primers(2, 3), 4).is_err());
    }
}
