//! The Layer-3 coordinator: the paper's system contribution in Rust.
//!
//! * [`store`] — the N × (2 + S) per-series Holt-Winters parameter store
//!   with batch gather/scatter (the vectorization trick's host side);
//! * [`batcher`] — shuffled fixed-size batch scheduling with padding masks;
//! * [`trainer`] — the joint-training epoch loop, evaluation and early
//!   stopping;
//! * [`checkpoint`] — JSON persistence of trained models.

pub mod batcher;
pub mod checkpoint;
pub mod store;
pub mod trainer;

pub use batcher::{Batch, Batcher};
pub use store::ParamStore;
pub use trainer::{EvalReport, EvalSplit, ModelState, TrainReport, Trainer};
