//! Persistent compute pool for the native backend's batch parallelism.
//!
//! Before this module, `run_train_step`/`run_predict` paid a fresh
//! `std::thread::scope` spawn (clone + stack map + join) on *every* call —
//! measurable overhead at small batches, exactly where the paper's §7
//! speedup-vs-batch-size curve says per-step costs dominate. The pool
//! spawns its workers once (lazily, on the first parallel call) and parks
//! them on a Mutex+Condvar — the same discipline as
//! [`crate::forecast::pool`] — so the steady-state hot path performs zero
//! thread spawns.
//!
//! ## Handoff protocol
//!
//! A call to [`ComputePool::run`] publishes one *task* — a borrowed
//! `Fn(chunk, participant)` closure — plus a chunk count `total` and a
//! participant count `stride = min(threads, total)` under the shared
//! mutex, bumps a generation counter (`epoch`) and wakes every worker.
//! Chunk assignment is **static**: participant `p` executes chunks
//! `p, p + stride, p + 2·stride, …` (participant 0 is the caller itself —
//! it never idles while workers compute). Each completed chunk increments
//! `done`; the caller sleeps on a second condvar until `done == total`,
//! then unpublishes the task. The closure reference is type-erased to a
//! raw pointer so it can sit in the shared state without infecting the
//! pool with a lifetime; this is sound because `run` does not return
//! until `done == total`, and `done` is incremented strictly *after* the
//! closure call returns — no worker can hold the pointer past the `run`
//! stack frame that owns the closure. Workers snapshot the task pointer
//! and the epoch in the same lock acquisition, so a straggler that slept
//! through a chunk-less round cannot re-enter a later round twice.
//!
//! Static assignment (rather than a work-stealing cursor) is a deliberate
//! trade: the backend's chunks are near-equal by construction
//! (`chunks_into`), so stealing buys little, and a *deterministic*
//! participant set is what makes the zero-allocation steady state
//! provable — every per-participant scratch arena reaches its high-water
//! mark on the first call with a given shape, instead of whenever the
//! scheduler happens to let that worker win a claim race.
//!
//! ## Determinism
//!
//! Chunk `i` is always the same slice of the batch *and* always runs on
//! participant `i % stride` (same scratch arena); the caller merges chunk
//! results in ascending chunk order after `run` returns. Numerics are
//! therefore invariant to thread scheduling — bit-identical to the old
//! scoped-spawn path for a given thread count.
//!
//! ## Panic containment
//!
//! Worker closures run under `catch_unwind`; the first panic payload is
//! stashed in the shared state and re-raised on the *caller* after the
//! round drains. Workers themselves never unwind out of their park loop,
//! so one poisoned step cannot deadlock or kill the pool for subsequent
//! calls (covered by `rust/tests/steady_state.rs`).

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// How the pool executes a parallel round — [`PoolMode::Persistent`] is
/// the production path; [`PoolMode::SpawnPerCall`] reproduces the old
/// scope-per-call behavior so BENCH_6 can measure the spawn overhead as a
/// same-binary A/B.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolMode {
    /// Workers are spawned once and parked between calls (zero spawns in
    /// steady state).
    Persistent,
    /// Every call spawns scoped workers and joins them (the pre-pool
    /// behavior, kept for benchmarking the difference).
    SpawnPerCall,
}

/// Type-erased reference to the caller's task closure. Only ever
/// dereferenced between task publication and `done == total` — i.e.
/// strictly within the lifetime of the `run` call that owns the closure.
struct TaskRef(*const (dyn Fn(usize, usize) + Sync));

// SAFETY: sending `TaskRef` to worker threads is sound because
// (1) the pointee is `Sync`, so shared `&`-calls from any number of
//     threads are permitted — the pool only ever calls through
//     `&dyn Fn`, it never moves out of or mutates the closure;
// (2) every dereference is bracketed by the publishing `run_pooled`
//     frame: `run_strided` increments `done` strictly *after* the call
//     through the pointer returns, and `run_pooled` blocks on the `done`
//     condvar until `done == total` before unpublishing the task and
//     returning — so no worker can touch the pointer once the closure's
//     owning stack frame is gone;
// (3) a straggler from an earlier round cannot observe a stale pointer:
//     `worker_loop` snapshots the task pointer and the epoch under one
//     lock acquisition, and the publish in `run_pooled` writes both in
//     one critical section.
unsafe impl Send for TaskRef {}

struct State {
    /// Generation counter: workers sleep until `epoch` moves past the
    /// last round they participated in.
    epoch: u64,
    /// The published task for the current round, if any.
    task: Option<TaskRef>,
    /// Total chunks in the current round.
    total: usize,
    /// Participants this round (`min(threads, total)`); the static
    /// chunk→participant stride.
    stride: usize,
    /// Chunks whose closure call has returned (or panicked).
    done: usize,
    /// First panic payload captured this round.
    panic: Option<Box<dyn Any + Send>>,
    /// Set once on drop; wakes workers for exit.
    shutdown: bool,
    /// Worker threads actually spawned (lazy).
    spawned: usize,
}

struct Shared {
    // lint:lock-name(pool.state)
    state: Mutex<State>,
    /// Workers wait here for a new epoch (or shutdown).
    work: Condvar,
    /// The caller waits here for `done == total`.
    done: Condvar,
}

/// Persistent worker pool executing chunked data-parallel rounds.
pub struct ComputePool {
    threads: usize,
    mode: PoolMode,
    shared: Arc<Shared>,
    // lint:lock-name(pool.handles)
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Worker threads spawned since construction ([`BackendStats::spawns`]
    /// feeds from this; steady state must not move it).
    ///
    /// [`BackendStats::spawns`]: crate::runtime::backend::BackendStats
    spawns: AtomicU64,
    /// Serializes concurrent `run` callers: the epoch/stride protocol
    /// handles one round at a time. Uncontended in every current caller
    /// (the backend's step/predict scratch mutexes already serialize).
    // lint:lock-name(pool.run_lock)
    run_lock: Mutex<()>,
}

impl ComputePool {
    /// Pool that will use up to `threads` participants per round (the
    /// caller plus `threads - 1` parked workers), in persistent mode.
    pub fn new(threads: usize) -> Self {
        Self::with_mode(threads, PoolMode::Persistent)
    }

    /// Pool with an explicit execution mode (benches construct
    /// [`PoolMode::SpawnPerCall`] for the A/B).
    pub fn with_mode(threads: usize, mode: PoolMode) -> Self {
        Self {
            threads: threads.max(1),
            mode,
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    epoch: 0,
                    task: None,
                    total: 0,
                    stride: 0,
                    done: 0,
                    panic: None,
                    shutdown: false,
                    spawned: 0,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
            spawns: AtomicU64::new(0),
            run_lock: Mutex::new(()),
        }
    }

    /// Participant budget (caller + parked workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn mode(&self) -> PoolMode {
        self.mode
    }

    /// Worker threads spawned over the pool's lifetime. Persistent mode
    /// plateaus at `threads - 1` after the first parallel call; spawn
    /// mode grows on every call — the gap is what BENCH_6 gates on.
    pub fn spawns(&self) -> u64 {
        self.spawns.load(Ordering::Relaxed)
    }

    /// Execute `f(chunk, participant)` for every `chunk in 0..n`.
    ///
    /// `participant` identifies the executing thread (0 = caller,
    /// `1..threads` = pool workers), indexes the backend's per-thread
    /// scratch arenas, and is a *static* function of the chunk:
    /// `participant = chunk % min(threads, n)`. Chunks may complete in
    /// any order; callers must merge per-chunk results in ascending chunk
    /// order afterwards for deterministic numerics.
    ///
    /// Panics from `f` are captured and re-raised on the caller after the
    /// round completes; the pool remains usable.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.threads == 1 || n == 1 {
            // Sequential fast path — both modes agree, nothing to hand off.
            for i in 0..n {
                f(i, 0);
            }
            return;
        }
        match self.mode {
            PoolMode::Persistent => self.run_pooled(n, f),
            PoolMode::SpawnPerCall => self.run_spawning(n, f),
        }
    }

    fn run_pooled(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        let _round = self.run_lock.lock().unwrap();
        self.ensure_spawned();
        let stride = self.threads.min(n);
        let task: *const (dyn Fn(usize, usize) + Sync) = f;
        {
            let mut st = self.shared.state.lock().unwrap();
            st.task = Some(TaskRef(task));
            st.total = n;
            st.stride = stride;
            st.done = 0;
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        // The caller is participant 0: execute its strided share rather
        // than blocking immediately.
        run_strided(&self.shared, task, n, stride, 0);
        let payload = {
            let mut st = self.shared.state.lock().unwrap();
            while st.done < st.total {
                st = self.shared.done.wait(st).unwrap();
            }
            st.task = None;
            st.panic.take()
        };
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    /// The pre-pool behavior: scoped spawn + join per call, same static
    /// chunk assignment so the two modes stay numerically identical and
    /// use the same per-participant scratch arenas.
    fn run_spawning(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        let _round = self.run_lock.lock().unwrap();
        let stride = self.threads.min(n);
        self.spawns
            .fetch_add(stride.saturating_sub(1) as u64, Ordering::Relaxed);
        std::thread::scope(|sc| {
            for participant in 1..stride {
                sc.spawn(move || {
                    let mut i = participant;
                    while i < n {
                        f(i, participant);
                        i += stride;
                    }
                });
            }
            let mut i = 0;
            while i < n {
                f(i, 0);
                i += stride;
            }
        });
    }

    /// Spawn the parked workers on first use (participants `1..threads`).
    fn ensure_spawned(&self) {
        let need = {
            let st = self.shared.state.lock().unwrap();
            st.spawned < self.threads - 1
        };
        if !need {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        while st.spawned < self.threads - 1 {
            st.spawned += 1;
            let participant = st.spawned;
            let shared = Arc::clone(&self.shared);
            self.spawns.fetch_add(1, Ordering::Relaxed);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("native-compute-{participant}"))
                    .spawn(move || worker_loop(&shared, participant))
                    .expect("spawn native compute worker"),
            );
        }
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

/// Parked worker: wake on a new epoch, run the strided share, park again.
/// The task pointer and the epoch are snapshotted under one lock
/// acquisition, so a worker can never observe round N's epoch with round
/// N+1's task (or vice versa) and double-execute.
fn worker_loop(shared: &Shared, participant: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let (task, total, stride) = {
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown && (st.epoch == seen_epoch || st.task.is_none()) {
                st = shared.work.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen_epoch = st.epoch;
            let ptr = match &st.task {
                Some(TaskRef(p)) => *p,
                None => unreachable!("wait loop requires a published task"),
            };
            (ptr, st.total, st.stride)
        };
        run_strided(shared, task, total, stride, participant);
    }
}

/// Execute participant `p`'s static share of the round: chunks
/// `p, p + stride, …` below `total`. Shared by pool workers and the
/// caller (participant 0).
fn run_strided(shared: &Shared, task: *const (dyn Fn(usize, usize) + Sync),
               total: usize, stride: usize, participant: usize) {
    if participant >= stride {
        return;
    }
    let mut i = participant;
    while i < total {
        // SAFETY: `task` was published by a `run_pooled` frame that cannot
        // return until `done == total`, and this chunk's `done` increment
        // happens only below, strictly after the call returns — the
        // pointee (the caller's borrowed closure) is alive for the whole
        // call. The pointee is `Sync`, so concurrent `&`-calls from other
        // participants are fine.
        let call = || unsafe { (*task)(i, participant) };
        let result = catch_unwind(AssertUnwindSafe(call));
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            // Keep the first payload; later panics in the same round are
            // almost certainly the same root cause.
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.done += 1;
        if st.done >= st.total {
            shared.done.notify_all();
        }
        drop(st);
        i += stride;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ComputePool::new(4);
        let hits = (0..37).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        pool.run(37, &|i, _p| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn spawns_plateau_in_persistent_mode() {
        let pool = ComputePool::new(3);
        assert_eq!(pool.spawns(), 0, "lazy: no spawns before first run");
        for _ in 0..5 {
            pool.run(8, &|_i, _p| {});
        }
        assert_eq!(pool.spawns(), 2, "threads-1 workers, spawned once");
    }

    #[test]
    fn spawn_per_call_mode_counts_every_round() {
        let pool = ComputePool::with_mode(3, PoolMode::SpawnPerCall);
        for _ in 0..4 {
            pool.run(8, &|_i, _p| {});
        }
        assert_eq!(pool.spawns(), 8, "2 workers per round x 4 rounds");
    }

    #[test]
    fn sequential_paths_never_spawn() {
        let single = ComputePool::new(1);
        single.run(16, &|_i, _p| {});
        assert_eq!(single.spawns(), 0);
        let pool = ComputePool::new(8);
        pool.run(1, &|_i, _p| {});
        assert_eq!(pool.spawns(), 0, "n == 1 runs inline on the caller");
    }

    #[test]
    fn chunk_assignment_is_static_and_in_range() {
        // Both modes must map chunk i to participant i % min(threads, n):
        // the backend's per-participant arenas rely on this for
        // deterministic growth (and bitwise-stable scratch assignment).
        for mode in [PoolMode::Persistent, PoolMode::SpawnPerCall] {
            let pool = ComputePool::with_mode(4, mode);
            let owner: Vec<AtomicUsize> =
                (0..64).map(|_| AtomicUsize::new(usize::MAX)).collect();
            pool.run(64, &|i, p| {
                owner[i].store(p, Ordering::Relaxed);
            });
            for (i, o) in owner.iter().enumerate() {
                assert_eq!(o.load(Ordering::Relaxed), i % 4,
                           "chunk {i} ran on the wrong participant \
                            ({mode:?})");
            }
        }
    }
}
