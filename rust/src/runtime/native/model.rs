//! The native backend's compute core: per-series forward pass and
//! hand-written reverse-mode backward through the full ES-RNN graph.
//!
//! This mirrors, operation for operation, the JAX graph in
//! `python/compile/model.py`, covering both the single-seasonality path
//! and the §8.2 dual-seasonality (hourly 24h×168h) path:
//!
//!   ES recurrence ([`hw::es_filter`] / [`hw::es_dual_filter`], Eqs. 1/3)
//!   → seasonality extension (product of per-component tails for dual
//!   configs, Gould et al. 2008) → per-position log-normalized windows
//!   (Fig. 2) → dilated-residual LSTM stack with ring-buffer state
//!   (Fig. 1) → tanh dense + linear head → masked pinball loss (§3.5) →
//!   gradients → Adam with the per-series learning-rate multiplier (§3.3).
//!
//! The backward pass was derived by hand and validated against central
//! finite differences (see `rust/tests/native_backend.rs`); the recurrence
//! gradient ordering invariants — including the coupled dual-recurrence
//! one — are documented inline at the ES backward loop. Everything here is
//! one-series-at-a-time — the batch dimension is parallelized by the
//! caller ([`super::NativeBackend`]) across std threads.

use anyhow::Result;

use crate::config::{valid_window_positions, window_positions};
use crate::hw;

/// Adam hyper-parameters baked into the train-step graph (mirror of
/// `python/compile/configs.py`).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Numeric floor inside the log-normalization (mirror of `model.py::EPS`).
/// Shared with the lane-vectorized kernels in [`super::lanes`].
pub(crate) const EPS: f32 = 1e-8;

// ---- buffer-reuse helpers (shared with `super::lanes`) ----
//
// The distinction between these is load-bearing for the zero-allocation
// arenas. `set_len` keeps stale contents and is only sound for buffers
// where every read is preceded by a store at the same index this call;
// `set_zeroed` is for accumulators and sparse-write buffers where stale
// data from a previous step would leak into the numerics. Each call site
// in this module and in `super::lanes` picked one of the two based on an
// audit of the buffer's read/write pattern (see DESIGN.md §Steady-state
// memory & thread reuse).

/// Resize without clearing: grows with zeros, keeps existing (stale)
/// prefix. Only for fully-overwritten buffers.
pub(crate) fn set_len(v: &mut Vec<f32>, n: usize) {
    v.resize(n, 0.0);
}

/// Clear and refill with zeros (accumulators, sparse writes).
pub(crate) fn set_zeroed(v: &mut Vec<f32>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

/// Clear and refill with `val` (e.g. padding-lane `y ≡ 1.0`).
pub(crate) fn set_filled(v: &mut Vec<f32>, n: usize, val: f32) {
    v.clear();
    v.resize(n, val);
}

/// Reset per-layer ring buffers to `dims[i] * inner` zeros each. Grows the
/// outer vec but never shrinks it, so a worker alternating between
/// frequencies with different layer counts keeps every ring's capacity;
/// rings past `dims.len()` are simply unused.
pub(crate) fn ring_reset(rings: &mut Vec<Vec<f32>>, dims: &[usize],
                         inner: usize) {
    while rings.len() < dims.len() {
        rings.push(Vec::new());
    }
    for (r, &d) in rings.iter_mut().zip(dims) {
        r.clear();
        r.resize(d * inner, 0.0);
    }
}

/// Clear and refill with `true` (the log-clamp OK flags default to true
/// and are flipped to false where the clamp fires).
pub(crate) fn refill_bool(v: &mut Vec<bool>, n: usize) {
    v.clear();
    v.resize(n, true);
}

/// Static shape of one frequency's compute graph.
#[derive(Debug, Clone)]
pub struct Shape {
    pub c: usize,
    /// Primary seasonal period S1.
    pub s: usize,
    /// §8.2 secondary seasonal period S2 (0 = single-seasonality).
    pub s2: usize,
    pub h: usize,
    pub in_w: usize,
    pub p: usize,
    pub hidden: usize,
    pub din0: usize,
    /// Dilation blocks (residual connections skip all but the first).
    pub blocks: Vec<Vec<usize>>,
    /// Flattened dilations, one per LSTM layer.
    pub flat: Vec<usize>,
    /// Input dimension per layer.
    pub layer_din: Vec<usize>,
    pub seasonal: bool,
    pub valid_positions: usize,
}

impl Shape {
    #[allow(clippy::too_many_arguments)]
    pub fn new(seasonality: usize, seasonality2: usize, horizon: usize,
               input_window: usize, length: usize, hidden: usize,
               dilations: &[Vec<usize>], n_categories: usize) -> Result<Self> {
        // Checked window counts (shared guards with `NetworkConfig`): a
        // series shorter than the window (or window + horizon) is a
        // descriptive error, not a usize wrap/panic.
        let p = window_positions(length, input_window)?;
        let valid_positions =
            valid_window_positions(length, input_window, horizon)?;
        let flat: Vec<usize> = dilations.iter().flatten().copied().collect();
        let din0 = input_window + n_categories;
        let mut layer_din = Vec::with_capacity(flat.len());
        let mut din = din0;
        for _ in &flat {
            layer_din.push(din);
            din = hidden;
        }
        Ok(Self {
            c: length,
            s: seasonality,
            s2: seasonality2,
            h: horizon,
            in_w: input_window,
            p,
            hidden,
            din0,
            blocks: dilations.to_vec(),
            flat,
            layer_din,
            seasonal: seasonality > 1,
            valid_positions,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.flat.len()
    }

    /// §8.2 dual-seasonality mode.
    pub fn dual(&self) -> bool {
        self.s2 > 0
    }

    /// Width of the packed `[S1 | S2]` per-series seasonality block.
    pub fn s_total(&self) -> usize {
        self.s + self.s2
    }
}

/// Borrowed view of the shared RNN weights (row-major slices).
#[derive(Clone, Copy)]
pub struct RnnView<'a> {
    /// Per layer: (w `[din+hid, 4*hid]`, b `[4*hid]`).
    pub cells: &'a [(&'a [f32], &'a [f32])],
    pub dense_w: &'a [f32],
    pub dense_b: &'a [f32],
    pub out_w: &'a [f32],
    pub out_b: &'a [f32],
}

/// Accumulated gradients for the shared RNN weights.
pub struct RnnGrads {
    pub cells: Vec<(Vec<f32>, Vec<f32>)>,
    pub dense_w: Vec<f32>,
    pub dense_b: Vec<f32>,
    pub out_w: Vec<f32>,
    pub out_b: Vec<f32>,
}

impl RnnGrads {
    /// Unsized accumulator; call [`RnnGrads::reset`] before use.
    pub fn empty() -> Self {
        Self {
            cells: Vec::new(),
            dense_w: Vec::new(),
            dense_b: Vec::new(),
            out_w: Vec::new(),
            out_b: Vec::new(),
        }
    }

    /// Size for `shape` and zero every leaf, reusing existing capacity.
    /// The outer `cells` vec only grows (a worker alternating between
    /// frequencies keeps each layer's capacity); layers past the current
    /// shape's count are stale and never read — every consumer indexes by
    /// the current shape's layers.
    pub fn reset(&mut self, shape: &Shape) {
        let hid = shape.hidden;
        while self.cells.len() < shape.n_layers() {
            self.cells.push((Vec::new(), Vec::new()));
        }
        for (li, &din) in shape.layer_din.iter().enumerate() {
            let (gw, gb) = &mut self.cells[li];
            set_zeroed(gw, (din + hid) * 4 * hid);
            set_zeroed(gb, 4 * hid);
        }
        set_zeroed(&mut self.dense_w, hid * hid);
        set_zeroed(&mut self.dense_b, hid);
        set_zeroed(&mut self.out_w, hid * shape.h);
        set_zeroed(&mut self.out_b, shape.h);
    }

    pub fn zeros(shape: &Shape) -> Self {
        let mut g = Self::empty();
        g.reset(shape);
        g
    }

    /// Retained heap footprint (for `BackendStats::scratch_bytes`).
    pub fn bytes(&self) -> u64 {
        let cells: usize = self
            .cells
            .iter()
            .map(|(w, b)| w.capacity() + b.capacity())
            .sum();
        4 * (cells + self.dense_w.capacity() + self.dense_b.capacity()
             + self.out_w.capacity() + self.out_b.capacity()) as u64
    }

    pub fn merge(&mut self, other: &RnnGrads) {
        fn add(dst: &mut [f32], src: &[f32]) {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            add(&mut a.0, &b.0);
            add(&mut a.1, &b.1);
        }
        add(&mut self.dense_w, &other.dense_w);
        add(&mut self.dense_b, &other.dense_b);
        add(&mut self.out_w, &other.out_w);
        add(&mut self.out_b, &other.out_b);
    }
}

impl Default for RnnGrads {
    fn default() -> Self {
        Self::empty()
    }
}

/// Gradients for one series' Holt-Winters parameters. `log_s_init` is the
/// full packed `[S1 | S2]` block; `gamma2_logit` stays 0 for single
/// configs.
#[derive(Debug, Clone)]
pub struct SeriesGrads {
    pub alpha_logit: f32,
    pub gamma_logit: f32,
    pub gamma2_logit: f32,
    pub log_s_init: Vec<f32>,
}

impl SeriesGrads {
    /// `s_total` is the packed seasonality width (S1 + S2).
    pub fn zeros(s_total: usize) -> Self {
        Self {
            alpha_logit: 0.0,
            gamma_logit: 0.0,
            gamma2_logit: 0.0,
            log_s_init: vec![0.0; s_total],
        }
    }
}

/// One series' Holt-Winters parameters in stored (logit/log) space.
/// `log_s_init` packs `[S1 | S2]`; `gamma2_logit` is ignored for single
/// configs.
#[derive(Clone, Copy)]
pub struct HwView<'a> {
    pub alpha_logit: f32,
    pub gamma_logit: f32,
    pub gamma2_logit: f32,
    pub log_s_init: &'a [f32],
}

/// Everything the forward pass records for one series: outputs plus the
/// activation tape the backward pass replays.
pub struct Forward {
    pub levels: Vec<f32>,
    /// Primary seasonal track `[C+S1]`.
    pub seas: Vec<f32>,
    /// §8.2 secondary seasonal track `[C+S2]` (empty for single configs).
    pub seas2: Vec<f32>,
    /// Combined multiplicative seasonality over `[C+H]`: the per-step
    /// product of the components, with each component's tail tiled from
    /// its own final period past C.
    pub seas_ext: Vec<f32>,
    pub alpha: f32,
    pub gamma: f32,
    pub gamma2: f32,
    pub s_init: Vec<f32>,
    pub s2_init: Vec<f32>,
    /// Log-normalized input windows `[P, in_w]`.
    pub x: Vec<f32>,
    /// Log-normalized targets `[P, H]` (empty unless `want_targets`).
    pub z: Vec<f32>,
    /// `false` where the log's EPS clamp fired (gradient is zero there).
    pub x_ok: Vec<bool>,
    pub z_ok: Vec<bool>,
    /// Head output `[P, H]` in normalized log space.
    pub out: Vec<f32>,
    // ---- tape (indexed [p][layer][k], flattened) ----
    x_in: Vec<f32>,
    h_prev: Vec<f32>,
    c_prev: Vec<f32>,
    si: Vec<f32>,
    sf: Vec<f32>,
    tg: Vec<f32>,
    so: Vec<f32>,
    tanh_c: Vec<f32>,
    h_seq: Vec<f32>,
    act: Vec<f32>,
    din_max: usize,
}

impl Forward {
    /// Unsized tape; populated by [`ScalarScratch::forward`].
    pub fn empty() -> Self {
        Self {
            levels: Vec::new(),
            seas: Vec::new(),
            seas2: Vec::new(),
            seas_ext: Vec::new(),
            alpha: 0.0,
            gamma: 0.0,
            gamma2: 0.0,
            s_init: Vec::new(),
            s2_init: Vec::new(),
            x: Vec::new(),
            z: Vec::new(),
            x_ok: Vec::new(),
            z_ok: Vec::new(),
            out: Vec::new(),
            x_in: Vec::new(),
            h_prev: Vec::new(),
            c_prev: Vec::new(),
            si: Vec::new(),
            sf: Vec::new(),
            tg: Vec::new(),
            so: Vec::new(),
            tanh_c: Vec::new(),
            h_seq: Vec::new(),
            act: Vec::new(),
            din_max: 0,
        }
    }

    /// Retained heap footprint (for `BackendStats::scratch_bytes`).
    pub fn bytes(&self) -> u64 {
        let f32s = self.levels.capacity() + self.seas.capacity()
            + self.seas2.capacity() + self.seas_ext.capacity()
            + self.s_init.capacity() + self.s2_init.capacity()
            + self.x.capacity() + self.z.capacity() + self.out.capacity()
            + self.x_in.capacity() + self.h_prev.capacity()
            + self.c_prev.capacity() + self.si.capacity()
            + self.sf.capacity() + self.tg.capacity() + self.so.capacity()
            + self.tanh_c.capacity() + self.h_seq.capacity()
            + self.act.capacity();
        (4 * f32s + self.x_ok.capacity() + self.z_ok.capacity()) as u64
    }
}

impl Default for Forward {
    fn default() -> Self {
        Self::empty()
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// `out[j] += Σ_i x[i] * w[i*cols + j]` for the given row range of `w`.
fn vec_mat_acc(x: &[f32], w: &[f32], row_offset: usize, cols: usize,
               out: &mut [f32]) {
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

/// `gw[(row_offset+i)*cols + j] += x[i] * dz[j]`.
fn outer_acc(x: &[f32], dz: &[f32], row_offset: usize, cols: usize,
             gw: &mut [f32]) {
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &mut gw[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        for (g, &d) in row.iter_mut().zip(dz) {
            *g += xi * d;
        }
    }
}

/// `out[i] = Σ_j w[(row_offset+i)*cols + j] * dz[j]` (transpose mat-vec).
fn mat_t_vec(w: &[f32], dz: &[f32], row_offset: usize, rows: usize,
             cols: usize, out: &mut [f32]) {
    for (i, o) in out.iter_mut().enumerate().take(rows) {
        let row = &w[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        let mut acc = 0.0f32;
        for (&wv, &d) in row.iter().zip(dz) {
            acc += wv * d;
        }
        *o = acc;
    }
}

/// Full forward pass for one series.
///
/// `y` has length C, `cat` length 6 (one-hot). Per-series parameters come
/// in logit/log space exactly as stored by the [`crate::coordinator::ParamStore`],
/// bundled in an [`HwView`] (dual configs carry `gamma2_logit` and a
/// packed `[S1 | S2]` seasonality block).
pub fn forward_series(shape: &Shape, y: &[f32], cat: &[f32], rnn: &RnnView,
                      hwp: HwView, want_targets: bool) -> Forward {
    let mut scratch = ScalarScratch::new();
    scratch.forward(shape, y, cat, rnn, hwp, want_targets);
    scratch.fwd
}

/// Reusable per-worker arena for the scalar path: owns a [`Forward`] tape
/// plus every temporary [`forward_series`] needs, so a warm worker runs
/// the whole forward pass without touching the heap. Buffers are grown on
/// first use (or on a shape change) and reused thereafter; the numeric
/// sequence is identical to the fresh-allocation path.
#[derive(Default)]
pub struct ScalarScratch {
    /// The forward tape, readable after [`ScalarScratch::forward`].
    pub fwd: Forward,
    h_ring: Vec<Vec<f32>>,
    c_ring: Vec<Vec<f32>>,
    feat: Vec<f32>,
    zbuf: Vec<f32>,
    h_in: Vec<f32>,
    block_in: Vec<f32>,
    pre: Vec<f32>,
    obuf: Vec<f32>,
}

impl ScalarScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// [`forward_series`] against pooled storage; results land in
    /// `self.fwd`. Buffers classified `set_len` are fully overwritten
    /// below (or have every read bounded by a preceding store, like the
    /// `din..din_max` tail of `x_in`); accumulator-like buffers use
    /// `set_zeroed`/`refill_bool`.
    pub fn forward(&mut self, shape: &Shape, y: &[f32], cat: &[f32],
                   rnn: &RnnView, hwp: HwView, want_targets: bool) {
        let (c, s, h, in_w, p_n) =
            (shape.c, shape.s, shape.h, shape.in_w, shape.p);
        let s2 = shape.s2;
        let dual = shape.dual();
        let hid = shape.hidden;
        let n_l = shape.n_layers();
        let din_max = shape.din0.max(hid);

        let fwd = &mut self.fwd;
        fwd.din_max = din_max;
        fwd.alpha = sigmoid(hwp.alpha_logit);
        if shape.seasonal {
            fwd.gamma = sigmoid(hwp.gamma_logit);
            fwd.s_init.clear();
            fwd.s_init.extend(hwp.log_s_init[..s].iter().map(|v| v.exp()));
        } else {
            fwd.gamma = 0.0;
            set_filled(&mut fwd.s_init, s, 1.0);
        }
        if dual {
            fwd.gamma2 = sigmoid(hwp.gamma2_logit);
            fwd.s2_init.clear();
            fwd.s2_init
                .extend(hwp.log_s_init[s..s + s2].iter().map(|v| v.exp()));
        } else {
            fwd.gamma2 = 0.0;
            fwd.s2_init.clear();
        }

        // 1. ES recurrence — the pure-Rust Holt-Winters mirror IS the
        //    kernel (coupled dual recurrence for §8.2 configs).
        if dual {
            hw::es_dual_filter_into(y, fwd.alpha, fwd.gamma, fwd.gamma2,
                                    &fwd.s_init, &fwd.s2_init,
                                    &mut fwd.levels, &mut fwd.seas,
                                    &mut fwd.seas2);
        } else {
            hw::es_filter_into(y, fwd.alpha, fwd.gamma, &fwd.s_init,
                               &mut fwd.levels, &mut fwd.seas);
            fwd.seas2.clear();
        }

        // 2. Seasonality extension past C: tile each component's final
        //    period (§3.4); dual configs multiply the two tracks (Gould
        //    et al. 2008).
        {
            let Forward { seas, seas2, seas_ext, .. } = fwd;
            seas_ext.clear();
            seas_ext.reserve(c + h);
            if dual {
                for t in 0..c {
                    seas_ext.push(seas[t] * seas2[t]);
                }
                for k in 0..h {
                    seas_ext.push(seas[c + (k % s)] * seas2[c + (k % s2)]);
                }
            } else {
                seas_ext.extend_from_slice(&seas[..c]);
                for k in 0..h {
                    seas_ext.push(seas[c + (k % s)]);
                }
            }
        }

        // 3. Windows: log-normalized inputs and (optionally) targets
        //    (Fig. 2).
        set_len(&mut fwd.x, p_n * in_w);
        refill_bool(&mut fwd.x_ok, p_n * in_w);
        if want_targets {
            set_len(&mut fwd.z, p_n * h);
            refill_bool(&mut fwd.z_ok, p_n * h);
        } else {
            fwd.z.clear();
            fwd.z_ok.clear();
        }
        {
            let Forward { levels, seas_ext, x, x_ok, z, z_ok, .. } = fwd;
            for p in 0..p_n {
                let lvl = levels[p + in_w - 1];
                for j in 0..in_w {
                    let u = y[p + j] / (lvl * seas_ext[p + j]);
                    if u <= EPS {
                        x[p * in_w + j] = EPS.ln();
                        x_ok[p * in_w + j] = false;
                    } else {
                        x[p * in_w + j] = u.ln();
                    }
                }
                if want_targets {
                    for k in 0..h {
                        let ty = (p + in_w + k).min(c - 1);
                        let u = y[ty] / (lvl * seas_ext[p + in_w + k]);
                        if u <= EPS {
                            z[p * h + k] = EPS.ln();
                            z_ok[p * h + k] = false;
                        } else {
                            z[p * h + k] = u.ln();
                        }
                    }
                }
            }
        }

        // 4. Dilated-residual LSTM stack with per-layer ring buffers:
        //    slot p % d holds the state from position p - d (Chang et
        //    al.). Rings must start zeroed — the first `d` positions read
        //    the zero state.
        ring_reset(&mut self.h_ring, &shape.flat, hid);
        ring_reset(&mut self.c_ring, &shape.flat, hid);

        let tape_len = p_n * n_l * hid;
        set_len(&mut fwd.out, p_n * h);
        set_len(&mut fwd.x_in, p_n * n_l * din_max);
        set_len(&mut fwd.h_prev, tape_len);
        set_len(&mut fwd.c_prev, tape_len);
        set_len(&mut fwd.si, tape_len);
        set_len(&mut fwd.sf, tape_len);
        set_len(&mut fwd.tg, tape_len);
        set_len(&mut fwd.so, tape_len);
        set_len(&mut fwd.tanh_c, tape_len);
        set_len(&mut fwd.h_seq, p_n * hid);
        set_len(&mut fwd.act, p_n * hid);

        set_len(&mut self.feat, shape.din0);
        set_len(&mut self.zbuf, 4 * hid);
        set_len(&mut self.h_in, din_max);
        set_len(&mut self.block_in, din_max);
        let feat = &mut self.feat;
        let zbuf = &mut self.zbuf;
        let h_in = &mut self.h_in;
        let block_in = &mut self.block_in;
        let h_ring = &mut self.h_ring;
        let c_ring = &mut self.c_ring;
        let pre = &mut self.pre;
        let obuf = &mut self.obuf;
        for p in 0..p_n {
            feat[..in_w].copy_from_slice(&fwd.x[p * in_w..(p + 1) * in_w]);
            feat[in_w..].copy_from_slice(cat);
            let mut cur_dim = shape.din0;
            h_in[..cur_dim].copy_from_slice(feat);

            let mut li = 0usize;
            for (bi, block) in shape.blocks.iter().enumerate() {
                let block_dim = cur_dim;
                block_in[..block_dim].copy_from_slice(&h_in[..block_dim]);
                for &d in block {
                    let slot = p % d;
                    let din = shape.layer_din[li];
                    let (w, b) = rnn.cells[li];
                    let t = (p * n_l + li) * hid;
                    let ti = (p * n_l + li) * din_max;
                    fwd.x_in[ti..ti + din].copy_from_slice(&h_in[..din]);
                    let h_prev = &h_ring[li][slot * hid..(slot + 1) * hid];
                    let c_prev = &c_ring[li][slot * hid..(slot + 1) * hid];
                    fwd.h_prev[t..t + hid].copy_from_slice(h_prev);
                    fwd.c_prev[t..t + hid].copy_from_slice(c_prev);

                    zbuf.copy_from_slice(b);
                    vec_mat_acc(&h_in[..din], w, 0, 4 * hid, zbuf);
                    vec_mat_acc(h_prev, w, din, 4 * hid, zbuf);

                    // Gate order i, f, g, o; forget-gate bias +1.0
                    // (ref.py).
                    for k in 0..hid {
                        let si = sigmoid(zbuf[k]);
                        let sf = sigmoid(zbuf[hid + k] + 1.0);
                        let tg = zbuf[2 * hid + k].tanh();
                        let so = sigmoid(zbuf[3 * hid + k]);
                        let c_new = sf * fwd.c_prev[t + k] + si * tg;
                        let tanh_c = c_new.tanh();
                        let h_new = so * tanh_c;
                        fwd.si[t + k] = si;
                        fwd.sf[t + k] = sf;
                        fwd.tg[t + k] = tg;
                        fwd.so[t + k] = so;
                        fwd.tanh_c[t + k] = tanh_c;
                        h_ring[li][slot * hid + k] = h_new;
                        c_ring[li][slot * hid + k] = c_new;
                        h_in[k] = h_new;
                    }
                    cur_dim = hid;
                    li += 1;
                }
                if bi > 0 {
                    // Residual connection over non-first blocks (Fig. 1).
                    for k in 0..hid {
                        h_in[k] += block_in[k];
                    }
                }
            }
            fwd.h_seq[p * hid..(p + 1) * hid].copy_from_slice(&h_in[..hid]);

            // 5. Output head (§3.4): tanh dense, then linear adapter to H.
            pre.clear();
            pre.extend_from_slice(rnn.dense_b);
            vec_mat_acc(&h_in[..hid], rnn.dense_w, 0, hid, pre);
            for (k, v) in pre.iter().enumerate() {
                fwd.act[p * hid + k] = v.tanh();
            }
            obuf.clear();
            obuf.extend_from_slice(rnn.out_b);
            vec_mat_acc(&fwd.act[p * hid..(p + 1) * hid], rnn.out_w, 0, h,
                        obuf);
            fwd.out[p * h..(p + 1) * h].copy_from_slice(obuf);
        }
    }

    /// Retained heap footprint (for `BackendStats::scratch_bytes`).
    pub fn bytes(&self) -> u64 {
        let rings: usize = self
            .h_ring
            .iter()
            .chain(&self.c_ring)
            .map(|r| r.capacity())
            .sum();
        self.fwd.bytes()
            + 4 * (rings + self.feat.capacity() + self.zbuf.capacity()
                   + self.h_in.capacity() + self.block_in.capacity()
                   + self.pre.capacity() + self.obuf.capacity())
                as u64
    }
}

/// Point forecast from a completed forward pass (§3.4): take the final
/// window position, de-normalize and re-seasonalize.
pub fn forecast_from(shape: &Shape, fwd: &Forward) -> Vec<f32> {
    let mut out = vec![0.0f32; shape.h];
    forecast_into(shape, fwd, &mut out);
    out
}

/// [`forecast_from`] writing into a caller-owned `[H]` slice (the pooled
/// predict path).
pub fn forecast_into(shape: &Shape, fwd: &Forward, out: &mut [f32]) {
    let (c, h, p_n) = (shape.c, shape.h, shape.p);
    let l_c = fwd.levels[c - 1];
    for (k, o) in out.iter_mut().enumerate().take(h) {
        *o = fwd.out[(p_n - 1) * h + k].exp() * l_c * fwd.seas_ext[c + k];
    }
}

/// Hand-written backward for one series.
///
/// `dout` and `dz` are the loss gradients w.r.t. the head output and the
/// log-normalized targets, both `[P, H]` and already weighted by the
/// position/series mask and the global loss denominator. RNN weight
/// gradients are accumulated into `grads`; per-series gradients are
/// returned.
pub fn backward_series(shape: &Shape, y: &[f32], rnn: &RnnView, fwd: &Forward,
                       dout: &[f32], dz: &[f32], grads: &mut RnnGrads)
                       -> SeriesGrads {
    let (c, s, h, in_w, p_n) = (shape.c, shape.s, shape.h, shape.in_w, shape.p);
    let s2 = shape.s2;
    let dual = shape.dual();
    let hid = shape.hidden;
    let n_l = shape.n_layers();
    let din_max = fwd.din_max;

    // ---- head backward, collecting dL/dh_seq ----
    let mut dh_seq = vec![0.0f32; p_n * hid];
    let mut dpre = vec![0.0f32; hid];
    for p in 0..p_n {
        let dop = &dout[p * h..(p + 1) * h];
        let a = &fwd.act[p * hid..(p + 1) * hid];
        outer_acc(a, dop, 0, h, &mut grads.out_w);
        for (g, &d) in grads.out_b.iter_mut().zip(dop) {
            *g += d;
        }
        // da = out_w @ dout;  dpre = da * (1 - a^2)
        mat_t_vec(rnn.out_w, dop, 0, hid, h, &mut dpre);
        for k in 0..hid {
            dpre[k] *= 1.0 - a[k] * a[k];
        }
        let hs = &fwd.h_seq[p * hid..(p + 1) * hid];
        outer_acc(hs, &dpre, 0, hid, &mut grads.dense_w);
        for (g, &d) in grads.dense_b.iter_mut().zip(&dpre) {
            *g += d;
        }
        mat_t_vec(rnn.dense_w, &dpre, 0, hid, hid, &mut dh_seq[p * hid..(p + 1) * hid]);
    }

    // ---- BPTT through the dilated stack ----
    // Gradient ring buffers mirror the forward rings: after processing
    // position p, slot p % d holds the gradient flowing to the state
    // produced at p - d; it is consumed (and overwritten) exactly when
    // that position is processed.
    let mut dh_ring: Vec<Vec<f32>> = shape.flat.iter().map(|&d| vec![0.0; d * hid]).collect();
    let mut dc_ring: Vec<Vec<f32>> = shape.flat.iter().map(|&d| vec![0.0; d * hid]).collect();
    let mut dx = vec![0.0f32; p_n * in_w];

    let mut g_h = vec![0.0f32; din_max];
    let mut g_resid = vec![0.0f32; hid];
    let mut dzz = vec![0.0f32; 4 * hid];
    let mut dinp = vec![0.0f32; din_max + hid];
    for p in (0..p_n).rev() {
        g_h[..hid].copy_from_slice(&dh_seq[p * hid..(p + 1) * hid]);
        let mut li = n_l;
        for (bi, block) in shape.blocks.iter().enumerate().rev() {
            let has_resid = bi > 0;
            if has_resid {
                g_resid.copy_from_slice(&g_h[..hid]);
            }
            for &d in block.iter().rev() {
                li -= 1;
                let slot = p % d;
                let din = shape.layer_din[li];
                let (w, _) = rnn.cells[li];
                let t = (p * n_l + li) * hid;
                let ti = (p * n_l + li) * din_max;
                let (gw, gb) = &mut grads.cells[li];
                for k in 0..hid {
                    let total_dh = g_h[k] + dh_ring[li][slot * hid + k];
                    let si = fwd.si[t + k];
                    let sf = fwd.sf[t + k];
                    let tg = fwd.tg[t + k];
                    let so = fwd.so[t + k];
                    let tanh_c = fwd.tanh_c[t + k];
                    let c_prev = fwd.c_prev[t + k];
                    let dc_total = dc_ring[li][slot * hid + k]
                        + total_dh * so * (1.0 - tanh_c * tanh_c);
                    dzz[k] = dc_total * tg * si * (1.0 - si); // d i_pre
                    dzz[hid + k] = dc_total * c_prev * sf * (1.0 - sf); // d f_pre
                    dzz[2 * hid + k] = dc_total * si * (1.0 - tg * tg); // d g_pre
                    dzz[3 * hid + k] = total_dh * tanh_c * so * (1.0 - so); // d o_pre
                    dc_ring[li][slot * hid + k] = dc_total * sf; // → c_prev
                }
                let x_in = &fwd.x_in[ti..ti + din];
                let h_prev = &fwd.h_prev[t..t + hid];
                outer_acc(x_in, &dzz, 0, 4 * hid, gw);
                outer_acc(h_prev, &dzz, din, 4 * hid, gw);
                for (g, &dv) in gb.iter_mut().zip(&dzz) {
                    *g += dv;
                }
                // dinp = w @ dzz, split into d x_in | d h_prev
                mat_t_vec(w, &dzz, 0, din + hid, 4 * hid, &mut dinp[..din + hid]);
                dh_ring[li][slot * hid..(slot + 1) * hid]
                    .copy_from_slice(&dinp[din..din + hid]);
                g_h[..din].copy_from_slice(&dinp[..din]);
            }
            if has_resid {
                // block_in feeds both the first layer and the skip path.
                for k in 0..hid {
                    g_h[k] += g_resid[k];
                }
            }
        }
        dx[p * in_w..(p + 1) * in_w].copy_from_slice(&g_h[..in_w]);
    }

    // ---- window backward: d levels, d seas_ext ----
    let mut dlev = vec![0.0f32; c];
    let mut dseas_ext = vec![0.0f32; c + h];
    for p in 0..p_n {
        let lvl = fwd.levels[p + in_w - 1];
        let mut dlvl = 0.0f32;
        for j in 0..in_w {
            if !fwd.x_ok[p * in_w + j] {
                continue;
            }
            let dxj = dx[p * in_w + j];
            dlvl -= dxj / lvl;
            dseas_ext[p + j] -= dxj / fwd.seas_ext[p + j];
        }
        for k in 0..h {
            if !fwd.z_ok[p * h + k] {
                continue;
            }
            let dzk = dz[p * h + k];
            dlvl -= dzk / lvl;
            dseas_ext[p + in_w + k] -= dzk / fwd.seas_ext[p + in_w + k];
        }
        dlev[p + in_w - 1] += dlvl;
    }

    // seas_ext → per-component seasonality gradients. For single configs
    // the combined track IS the primary track; for dual configs
    // seas_ext[t] = seas1[t] * seas2[t] (head) and
    // seas_ext[C+k] = seas1[C + k%S1] * seas2[C + k%S2] (tails), so the
    // product rule routes each position's gradient to both components.
    let mut gseas = vec![0.0f32; c + s];
    let mut gseas2 = vec![0.0f32; if dual { c + s2 } else { 0 }];
    if dual {
        for t in 0..c {
            gseas[t] += dseas_ext[t] * fwd.seas2[t];
            gseas2[t] += dseas_ext[t] * fwd.seas[t];
        }
        for k in 0..h {
            let (i1, i2) = (c + (k % s), c + (k % s2));
            gseas[i1] += dseas_ext[c + k] * fwd.seas2[i2];
            gseas2[i2] += dseas_ext[c + k] * fwd.seas[i1];
        }
    } else {
        gseas[..c].copy_from_slice(&dseas_ext[..c]);
        for k in 0..h {
            gseas[c + (k % s)] += dseas_ext[c + k];
        }
    }

    // ---- ES recurrence backward ----
    // Reverse over t: when step t is processed, every use of seas1[t+S1]
    // and seas2[t+S2] (level at t' = t+S_i, both seasonal updates at
    // t' = t+S_i, direct window reads) has already deposited its gradient,
    // because all those uses happen at steps > t or were seeded above.
    //
    // Dual-recurrence coupling invariant: within step t the forward order
    // is l_t first (reading s1_t, s2_t, l_{t-1}), then seas1[t+S1] and
    // seas2[t+S2] (each reading l_t AND the *other* component's s_t). The
    // backward therefore (a) drains both "next" seasonal gradients into
    // glev[t] / gseas{1,2}[t] / d gamma{1,2} — including the cross terms
    // through the other component — and only then (b) consumes glev[t]
    // for the level recurrence: by that point l_t's full use set {level
    // at t+1, seas1[t+S1], seas2[t+S2], window reads} has deposited.
    // Deposits into gseas{1,2}[t] are safe because index t is consumed at
    // step t-S_i < t (or, for t < S_i, by the s_init mapping after the
    // loop).
    let (alpha, gamma, gamma2) = (fwd.alpha, fwd.gamma, fwd.gamma2);
    let mut glev = dlev;
    let mut d_alpha = 0.0f32;
    let mut d_gamma = 0.0f32;
    let mut d_gamma2 = 0.0f32;
    for t in (0..c).rev() {
        let l_t = fwd.levels[t];
        let y_t = y[t];
        let s1_t = fwd.seas[t];
        let s2_t = if dual { fwd.seas2[t] } else { 1.0 };

        // seas1[t+S1] = gamma*y_t/(l_t*s2_t) + (1-gamma)*s1_t
        let g1n = gseas[t + s];
        let u1 = y_t / (l_t * s2_t);
        glev[t] += g1n * (-gamma * u1 / l_t);
        d_gamma += g1n * (u1 - s1_t);
        gseas[t] += g1n * (1.0 - gamma);
        if dual {
            gseas2[t] += g1n * (-gamma * u1 / s2_t);
            // seas2[t+S2] = gamma2*y_t/(l_t*s1_t) + (1-gamma2)*s2_t
            let g2n = gseas2[t + s2];
            let u2 = y_t / (l_t * s1_t);
            glev[t] += g2n * (-gamma2 * u2 / l_t);
            d_gamma2 += g2n * (u2 - s2_t);
            gseas[t] += g2n * (-gamma2 * u2 / s1_t);
            gseas2[t] += g2n * (1.0 - gamma2);
        }

        let g_l = glev[t];
        let s_all = s1_t * s2_t;
        if t > 0 {
            // l_t = alpha*y_t/(s1_t*s2_t) + (1-alpha)*l_{t-1}
            d_alpha += g_l * (y_t / s_all - fwd.levels[t - 1]);
            gseas[t] += g_l * (-alpha * y_t / (s_all * s1_t));
            if dual {
                gseas2[t] += g_l * (-alpha * y_t / (s_all * s2_t));
            }
            glev[t - 1] += g_l * (1.0 - alpha);
        } else {
            // l_0 = y_0/(s1_0*s2_0)
            gseas[0] += g_l * (-y_t / (s_all * s1_t));
            if dual {
                gseas2[0] += g_l * (-y_t / (s_all * s2_t));
            }
        }
    }

    let d_alpha_logit = d_alpha * alpha * (1.0 - alpha);
    let (d_gamma_logit, d_gamma2_logit, d_log_s) = if shape.seasonal {
        let mut d_log_s = Vec::with_capacity(s + s2);
        // d log s_init = d s_init * s_init (chain through exp), per block.
        d_log_s.extend((0..s).map(|k| gseas[k] * fwd.s_init[k]));
        d_log_s.extend((0..s2).map(|k| gseas2[k] * fwd.s2_init[k]));
        (d_gamma * gamma * (1.0 - gamma),
         if dual { d_gamma2 * gamma2 * (1.0 - gamma2) } else { 0.0 },
         d_log_s)
    } else {
        // Non-seasonal: gamma is pinned to 0 and s_init to 1 in-graph, so
        // no gradient flows to the stored logits (matches the artifact).
        (0.0, 0.0, vec![0.0; s + s2])
    };
    SeriesGrads {
        alpha_logit: d_alpha_logit,
        gamma_logit: d_gamma_logit,
        gamma2_logit: d_gamma2_logit,
        log_s_init: d_log_s,
    }
}

/// One Adam update for a single parameter leaf (in place, mirroring
/// `model.py::_adam_update`). `bc1`/`bc2` are the bias corrections for the
/// *post-increment* step.
pub fn adam_update(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32],
                   lr: f32, mult: f32, bc1: f32, bc2: f32) {
    for i in 0..p.len() {
        let m2 = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        let v2 = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + ADAM_EPS);
        p[i] -= lr * mult * upd;
        m[i] = m2;
        v[i] = v2;
    }
}

/// Pinball loss value plus `dout`/`dz` seeds for one series.
///
/// `weight` is `pos_mask[p] * smask / denom` pre-division; to keep the
/// caller simple this takes the scalar series mask and global denominator
/// and applies the position mask internally.
pub fn pinball_seeds(shape: &Shape, fwd: &Forward, tau: f32, smask: f32,
                     denom: f32) -> (f64, Vec<f32>, Vec<f32>) {
    let (h, p_n) = (shape.h, shape.p);
    let mut loss_num = 0.0f64;
    let mut dout = vec![0.0f32; p_n * h];
    let mut dz = vec![0.0f32; p_n * h];
    if smask == 0.0 {
        return (0.0, dout, dz);
    }
    for p in 0..p_n {
        if p >= shape.valid_positions {
            break; // pos_mask is 1 for p < valid_positions, 0 after
        }
        for k in 0..h {
            let d = fwd.z[p * h + k] - fwd.out[p * h + k];
            let per = (tau * d).max((tau - 1.0) * d);
            loss_num += (per * smask) as f64;
            let w = smask / denom;
            if d >= 0.0 {
                dout[p * h + k] = -tau * w;
                dz[p * h + k] = tau * w;
            } else {
                dout[p * h + k] = (1.0 - tau) * w;
                dz[p * h + k] = (tau - 1.0) * w;
            }
        }
    }
    (loss_num, dout, dz)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_shape() -> Shape {
        Shape::new(4, 0, 4, 5, 20, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap()
    }

    fn toy_dual_shape() -> Shape {
        Shape::new(3, 6, 4, 5, 24, 6, &[vec![1, 2], vec![2, 4]], 6).unwrap()
    }

    fn toy_rnn(shape: &Shape, seed: u64) -> Vec<(Vec<f32>, Vec<f32>)> {
        // (cells w/b, then dense_w, dense_b, out_w, out_b) packed flat;
        // helper for tests only.
        let mut rng = crate::util::rng::Rng::new(seed);
        let hid = shape.hidden;
        let mut out = Vec::new();
        for &din in &shape.layer_din {
            let lim = (6.0 / (din + hid + 4 * hid) as f64).sqrt();
            let w: Vec<f32> = (0..(din + hid) * 4 * hid)
                .map(|_| rng.uniform(-lim, lim) as f32)
                .collect();
            out.push((w, vec![0.0; 4 * hid]));
        }
        let lim = (6.0 / (2 * hid) as f64).sqrt();
        out.push((
            (0..hid * hid).map(|_| rng.uniform(-lim, lim) as f32).collect(),
            vec![0.0; hid],
        ));
        let lim = (6.0 / (hid + shape.h) as f64).sqrt();
        out.push((
            (0..hid * shape.h).map(|_| rng.uniform(-lim, lim) as f32).collect(),
            vec![0.0; shape.h],
        ));
        out
    }

    fn cell_refs(parts: &[(Vec<f32>, Vec<f32>)]) -> Vec<(&[f32], &[f32])> {
        let n = parts.len() - 2;
        parts[..n]
            .iter()
            .map(|q| (q.0.as_slice(), q.1.as_slice()))
            .collect()
    }

    fn view<'a>(parts: &'a [(Vec<f32>, Vec<f32>)],
                cells: &'a [(&'a [f32], &'a [f32])]) -> RnnView<'a> {
        let n = parts.len() - 2;
        RnnView {
            cells,
            dense_w: &parts[n].0,
            dense_b: &parts[n].1,
            out_w: &parts[n + 1].0,
            out_b: &parts[n + 1].1,
        }
    }

    fn toy_series(shape: &Shape, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..shape.c)
            .map(|t| {
                let seas = 1.0 + 0.25 * ((t % shape.s) as f32 / shape.s as f32
                                         * std::f32::consts::TAU).sin();
                (60.0 + 0.8 * t as f32) * seas * rng.uniform(0.95, 1.05) as f32
            })
            .collect()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let shape = toy_shape();
        let parts = toy_rnn(&shape, 7);
        let cells = cell_refs(&parts);
        let rnn = view(&parts, &cells);
        let y = toy_series(&shape, 3);
        let cat = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let log_s = vec![0.05, -0.05, 0.1, -0.1];
        let hwp = HwView {
            alpha_logit: -0.5,
            gamma_logit: -2.0,
            gamma2_logit: 0.0,
            log_s_init: &log_s,
        };
        let fwd = forward_series(&shape, &y, &cat, &rnn, hwp, true);
        assert_eq!(fwd.out.len(), shape.p * shape.h);
        assert_eq!(fwd.z.len(), shape.p * shape.h);
        assert!(fwd.out.iter().all(|v| v.is_finite()));
        assert!(fwd.levels.iter().all(|v| v.is_finite() && *v > 0.0));
        let fc = forecast_from(&shape, &fwd);
        assert_eq!(fc.len(), shape.h);
        assert!(fc.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn dual_forward_tracks_and_forecast_are_finite() {
        let shape = toy_dual_shape();
        assert!(shape.dual());
        assert_eq!(shape.s_total(), 9);
        let parts = toy_rnn(&shape, 9);
        let cells = cell_refs(&parts);
        let rnn = view(&parts, &cells);
        let y = toy_series(&shape, 5);
        let cat = [0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let log_s = vec![0.02f32; 9];
        let hwp = HwView {
            alpha_logit: -0.5,
            gamma_logit: -2.0,
            gamma2_logit: -2.5,
            log_s_init: &log_s,
        };
        let fwd = forward_series(&shape, &y, &cat, &rnn, hwp, true);
        assert_eq!(fwd.seas.len(), shape.c + shape.s);
        assert_eq!(fwd.seas2.len(), shape.c + shape.s2);
        assert_eq!(fwd.seas_ext.len(), shape.c + shape.h);
        // Combined head equals the product of the component tracks.
        for t in 0..shape.c {
            assert!((fwd.seas_ext[t] - fwd.seas[t] * fwd.seas2[t]).abs()
                    < 1e-6);
        }
        let fc = forecast_from(&shape, &fwd);
        assert!(fc.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn shape_rejects_short_series() {
        // length < input_window: no positions at all.
        assert!(Shape::new(4, 0, 4, 30, 20, 6, &[vec![1]], 6).is_err());
        // length >= input_window but < input_window + horizon.
        assert!(Shape::new(4, 0, 18, 12, 20, 6, &[vec![1]], 6).is_err());
        // Exactly one valid position is fine.
        let ok = Shape::new(4, 0, 4, 5, 9, 6, &[vec![1]], 6).unwrap();
        assert_eq!(ok.valid_positions, 1);
        assert_eq!(ok.p, 5);
    }

    #[test]
    fn pinball_seeds_mask_padding() {
        let shape = toy_shape();
        let parts = toy_rnn(&shape, 7);
        let cells = cell_refs(&parts);
        let rnn = view(&parts, &cells);
        let y = toy_series(&shape, 4);
        let cat = [0.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let hwp = HwView {
            alpha_logit: -0.5,
            gamma_logit: -2.0,
            gamma2_logit: 0.0,
            log_s_init: &[0.0; 4],
        };
        let fwd = forward_series(&shape, &y, &cat, &rnn, hwp, true);
        let (l0, d0, z0) = pinball_seeds(&shape, &fwd, 0.48, 0.0, 100.0);
        assert_eq!(l0, 0.0);
        assert!(d0.iter().all(|v| *v == 0.0) && z0.iter().all(|v| *v == 0.0));
        let (l1, d1, _) = pinball_seeds(&shape, &fwd, 0.48, 1.0, 100.0);
        assert!(l1 > 0.0);
        assert!(d1.iter().any(|v| *v != 0.0));
        // Positions past the valid range never carry gradient.
        for p in shape.valid_positions..shape.p {
            for k in 0..shape.h {
                assert_eq!(d1[p * shape.h + k], 0.0);
            }
        }
    }

    #[test]
    fn adam_zero_grad_is_identity_from_zero_moments() {
        let mut p = vec![1.5f32, -2.0];
        let g = vec![0.0f32, 0.0];
        let mut m = vec![0.0f32, 0.0];
        let mut v = vec![0.0f32, 0.0];
        adam_update(&mut p, &g, &mut m, &mut v, 1e-3, 1.5,
                    1.0 - ADAM_B1, 1.0 - ADAM_B2);
        assert_eq!(p, vec![1.5, -2.0]);
    }
}
