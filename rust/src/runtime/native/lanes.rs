//! Lane-vectorized (structure-of-arrays) batch kernels — the paper's §5
//! vectorization step executed natively.
//!
//! The scalar compute core ([`super::model`]) runs one series at a time;
//! this module runs [`LANES`] series per recurrence step. A batch is
//! marshalled into [`LaneGroup`]s: every per-series value becomes one
//! lane of an SoA buffer (`buf[t * LANES + l]` is series `l` at index
//! `t`), so the ES filter, the window log-normalization, the
//! dilated-LSTM cell, the pinball loss, the hand-written backward and
//! the Adam leaf updates all execute as 8-wide [`Lanes`] arithmetic with
//! shared RNN weights broadcast across lanes.
//!
//! Conventions:
//!
//! * **Tail handling** — a batch that does not fill the last group (or a
//!   masked-out slot anywhere) gets *padding lanes*: `y ≡ 1.0`, zero
//!   logits, zero `log_s`, lane mask 0. Padding forwards to finite values
//!   and receives exactly-zero loss seeds, so its gradients are exact
//!   zeros and its outputs are simply never copied out. Flat leaf
//!   updates ([`adam_update_lanes`]) instead use a scalar tail for the
//!   `len % LANES` remainder.
//! * **Parity** — each lane executes the same floating-point operation
//!   sequence as the scalar core, except that shared-weight reductions
//!   sum 8 series at once and the transcendentals use the fast
//!   [`Lanes`] approximations (≤ 3e-7). `rust/tests/simd_parity.rs`
//!   property-tests every kernel here against the scalar oracle,
//!   including ragged tails and the §8.2 dual-seasonality path.
//! * **Determinism** — lane order inside a group and group order inside
//!   a batch are fixed, so a given thread count always reproduces the
//!   same bits; across thread counts only the f32 association of the
//!   shared-weight chunk merge differs (last-ulp effects, same as the
//!   scalar path).

use crate::hw;
use crate::simd::{add_assign_slice, Lanes, LANES};

use super::model::{self, RnnGrads, RnnView, Shape};

/// One lane group's marshalled inputs: [`LANES`] series in SoA layout.
pub struct LaneGroup {
    /// First batch slot this group covers.
    pub start: usize,
    /// Real batch slots in this group (1..=LANES); lanes ≥ `fill` are
    /// padding.
    pub fill: usize,
    /// Series values, `[C][LANES]` (padding/masked lanes hold 1.0).
    pub y: Vec<f32>,
    /// One-hot categories, `[6][LANES]`.
    pub cat: Vec<f32>,
    pub alpha_logit: Lanes,
    pub gamma_logit: Lanes,
    pub gamma2_logit: Lanes,
    /// Packed `[S1 | S2]` log seasonality inits, `[s_total][LANES]`.
    pub log_s: Vec<f32>,
    /// Per-lane series mask (0.0 for padding and masked-out slots).
    pub mask: Lanes,
}

impl LaneGroup {
    /// Placeholder group for pooled marshal buffers;
    /// [`marshal_groups_into`] refills every field before use.
    pub fn empty() -> Self {
        Self {
            start: 0,
            fill: 0,
            y: Vec::new(),
            cat: Vec::new(),
            alpha_logit: Lanes::ZERO,
            gamma_logit: Lanes::ZERO,
            gamma2_logit: Lanes::ZERO,
            log_s: Vec::new(),
            mask: Lanes::ZERO,
        }
    }

    /// Retained heap footprint (for `BackendStats::scratch_bytes`).
    pub fn bytes(&self) -> u64 {
        (4 * (self.y.capacity() + self.cat.capacity()
              + self.log_s.capacity())) as u64
    }
}

/// Split a batch of `b` AoS series rows into `ceil(b / LANES)` SoA lane
/// groups. `y` is `[b, C]`, `cat` `[b, 6]`, `log_s` `[b, s_total]`;
/// `gamma2_logit` may be empty for single-seasonality configs. A slot is
/// *live* iff it exists (`i < b`) and its `mask` entry (when given) is
/// non-zero; dead slots become padding lanes (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn marshal_groups(shape: &Shape, b: usize, y: &[f32], cat: &[f32],
                      mask: Option<&[f32]>, alpha_logit: &[f32],
                      gamma_logit: &[f32], gamma2_logit: &[f32],
                      log_s: &[f32]) -> Vec<LaneGroup> {
    let mut groups = Vec::new();
    marshal_groups_into(&mut groups, shape, b, y, cat, mask, alpha_logit,
                        gamma_logit, gamma2_logit, log_s);
    groups
}

/// [`marshal_groups`] overwriting pooled group buffers instead of
/// reallocating: each [`LaneGroup`]'s SoA vectors are refilled in place,
/// so a steady-state caller with a fixed batch shape performs zero heap
/// allocations here. Bit-identical fill to [`marshal_groups`].
#[allow(clippy::too_many_arguments)]
pub fn marshal_groups_into(groups: &mut Vec<LaneGroup>, shape: &Shape,
                           b: usize, y: &[f32], cat: &[f32],
                           mask: Option<&[f32]>, alpha_logit: &[f32],
                           gamma_logit: &[f32], gamma2_logit: &[f32],
                           log_s: &[f32]) {
    let c = shape.c;
    let w = shape.s_total();
    let n_groups = b.div_ceil(LANES);
    groups.resize_with(n_groups, LaneGroup::empty);
    for (g, grp) in groups.iter_mut().enumerate() {
        let start = g * LANES;
        let fill = LANES.min(b - start);
        // Padding baseline: benign y ≡ 1.0, zeroed logits/log_s/mask —
        // live lanes overwrite below.
        model::set_filled(&mut grp.y, c * LANES, 1.0);
        model::set_zeroed(&mut grp.cat, 6 * LANES);
        model::set_zeroed(&mut grp.log_s, w * LANES);
        let mut ga = [0.0f32; LANES];
        let mut gg = [0.0f32; LANES];
        let mut gg2 = [0.0f32; LANES];
        let mut gm = [0.0f32; LANES];
        for l in 0..fill {
            let i = start + l;
            let m = mask.map_or(1.0, |mv| mv[i]);
            if m == 0.0 {
                // Masked slot: keep the benign padding values so the
                // forward stays finite; zero seeds then make every
                // gradient for this lane exactly zero.
                continue;
            }
            gm[l] = m;
            for t in 0..c {
                grp.y[t * LANES + l] = y[i * c + t];
            }
            for j in 0..6 {
                grp.cat[j * LANES + l] = cat[i * 6 + j];
            }
            ga[l] = alpha_logit[i];
            gg[l] = gamma_logit[i];
            if !gamma2_logit.is_empty() {
                gg2[l] = gamma2_logit[i];
            }
            for k in 0..w {
                grp.log_s[k * LANES + l] = log_s[i * w + k];
            }
        }
        grp.start = start;
        grp.fill = fill;
        grp.alpha_logit = Lanes(ga);
        grp.gamma_logit = Lanes(gg);
        grp.gamma2_logit = Lanes(gg2);
        grp.mask = Lanes(gm);
    }
}

/// `out[j] += Σ_i x[i] · w[(row_offset+i), j]` with `x` SoA `[n_rows][L]`
/// and `out` SoA `[cols][L]` — the shared weight is broadcast across
/// lanes. Row-major `w` is streamed once (i outer, j inner), matching
/// the scalar accumulation order.
fn vec_mat_acc_lanes(x: &[f32], n_rows: usize, w: &[f32], row_offset: usize,
                     cols: usize, out: &mut [f32]) {
    for i in 0..n_rows {
        let xi = Lanes::load(&x[i * LANES..]);
        let row = &w[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        for (j, &wv) in row.iter().enumerate() {
            (Lanes::load(&out[j * LANES..]) + xi * Lanes::splat(wv))
                .store(&mut out[j * LANES..]);
        }
    }
}

/// `gw[(row_offset+i), j] += Σ_l x[i][l] · dz[j][l]` — the shared-weight
/// gradient is the horizontal lane sum of the per-series outer products
/// (fixed lane order, so thread-count independent).
fn outer_acc_lanes(x: &[f32], n_rows: usize, dz: &[f32], row_offset: usize,
                   cols: usize, gw: &mut [f32]) {
    for i in 0..n_rows {
        let xi = Lanes::load(&x[i * LANES..]);
        let row = &mut gw[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        for (j, g) in row.iter_mut().enumerate() {
            *g += (xi * Lanes::load(&dz[j * LANES..])).sum();
        }
    }
}

/// `out[i] = Σ_j w[(row_offset+i), j] · dz[j]` (transpose mat-vec),
/// `dz`/`out` SoA.
fn mat_t_vec_lanes(w: &[f32], dz: &[f32], row_offset: usize, rows: usize,
                   cols: usize, out: &mut [f32]) {
    for i in 0..rows {
        let row = &w[(row_offset + i) * cols..(row_offset + i + 1) * cols];
        let mut acc = Lanes::ZERO;
        for (j, &wv) in row.iter().enumerate() {
            acc += Lanes::splat(wv) * Lanes::load(&dz[j * LANES..]);
        }
        acc.store(&mut out[i * LANES..]);
    }
}

/// Broadcast a shared bias vector into an SoA `[b.len()][LANES]` buffer.
fn broadcast_rows(b: &[f32], out: &mut [f32]) {
    for (k, &v) in b.iter().enumerate() {
        Lanes::splat(v).store(&mut out[k * LANES..]);
    }
}

/// Elementwise exp over an SoA buffer (length must be a LANES multiple).
fn exp_slice(buf: &mut [f32]) {
    for chunk in buf.chunks_exact_mut(LANES) {
        Lanes::load(chunk).exp().store(chunk);
    }
}

/// Clamped log-normalization: returns `(ln(max(u, EPS)), gate)` with
/// gate 1.0 where `u > EPS` (mirror of the scalar `x_ok` bookkeeping —
/// the gradient is gated by multiply instead of a branch).
fn ln_gate(u: Lanes) -> (Lanes, Lanes) {
    let eps = Lanes::splat(model::EPS);
    (u.max(eps).ln(), u.gt_gate(eps))
}

/// Everything the lane forward records for one group: outputs plus the
/// SoA activation tape the backward replays. Field meanings mirror
/// [`model::Forward`]; every buffer gains a trailing `[LANES]` axis.
pub struct ForwardLanes {
    /// `[C][L]`.
    pub levels: Vec<f32>,
    /// `[C+S1][L]`.
    pub seas: Vec<f32>,
    /// `[C+S2][L]` (empty for single configs).
    pub seas2: Vec<f32>,
    /// `[C+H][L]` combined multiplicative seasonality.
    pub seas_ext: Vec<f32>,
    pub alpha: Lanes,
    pub gamma: Lanes,
    pub gamma2: Lanes,
    /// `[S1][L]`.
    pub s_init: Vec<f32>,
    /// `[S2][L]`.
    pub s2_init: Vec<f32>,
    /// `[P][in_w][L]` log-normalized input windows.
    pub x: Vec<f32>,
    /// `[P][H][L]` log-normalized targets (empty unless `want_targets`).
    pub z: Vec<f32>,
    /// 1.0/0.0 gates where the log's EPS clamp did NOT fire.
    pub x_ok: Vec<f32>,
    pub z_ok: Vec<f32>,
    /// `[P][H][L]` head output in normalized log space.
    pub out: Vec<f32>,
    // ---- tape (indexed [p][layer][k][lane], flattened) ----
    x_in: Vec<f32>,
    h_prev: Vec<f32>,
    c_prev: Vec<f32>,
    si: Vec<f32>,
    sf: Vec<f32>,
    tg: Vec<f32>,
    so: Vec<f32>,
    tanh_c: Vec<f32>,
    h_seq: Vec<f32>,
    act: Vec<f32>,
    din_max: usize,
}

impl ForwardLanes {
    /// Empty record for pooled scratch; [`LaneScratch::forward`] sizes
    /// and fills every buffer before any read.
    pub fn empty() -> Self {
        Self {
            levels: Vec::new(),
            seas: Vec::new(),
            seas2: Vec::new(),
            seas_ext: Vec::new(),
            alpha: Lanes::ZERO,
            gamma: Lanes::ZERO,
            gamma2: Lanes::ZERO,
            s_init: Vec::new(),
            s2_init: Vec::new(),
            x: Vec::new(),
            z: Vec::new(),
            x_ok: Vec::new(),
            z_ok: Vec::new(),
            out: Vec::new(),
            x_in: Vec::new(),
            h_prev: Vec::new(),
            c_prev: Vec::new(),
            si: Vec::new(),
            sf: Vec::new(),
            tg: Vec::new(),
            so: Vec::new(),
            tanh_c: Vec::new(),
            h_seq: Vec::new(),
            act: Vec::new(),
            din_max: 0,
        }
    }

    /// Approximate bytes pinned by this record's buffers.
    fn bytes(&self) -> u64 {
        let caps = self.levels.capacity() + self.seas.capacity()
            + self.seas2.capacity() + self.seas_ext.capacity()
            + self.s_init.capacity() + self.s2_init.capacity()
            + self.x.capacity() + self.z.capacity() + self.x_ok.capacity()
            + self.z_ok.capacity() + self.out.capacity()
            + self.x_in.capacity() + self.h_prev.capacity()
            + self.c_prev.capacity() + self.si.capacity()
            + self.sf.capacity() + self.tg.capacity() + self.so.capacity()
            + self.tanh_c.capacity() + self.h_seq.capacity()
            + self.act.capacity();
        (caps * 4) as u64
    }
}

impl Default for ForwardLanes {
    fn default() -> Self {
        Self::empty()
    }
}

/// Reusable temporaries of the lane forward pass.
#[derive(Default)]
struct ForwardTmp {
    h_ring: Vec<Vec<f32>>,
    c_ring: Vec<Vec<f32>>,
    zbuf: Vec<f32>,
    h_in: Vec<f32>,
    block_in: Vec<f32>,
    pre: Vec<f32>,
    head: Vec<f32>,
}

impl ForwardTmp {
    fn bytes(&self) -> u64 {
        let rings: usize = self.h_ring.iter().chain(&self.c_ring)
            .map(|r| r.capacity())
            .sum();
        ((rings + self.zbuf.capacity() + self.h_in.capacity()
          + self.block_in.capacity() + self.pre.capacity()
          + self.head.capacity()) * 4) as u64
    }
}

/// Full forward pass for one lane group (mirror of
/// [`model::forward_series`], all [`LANES`] series advancing together).
/// Allocating convenience wrapper over [`LaneScratch::forward`].
pub fn forward_lanes(shape: &Shape, grp: &LaneGroup, rnn: &RnnView,
                     want_targets: bool) -> ForwardLanes {
    let mut scratch = LaneScratch::new();
    scratch.forward(shape, grp, rnn, want_targets);
    scratch.fwd
}

/// The forward body: identical floating-point schedule to the historical
/// allocating version, but every buffer comes from `fwd`/`tmp` (resized
/// in place; grown once per shape, then reused allocation-free).
///
/// Reuse-safety: buffers that carry accumulations or sparse writes are
/// re-zeroed ([`model::set_zeroed`] / [`model::ring_reset`]); buffers
/// whose every read position is stored first on each call keep stale
/// contents and are merely resized ([`model::set_len`]) — the per-buffer
/// classification is in DESIGN.md §Steady-state memory & thread reuse.
fn forward_lanes_core(shape: &Shape, grp: &LaneGroup, rnn: &RnnView,
                      want_targets: bool, fwd: &mut ForwardLanes,
                      tmp: &mut ForwardTmp) {
    let (c, s, h, in_w, p_n) = (shape.c, shape.s, shape.h, shape.in_w, shape.p);
    let s2 = shape.s2;
    let dual = shape.dual();
    let hid = shape.hidden;
    let n_l = shape.n_layers();
    let din_max = shape.din0.max(hid);
    fwd.din_max = din_max;

    fwd.alpha = grp.alpha_logit.sigmoid();
    if shape.seasonal {
        fwd.s_init.clear();
        fwd.s_init.extend_from_slice(&grp.log_s[..s * LANES]);
        exp_slice(&mut fwd.s_init);
        fwd.gamma = grp.gamma_logit.sigmoid();
    } else {
        model::set_filled(&mut fwd.s_init, s * LANES, 1.0);
        fwd.gamma = Lanes::ZERO;
    }
    if dual {
        fwd.s2_init.clear();
        fwd.s2_init.extend_from_slice(&grp.log_s[s * LANES..(s + s2) * LANES]);
        exp_slice(&mut fwd.s2_init);
        fwd.gamma2 = grp.gamma2_logit.sigmoid();
    } else {
        fwd.s2_init.clear();
        fwd.gamma2 = Lanes::ZERO;
    }
    let (alpha, gamma, gamma2) = (fwd.alpha, fwd.gamma, fwd.gamma2);

    // 1. ES recurrence, one lane per series.
    if dual {
        hw::es_dual_filter_lanes_into(
            &grp.y[..c * LANES], c, alpha, gamma, gamma2, &fwd.s_init, s,
            &fwd.s2_init, s2, &mut fwd.levels, &mut fwd.seas,
            &mut fwd.seas2);
    } else {
        hw::es_filter_lanes_into(&grp.y[..c * LANES], c, alpha, gamma,
                                 &fwd.s_init, s, &mut fwd.levels,
                                 &mut fwd.seas);
        fwd.seas2.clear();
    }

    // 2. Seasonality extension past C (per-component tail tiling).
    model::set_len(&mut fwd.seas_ext, (c + h) * LANES);
    let (levels, seas, seas2, seas_ext) =
        (&fwd.levels, &fwd.seas, &fwd.seas2, &mut fwd.seas_ext);
    if dual {
        for t in 0..c {
            (Lanes::load(&seas[t * LANES..])
             * Lanes::load(&seas2[t * LANES..]))
                .store(&mut seas_ext[t * LANES..]);
        }
        for k in 0..h {
            (Lanes::load(&seas[(c + (k % s)) * LANES..])
             * Lanes::load(&seas2[(c + (k % s2)) * LANES..]))
                .store(&mut seas_ext[(c + k) * LANES..]);
        }
    } else {
        seas_ext[..c * LANES].copy_from_slice(&seas[..c * LANES]);
        for k in 0..h {
            Lanes::load(&seas[(c + (k % s)) * LANES..])
                .store(&mut seas_ext[(c + k) * LANES..]);
        }
    }

    // 3. Log-normalized windows and (optionally) targets.
    model::set_len(&mut fwd.x, p_n * in_w * LANES);
    model::set_len(&mut fwd.x_ok, p_n * in_w * LANES);
    if want_targets {
        model::set_len(&mut fwd.z, p_n * h * LANES);
        model::set_len(&mut fwd.z_ok, p_n * h * LANES);
    } else {
        fwd.z.clear();
        fwd.z_ok.clear();
    }
    {
        let x = &mut fwd.x;
        let x_ok = &mut fwd.x_ok;
        let z = &mut fwd.z;
        let z_ok = &mut fwd.z_ok;
        let seas_ext = &fwd.seas_ext;
        for p in 0..p_n {
            let lvl = Lanes::load(&fwd.levels[(p + in_w - 1) * LANES..]);
            for j in 0..in_w {
                let u = Lanes::load(&grp.y[(p + j) * LANES..])
                    / (lvl * Lanes::load(&seas_ext[(p + j) * LANES..]));
                let (xv, ok) = ln_gate(u);
                xv.store(&mut x[(p * in_w + j) * LANES..]);
                ok.store(&mut x_ok[(p * in_w + j) * LANES..]);
            }
            if want_targets {
                for k in 0..h {
                    let ty = (p + in_w + k).min(c - 1);
                    let u = Lanes::load(&grp.y[ty * LANES..])
                        / (lvl
                           * Lanes::load(&seas_ext[(p + in_w + k) * LANES..]));
                    let (zv, ok) = ln_gate(u);
                    zv.store(&mut z[(p * h + k) * LANES..]);
                    ok.store(&mut z_ok[(p * h + k) * LANES..]);
                }
            }
        }
    }

    // 4. Dilated-residual LSTM stack, ring buffers now SoA per slot
    // (rings carry recurrent state, so they must restart at zero).
    model::ring_reset(&mut tmp.h_ring, &shape.flat, hid * LANES);
    model::ring_reset(&mut tmp.c_ring, &shape.flat, hid * LANES);
    let h_ring = &mut tmp.h_ring;
    let c_ring = &mut tmp.c_ring;

    let tape_len = p_n * n_l * hid * LANES;
    model::set_len(&mut fwd.out, p_n * h * LANES);
    model::set_len(&mut fwd.x_in, p_n * n_l * din_max * LANES);
    model::set_len(&mut fwd.h_prev, tape_len);
    model::set_len(&mut fwd.c_prev, tape_len);
    model::set_len(&mut fwd.si, tape_len);
    model::set_len(&mut fwd.sf, tape_len);
    model::set_len(&mut fwd.tg, tape_len);
    model::set_len(&mut fwd.so, tape_len);
    model::set_len(&mut fwd.tanh_c, tape_len);
    model::set_len(&mut fwd.h_seq, p_n * hid * LANES);
    model::set_len(&mut fwd.act, p_n * hid * LANES);

    model::set_len(&mut tmp.zbuf, 4 * hid * LANES);
    model::set_len(&mut tmp.h_in, din_max * LANES);
    model::set_len(&mut tmp.block_in, din_max * LANES);
    model::set_len(&mut tmp.pre, hid * LANES);
    model::set_len(&mut tmp.head, h * LANES);
    let zbuf = &mut tmp.zbuf;
    let h_in = &mut tmp.h_in;
    let block_in = &mut tmp.block_in;
    let pre = &mut tmp.pre;
    let head = &mut tmp.head;
    for p in 0..p_n {
        h_in[..in_w * LANES]
            .copy_from_slice(&fwd.x[p * in_w * LANES..(p + 1) * in_w * LANES]);
        h_in[in_w * LANES..shape.din0 * LANES].copy_from_slice(&grp.cat);
        let mut cur_dim = shape.din0;

        let mut li = 0usize;
        for (bi, block) in shape.blocks.iter().enumerate() {
            let block_dim = cur_dim;
            block_in[..block_dim * LANES]
                .copy_from_slice(&h_in[..block_dim * LANES]);
            for &d in block {
                let slot = p % d;
                let din = shape.layer_din[li];
                let (w, b) = rnn.cells[li];
                let t = (p * n_l + li) * hid * LANES;
                let ti = (p * n_l + li) * din_max * LANES;
                let ring_at = slot * hid * LANES;
                fwd.x_in[ti..ti + din * LANES]
                    .copy_from_slice(&h_in[..din * LANES]);
                fwd.h_prev[t..t + hid * LANES]
                    .copy_from_slice(&h_ring[li][ring_at..ring_at + hid * LANES]);
                fwd.c_prev[t..t + hid * LANES]
                    .copy_from_slice(&c_ring[li][ring_at..ring_at + hid * LANES]);

                broadcast_rows(b, &mut zbuf);
                vec_mat_acc_lanes(&h_in, din, w, 0, 4 * hid, &mut zbuf);
                vec_mat_acc_lanes(&fwd.h_prev[t..t + hid * LANES], hid, w,
                                  din, 4 * hid, &mut zbuf);

                // Gate order i, f, g, o; forget-gate bias +1.0 (ref.py).
                for k in 0..hid {
                    let si = Lanes::load(&zbuf[k * LANES..]).sigmoid();
                    let sf = (Lanes::load(&zbuf[(hid + k) * LANES..])
                              + Lanes::ONE)
                        .sigmoid();
                    let tg = Lanes::load(&zbuf[(2 * hid + k) * LANES..]).tanh();
                    let so = Lanes::load(&zbuf[(3 * hid + k) * LANES..])
                        .sigmoid();
                    let c_prev = Lanes::load(&fwd.c_prev[t + k * LANES..]);
                    let c_new = sf * c_prev + si * tg;
                    let tanh_c = c_new.tanh();
                    let h_new = so * tanh_c;
                    si.store(&mut fwd.si[t + k * LANES..]);
                    sf.store(&mut fwd.sf[t + k * LANES..]);
                    tg.store(&mut fwd.tg[t + k * LANES..]);
                    so.store(&mut fwd.so[t + k * LANES..]);
                    tanh_c.store(&mut fwd.tanh_c[t + k * LANES..]);
                    h_new.store(&mut h_ring[li][ring_at + k * LANES..]);
                    c_new.store(&mut c_ring[li][ring_at + k * LANES..]);
                    h_new.store(&mut h_in[k * LANES..]);
                }
                cur_dim = hid;
                li += 1;
            }
            if bi > 0 {
                // Residual connection over non-first blocks (Fig. 1).
                add_assign_slice(&mut h_in[..hid * LANES],
                                 &block_in[..hid * LANES]);
            }
        }
        fwd.h_seq[p * hid * LANES..(p + 1) * hid * LANES]
            .copy_from_slice(&h_in[..hid * LANES]);

        // 5. Output head: tanh dense, then linear adapter to H.
        broadcast_rows(rnn.dense_b, &mut pre);
        vec_mat_acc_lanes(&h_in, hid, rnn.dense_w, 0, hid, &mut pre);
        for k in 0..hid {
            Lanes::load(&pre[k * LANES..])
                .tanh()
                .store(&mut fwd.act[(p * hid + k) * LANES..]);
        }
        broadcast_rows(rnn.out_b, &mut head);
        vec_mat_acc_lanes(&fwd.act[p * hid * LANES..(p + 1) * hid * LANES],
                          hid, rnn.out_w, 0, h, &mut head);
        fwd.out[p * h * LANES..(p + 1) * h * LANES].copy_from_slice(&head);
    }
}

/// Point forecasts from a completed lane forward, `[H][LANES]` SoA
/// (mirror of [`model::forecast_from`]).
pub fn forecast_from_lanes(shape: &Shape, fwd: &ForwardLanes) -> Vec<f32> {
    let mut out = vec![0.0f32; shape.h * LANES];
    forecast_from_lanes_into(shape, fwd, &mut out);
    out
}

// lint:hot-path-begin — steady-state predict kernel (no allocation).
/// [`forecast_from_lanes`] writing into a caller-owned `[H][LANES]`
/// slice (every element is stored).
pub fn forecast_from_lanes_into(shape: &Shape, fwd: &ForwardLanes,
                                out: &mut [f32]) {
    let (c, h, p_n) = (shape.c, shape.h, shape.p);
    let l_c = Lanes::load(&fwd.levels[(c - 1) * LANES..]);
    for k in 0..h {
        (Lanes::load(&fwd.out[((p_n - 1) * h + k) * LANES..]).exp()
         * l_c
         * Lanes::load(&fwd.seas_ext[(c + k) * LANES..]))
            .store(&mut out[k * LANES..]);
    }
}
// lint:hot-path-end

/// Pinball loss numerator plus `dout`/`dz` seeds for one lane group
/// (mirror of [`model::pinball_seeds`]; `smask` carries the per-lane
/// series masks, so padding lanes get exactly-zero seeds).
pub fn pinball_seeds_lanes(shape: &Shape, fwd: &ForwardLanes, tau: f32,
                           smask: Lanes, denom: f32)
                           -> (f64, Vec<f32>, Vec<f32>) {
    let (mut dout, mut dz) = (Vec::new(), Vec::new());
    let loss_num = pinball_seeds_lanes_into(shape, fwd, tau, smask, denom,
                                            &mut dout, &mut dz);
    (loss_num, dout, dz)
}

// lint:hot-path-begin — steady-state loss/seed kernel: `set_zeroed` only
// rewrites warm capacity, so no allocation after the first shaped call.
/// [`pinball_seeds_lanes`] writing the seed buffers in place (re-zeroed
/// each call: positions past `valid_positions` must stay zero).
pub fn pinball_seeds_lanes_into(shape: &Shape, fwd: &ForwardLanes, tau: f32,
                                smask: Lanes, denom: f32,
                                dout: &mut Vec<f32>, dz: &mut Vec<f32>)
                                -> f64 {
    let (h, p_n) = (shape.h, shape.p);
    let mut loss_num = 0.0f64;
    model::set_zeroed(dout, p_n * h * LANES);
    model::set_zeroed(dz, p_n * h * LANES);
    if smask.0.iter().all(|v| *v == 0.0) {
        return 0.0;
    }
    let tau_l = Lanes::splat(tau);
    let wv = smask / Lanes::splat(denom);
    let dout_ge = -tau_l * wv;
    let dout_lt = (Lanes::ONE - tau_l) * wv;
    let dz_ge = tau_l * wv;
    let dz_lt = -dout_lt;
    for p in 0..p_n.min(shape.valid_positions) {
        for k in 0..h {
            let idx = (p * h + k) * LANES;
            let d = Lanes::load(&fwd.z[idx..]) - Lanes::load(&fwd.out[idx..]);
            let per = (tau_l * d).max((tau_l - Lanes::ONE) * d);
            let weighted = per * smask;
            for l in 0..LANES {
                loss_num += weighted.0[l] as f64;
            }
            d.select_ge_zero(dout_ge, dout_lt).store(&mut dout[idx..]);
            d.select_ge_zero(dz_ge, dz_lt).store(&mut dz[idx..]);
        }
    }
    loss_num
}
// lint:hot-path-end

/// Per-lane Holt-Winters gradients for one group; `log_s_init` is SoA
/// `[s_total][LANES]`. Padding lanes hold exact zeros.
pub struct SeriesGradsLanes {
    pub alpha_logit: Lanes,
    pub gamma_logit: Lanes,
    pub gamma2_logit: Lanes,
    pub log_s_init: Vec<f32>,
}

impl SeriesGradsLanes {
    /// All-zero gradients (`s_total` is the packed seasonality width).
    pub fn zeros(s_total: usize) -> Self {
        Self {
            alpha_logit: Lanes::ZERO,
            gamma_logit: Lanes::ZERO,
            gamma2_logit: Lanes::ZERO,
            log_s_init: vec![0.0; s_total * LANES],
        }
    }
}

impl Default for SeriesGradsLanes {
    /// Width-0 placeholder for pooled scratch;
    /// [`LaneScratch::backward`] sizes `log_s_init` before any read.
    fn default() -> Self {
        Self::zeros(0)
    }
}

/// Reusable temporaries of the lane backward pass.
#[derive(Default)]
struct BackwardTmp {
    dh_seq: Vec<f32>,
    dpre: Vec<f32>,
    dh_ring: Vec<Vec<f32>>,
    dc_ring: Vec<Vec<f32>>,
    dx: Vec<f32>,
    g_h: Vec<f32>,
    g_resid: Vec<f32>,
    dzz: Vec<f32>,
    dinp: Vec<f32>,
    dlev: Vec<f32>,
    dseas_ext: Vec<f32>,
    gseas: Vec<f32>,
    gseas2: Vec<f32>,
}

impl BackwardTmp {
    fn bytes(&self) -> u64 {
        let rings: usize = self.dh_ring.iter().chain(&self.dc_ring)
            .map(|r| r.capacity())
            .sum();
        ((rings + self.dh_seq.capacity() + self.dpre.capacity()
          + self.dx.capacity() + self.g_h.capacity()
          + self.g_resid.capacity() + self.dzz.capacity()
          + self.dinp.capacity() + self.dlev.capacity()
          + self.dseas_ext.capacity() + self.gseas.capacity()
          + self.gseas2.capacity()) * 4) as u64
    }
}

/// Hand-written backward for one lane group (mirror of
/// [`model::backward_series`]; see that function and DESIGN.md for the
/// recurrence-ordering invariants, which are unchanged — lanes never
/// exchange data except in the shared-weight reductions).
/// Allocating convenience wrapper over [`LaneScratch::backward`]'s core.
pub fn backward_lanes(shape: &Shape, grp: &LaneGroup, rnn: &RnnView,
                      fwd: &ForwardLanes, dout: &[f32], dz: &[f32],
                      grads: &mut RnnGrads) -> SeriesGradsLanes {
    let mut tmp = BackwardTmp::default();
    let mut sg = SeriesGradsLanes::zeros(shape.s_total());
    backward_lanes_core(shape, grp, rnn, fwd, dout, dz, grads, &mut tmp,
                        &mut sg);
    sg
}

/// The backward body over pooled temporaries (same reuse-safety
/// classification as [`forward_lanes_core`]): identical floating-point
/// schedule to the historical allocating version.
#[allow(clippy::too_many_arguments)]
fn backward_lanes_core(shape: &Shape, grp: &LaneGroup, rnn: &RnnView,
                       fwd: &ForwardLanes, dout: &[f32], dz: &[f32],
                       grads: &mut RnnGrads, tmp: &mut BackwardTmp,
                       sg: &mut SeriesGradsLanes) {
    let (c, s, h, in_w, p_n) = (shape.c, shape.s, shape.h, shape.in_w, shape.p);
    let s2 = shape.s2;
    let dual = shape.dual();
    let hid = shape.hidden;
    let n_l = shape.n_layers();
    let din_max = fwd.din_max;
    let one = Lanes::ONE;
    let BackwardTmp {
        dh_seq, dpre, dh_ring, dc_ring, dx, g_h, g_resid, dzz, dinp, dlev,
        dseas_ext, gseas, gseas2,
    } = tmp;

    // ---- head backward, collecting dL/dh_seq ----
    model::set_len(dh_seq, p_n * hid * LANES);
    model::set_len(dpre, hid * LANES);
    for p in 0..p_n {
        let dop = &dout[p * h * LANES..(p + 1) * h * LANES];
        let a = &fwd.act[p * hid * LANES..(p + 1) * hid * LANES];
        outer_acc_lanes(a, hid, dop, 0, h, &mut grads.out_w);
        for (k, g) in grads.out_b.iter_mut().enumerate() {
            *g += Lanes::load(&dop[k * LANES..]).sum();
        }
        // da = out_w @ dout;  dpre = da * (1 - a^2)
        mat_t_vec_lanes(rnn.out_w, dop, 0, hid, h, &mut dpre);
        for k in 0..hid {
            let av = Lanes::load(&a[k * LANES..]);
            (Lanes::load(&dpre[k * LANES..]) * (one - av * av))
                .store(&mut dpre[k * LANES..]);
        }
        let hs = &fwd.h_seq[p * hid * LANES..(p + 1) * hid * LANES];
        outer_acc_lanes(hs, hid, &dpre, 0, hid, &mut grads.dense_w);
        for (k, g) in grads.dense_b.iter_mut().enumerate() {
            *g += Lanes::load(&dpre[k * LANES..]).sum();
        }
        mat_t_vec_lanes(rnn.dense_w, &dpre, 0, hid, hid,
                        &mut dh_seq[p * hid * LANES..(p + 1) * hid * LANES]);
    }

    // ---- BPTT through the dilated stack (SoA gradient rings) ----
    model::ring_reset(dh_ring, &shape.flat, hid * LANES);
    model::ring_reset(dc_ring, &shape.flat, hid * LANES);
    model::set_len(dx, p_n * in_w * LANES);

    model::set_len(g_h, din_max * LANES);
    model::set_len(g_resid, hid * LANES);
    model::set_len(dzz, 4 * hid * LANES);
    model::set_len(dinp, (din_max + hid) * LANES);
    for p in (0..p_n).rev() {
        g_h[..hid * LANES]
            .copy_from_slice(&dh_seq[p * hid * LANES..(p + 1) * hid * LANES]);
        let mut li = n_l;
        for (bi, block) in shape.blocks.iter().enumerate().rev() {
            let has_resid = bi > 0;
            if has_resid {
                g_resid.copy_from_slice(&g_h[..hid * LANES]);
            }
            for &d in block.iter().rev() {
                li -= 1;
                let slot = p % d;
                let din = shape.layer_din[li];
                let (w, _) = rnn.cells[li];
                let t = (p * n_l + li) * hid * LANES;
                let ti = (p * n_l + li) * din_max * LANES;
                let ring_at = slot * hid * LANES;
                let (gw, gb) = &mut grads.cells[li];
                for k in 0..hid {
                    let kt = t + k * LANES;
                    let kr = ring_at + k * LANES;
                    let total_dh = Lanes::load(&g_h[k * LANES..])
                        + Lanes::load(&dh_ring[li][kr..]);
                    let si = Lanes::load(&fwd.si[kt..]);
                    let sf = Lanes::load(&fwd.sf[kt..]);
                    let tg = Lanes::load(&fwd.tg[kt..]);
                    let so = Lanes::load(&fwd.so[kt..]);
                    let tanh_c = Lanes::load(&fwd.tanh_c[kt..]);
                    let c_prev = Lanes::load(&fwd.c_prev[kt..]);
                    let dc_total = Lanes::load(&dc_ring[li][kr..])
                        + total_dh * so * (one - tanh_c * tanh_c);
                    (dc_total * tg * si * (one - si)) // d i_pre
                        .store(&mut dzz[k * LANES..]);
                    (dc_total * c_prev * sf * (one - sf)) // d f_pre
                        .store(&mut dzz[(hid + k) * LANES..]);
                    (dc_total * si * (one - tg * tg)) // d g_pre
                        .store(&mut dzz[(2 * hid + k) * LANES..]);
                    (total_dh * tanh_c * so * (one - so)) // d o_pre
                        .store(&mut dzz[(3 * hid + k) * LANES..]);
                    (dc_total * sf).store(&mut dc_ring[li][kr..]); // → c_prev
                }
                let x_in = &fwd.x_in[ti..ti + din * LANES];
                let h_prev = &fwd.h_prev[t..t + hid * LANES];
                outer_acc_lanes(x_in, din, &dzz, 0, 4 * hid, gw);
                outer_acc_lanes(h_prev, hid, &dzz, din, 4 * hid, gw);
                for (k, g) in gb.iter_mut().enumerate() {
                    *g += Lanes::load(&dzz[k * LANES..]).sum();
                }
                // dinp = w @ dzz, split into d x_in | d h_prev
                mat_t_vec_lanes(w, &dzz, 0, din + hid, 4 * hid,
                                &mut dinp[..(din + hid) * LANES]);
                dh_ring[li][ring_at..ring_at + hid * LANES]
                    .copy_from_slice(&dinp[din * LANES..(din + hid) * LANES]);
                g_h[..din * LANES].copy_from_slice(&dinp[..din * LANES]);
            }
            if has_resid {
                // block_in feeds both the first layer and the skip path.
                add_assign_slice(&mut g_h[..hid * LANES],
                                 &g_resid[..hid * LANES]);
            }
        }
        dx[p * in_w * LANES..(p + 1) * in_w * LANES]
            .copy_from_slice(&g_h[..in_w * LANES]);
    }

    // ---- window backward: d levels, d seas_ext (gate by multiply) ----
    model::set_zeroed(dlev, c * LANES);
    model::set_zeroed(dseas_ext, (c + h) * LANES);
    for p in 0..p_n {
        let lvl = Lanes::load(&fwd.levels[(p + in_w - 1) * LANES..]);
        let mut dlvl = Lanes::ZERO;
        for j in 0..in_w {
            let idx = (p * in_w + j) * LANES;
            let dxj = Lanes::load(&dx[idx..]) * Lanes::load(&fwd.x_ok[idx..]);
            dlvl -= dxj / lvl;
            let se_at = (p + j) * LANES;
            (Lanes::load(&dseas_ext[se_at..])
             - dxj / Lanes::load(&fwd.seas_ext[se_at..]))
                .store(&mut dseas_ext[se_at..]);
        }
        for k in 0..h {
            let idx = (p * h + k) * LANES;
            let dzk = Lanes::load(&dz[idx..]) * Lanes::load(&fwd.z_ok[idx..]);
            dlvl -= dzk / lvl;
            let se_at = (p + in_w + k) * LANES;
            (Lanes::load(&dseas_ext[se_at..])
             - dzk / Lanes::load(&fwd.seas_ext[se_at..]))
                .store(&mut dseas_ext[se_at..]);
        }
        let dl_at = (p + in_w - 1) * LANES;
        (Lanes::load(&dlev[dl_at..]) + dlvl).store(&mut dlev[dl_at..]);
    }

    // ---- seas_ext → per-component seasonality gradients ----
    model::set_zeroed(gseas, (c + s) * LANES);
    model::set_zeroed(gseas2, if dual { (c + s2) * LANES } else { 0 });
    if dual {
        for t in 0..c {
            let dse = Lanes::load(&dseas_ext[t * LANES..]);
            (Lanes::load(&gseas[t * LANES..])
             + dse * Lanes::load(&fwd.seas2[t * LANES..]))
                .store(&mut gseas[t * LANES..]);
            (Lanes::load(&gseas2[t * LANES..])
             + dse * Lanes::load(&fwd.seas[t * LANES..]))
                .store(&mut gseas2[t * LANES..]);
        }
        for k in 0..h {
            let (i1, i2) = ((c + (k % s)) * LANES, (c + (k % s2)) * LANES);
            let dse = Lanes::load(&dseas_ext[(c + k) * LANES..]);
            (Lanes::load(&gseas[i1..]) + dse * Lanes::load(&fwd.seas2[i2..]))
                .store(&mut gseas[i1..]);
            (Lanes::load(&gseas2[i2..]) + dse * Lanes::load(&fwd.seas[i1..]))
                .store(&mut gseas2[i2..]);
        }
    } else {
        gseas[..c * LANES].copy_from_slice(&dseas_ext[..c * LANES]);
        for k in 0..h {
            let at = (c + (k % s)) * LANES;
            (Lanes::load(&gseas[at..])
             + Lanes::load(&dseas_ext[(c + k) * LANES..]))
                .store(&mut gseas[at..]);
        }
    }

    // ---- ES recurrence backward ----
    // Same ordering invariants as the scalar core (see backward_series
    // and DESIGN.md §Dual-recurrence backward ordering invariant); every
    // lane runs the scalar schedule independently.
    let (alpha, gamma, gamma2) = (fwd.alpha, fwd.gamma, fwd.gamma2);
    // dlev doubles as the running level gradient (mutated in place).
    let glev = dlev;
    let mut d_alpha = Lanes::ZERO;
    let mut d_gamma = Lanes::ZERO;
    let mut d_gamma2 = Lanes::ZERO;
    for t in (0..c).rev() {
        let l_t = Lanes::load(&fwd.levels[t * LANES..]);
        let y_t = Lanes::load(&grp.y[t * LANES..]);
        let s1_t = Lanes::load(&fwd.seas[t * LANES..]);
        let mut glev_t = Lanes::load(&glev[t * LANES..]);
        let mut gs1_t = Lanes::load(&gseas[t * LANES..]);

        // seas1[t+S1] = gamma*y_t/(l_t*s2_t) + (1-gamma)*s1_t
        let g1n = Lanes::load(&gseas[(t + s) * LANES..]);
        if dual {
            let s2_t = Lanes::load(&fwd.seas2[t * LANES..]);
            let mut gs2_t = Lanes::load(&gseas2[t * LANES..]);
            let u1 = y_t / (l_t * s2_t);
            glev_t += g1n * (-gamma * u1 / l_t);
            d_gamma += g1n * (u1 - s1_t);
            gs1_t += g1n * (one - gamma);
            gs2_t += g1n * (-gamma * u1 / s2_t);
            // seas2[t+S2] = gamma2*y_t/(l_t*s1_t) + (1-gamma2)*s2_t
            let g2n = Lanes::load(&gseas2[(t + s2) * LANES..]);
            let u2 = y_t / (l_t * s1_t);
            glev_t += g2n * (-gamma2 * u2 / l_t);
            d_gamma2 += g2n * (u2 - s2_t);
            gs1_t += g2n * (-gamma2 * u2 / s1_t);
            gs2_t += g2n * (one - gamma2);

            let g_l = glev_t;
            let s_all = s1_t * s2_t;
            if t > 0 {
                // l_t = alpha*y_t/(s1_t*s2_t) + (1-alpha)*l_{t-1}
                let l_prev = Lanes::load(&fwd.levels[(t - 1) * LANES..]);
                d_alpha += g_l * (y_t / s_all - l_prev);
                gs1_t += g_l * (-alpha * y_t / (s_all * s1_t));
                gs2_t += g_l * (-alpha * y_t / (s_all * s2_t));
                (Lanes::load(&glev[(t - 1) * LANES..]) + g_l * (one - alpha))
                    .store(&mut glev[(t - 1) * LANES..]);
            } else {
                // l_0 = y_0/(s1_0*s2_0)
                gs1_t += g_l * (-y_t / (s_all * s1_t));
                gs2_t += g_l * (-y_t / (s_all * s2_t));
            }
            gs2_t.store(&mut gseas2[t * LANES..]);
        } else {
            let u1 = y_t / l_t;
            glev_t += g1n * (-gamma * u1 / l_t);
            d_gamma += g1n * (u1 - s1_t);
            gs1_t += g1n * (one - gamma);

            let g_l = glev_t;
            if t > 0 {
                let l_prev = Lanes::load(&fwd.levels[(t - 1) * LANES..]);
                d_alpha += g_l * (y_t / s1_t - l_prev);
                gs1_t += g_l * (-alpha * y_t / (s1_t * s1_t));
                (Lanes::load(&glev[(t - 1) * LANES..]) + g_l * (one - alpha))
                    .store(&mut glev[(t - 1) * LANES..]);
            } else {
                gs1_t += g_l * (-y_t / (s1_t * s1_t));
            }
        }
        gs1_t.store(&mut gseas[t * LANES..]);
    }

    sg.alpha_logit = d_alpha * alpha * (one - alpha);
    if shape.seasonal {
        // d log s_init = d s_init * s_init (chain through exp), per block.
        let d_log_s = &mut sg.log_s_init;
        model::set_len(d_log_s, (s + s2) * LANES);
        for k in 0..s {
            (Lanes::load(&gseas[k * LANES..])
             * Lanes::load(&fwd.s_init[k * LANES..]))
                .store(&mut d_log_s[k * LANES..]);
        }
        for k in 0..s2 {
            (Lanes::load(&gseas2[k * LANES..])
             * Lanes::load(&fwd.s2_init[k * LANES..]))
                .store(&mut d_log_s[(s + k) * LANES..]);
        }
        sg.gamma_logit = d_gamma * gamma * (one - gamma);
        sg.gamma2_logit = if dual {
            d_gamma2 * gamma2 * (one - gamma2)
        } else {
            Lanes::ZERO
        };
    } else {
        // Non-seasonal: gamma pinned to 0 in-graph, no gradient flows.
        model::set_zeroed(&mut sg.log_s_init, (s + s2) * LANES);
        sg.gamma_logit = Lanes::ZERO;
        sg.gamma2_logit = Lanes::ZERO;
    }
}

/// Per-thread arena for the lane hot path: forward record + tape, loss
/// seeds, backward temporaries and the per-series gradient output, all
/// grown once to their high-water shape and reused across steps. One
/// instance lives per pool participant in the native backend, so no
/// locking or cross-thread sharing happens on the compute path.
#[derive(Default)]
pub struct LaneScratch {
    /// Forward outputs + activation tape of the most recent
    /// [`LaneScratch::forward`] call.
    pub fwd: ForwardLanes,
    ftmp: ForwardTmp,
    btmp: BackwardTmp,
    /// Loss seeds from [`LaneScratch::pinball`].
    pub dout: Vec<f32>,
    pub dz: Vec<f32>,
    /// Per-series gradients from [`LaneScratch::backward`].
    pub sg: SeriesGradsLanes,
}

impl LaneScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// [`forward_lanes`] into the pooled record (`self.fwd`).
    pub fn forward(&mut self, shape: &Shape, grp: &LaneGroup, rnn: &RnnView,
                   want_targets: bool) {
        forward_lanes_core(shape, grp, rnn, want_targets, &mut self.fwd,
                           &mut self.ftmp);
    }

    /// [`pinball_seeds_lanes`] over `self.fwd` into the pooled seed
    /// buffers; returns the loss numerator.
    pub fn pinball(&mut self, shape: &Shape, tau: f32, smask: Lanes,
                   denom: f32) -> f64 {
        pinball_seeds_lanes_into(shape, &self.fwd, tau, smask, denom,
                                 &mut self.dout, &mut self.dz)
    }

    /// [`backward_lanes`] over `self.fwd` and the pooled seeds,
    /// accumulating shared-weight gradients into `grads` and leaving the
    /// per-series gradients in `self.sg`.
    pub fn backward(&mut self, shape: &Shape, grp: &LaneGroup,
                    rnn: &RnnView, grads: &mut RnnGrads) {
        backward_lanes_core(shape, grp, rnn, &self.fwd, &self.dout,
                            &self.dz, grads, &mut self.btmp, &mut self.sg);
    }

    /// Approximate bytes pinned by this arena
    /// ([`BackendStats::scratch_bytes`] feeds from this).
    ///
    /// [`BackendStats::scratch_bytes`]: crate::runtime::backend::BackendStats
    pub fn bytes(&self) -> u64 {
        self.fwd.bytes() + self.ftmp.bytes() + self.btmp.bytes()
            + ((self.dout.capacity() + self.dz.capacity()
                + self.sg.log_s_init.capacity()) * 4) as u64
    }
}

// lint:hot-path-begin — steady-state optimizer kernel (pure in-place).
/// Lane-vectorized Adam leaf update: bit-identical to
/// [`model::adam_update`] (same operation sequence per element), with a
/// scalar tail for the `len % LANES` remainder.
#[allow(clippy::too_many_arguments)]
pub fn adam_update_lanes(p: &mut [f32], g: &[f32], m: &mut [f32],
                         v: &mut [f32], lr: f32, mult: f32, bc1: f32,
                         bc2: f32) {
    let n = p.len();
    let main = n - n % LANES;
    let b1 = Lanes::splat(model::ADAM_B1);
    let b1c = Lanes::splat(1.0 - model::ADAM_B1);
    let b2 = Lanes::splat(model::ADAM_B2);
    let b2c = Lanes::splat(1.0 - model::ADAM_B2);
    let rbc1 = Lanes::splat(bc1);
    let rbc2 = Lanes::splat(bc2);
    let eps = Lanes::splat(model::ADAM_EPS);
    let step = Lanes::splat(lr * mult);
    for i in (0..main).step_by(LANES) {
        let gv = Lanes::load(&g[i..]);
        let m2 = b1 * Lanes::load(&m[i..]) + b1c * gv;
        let v2 = b2 * Lanes::load(&v[i..]) + b2c * gv * gv;
        let upd = (m2 / rbc1) / ((v2 / rbc2).sqrt() + eps);
        (Lanes::load(&p[i..]) - step * upd).store(&mut p[i..]);
        m2.store(&mut m[i..]);
        v2.store(&mut v[i..]);
    }
    model::adam_update(&mut p[main..], &g[main..], &mut m[main..],
                       &mut v[main..], lr, mult, bc1, bc2);
}
// lint:hot-path-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Toy RNN parameters: per-cell (w, b) plus dense/out head weights.
    fn toy_rnn(shape: &Shape, seed: u64)
               -> (Vec<(Vec<f32>, Vec<f32>)>, Vec<f32>, Vec<f32>, Vec<f32>,
                   Vec<f32>) {
        let mut rng = Rng::new(seed);
        let hid = shape.hidden;
        let mut cells = Vec::new();
        for &din in &shape.layer_din {
            let lim = (6.0 / (din + hid + 4 * hid) as f64).sqrt();
            cells.push((
                (0..(din + hid) * 4 * hid)
                    .map(|_| rng.uniform(-lim, lim) as f32)
                    .collect(),
                vec![0.0; 4 * hid],
            ));
        }
        let lim_d = (6.0 / (2 * hid) as f64).sqrt();
        let dense_w = (0..hid * hid)
            .map(|_| rng.uniform(-lim_d, lim_d) as f32)
            .collect();
        let lim_o = (6.0 / (hid + shape.h) as f64).sqrt();
        let out_w = (0..hid * shape.h)
            .map(|_| rng.uniform(-lim_o, lim_o) as f32)
            .collect();
        (cells, dense_w, vec![0.0; hid], out_w, vec![0.0; shape.h])
    }

    #[test]
    fn marshal_pads_tail_and_masked_slots() {
        let shape =
            Shape::new(4, 0, 4, 5, 20, 6, &[vec![1, 2], vec![2, 4]], 6)
                .unwrap();
        let b = 11usize; // 2 groups, second fill = 3
        let c = shape.c;
        let y: Vec<f32> = (0..b * c).map(|i| 10.0 + i as f32).collect();
        let mut cat = vec![0.0f32; b * 6];
        let mut mask = vec![1.0f32; b];
        mask[1] = 0.0; // masked slot inside the first group
        for i in 0..b {
            cat[i * 6 + i % 6] = 1.0;
        }
        let alpha: Vec<f32> = (0..b).map(|i| -0.1 * i as f32).collect();
        let gamma: Vec<f32> = (0..b).map(|i| -1.0 - 0.1 * i as f32).collect();
        let log_s: Vec<f32> =
            (0..b * 4).map(|i| 0.01 * i as f32).collect();
        let groups = marshal_groups(&shape, b, &y, &cat, Some(&mask), &alpha,
                                    &gamma, &[], &log_s);
        assert_eq!(groups.len(), 2);
        assert_eq!((groups[0].start, groups[0].fill), (0, LANES));
        assert_eq!((groups[1].start, groups[1].fill), (8, 3));
        // Live lane 0 carries its series transposed.
        assert_eq!(groups[0].y[0], y[0]);
        assert_eq!(groups[0].y[3 * LANES], y[3]);
        assert_eq!(groups[0].alpha_logit.0[0], alpha[0]);
        assert_eq!(groups[0].log_s[2 * LANES], log_s[2]);
        assert_eq!(groups[0].mask.0[0], 1.0);
        // Masked lane 1 is padding: benign y, zeroed params, mask 0.
        assert_eq!(groups[0].mask.0[1], 0.0);
        assert!(groups[0].y.iter().skip(1).step_by(LANES).all(|v| *v == 1.0));
        assert_eq!(groups[0].alpha_logit.0[1], 0.0);
        // Tail lanes of the last group are padding too.
        for l in 3..LANES {
            assert_eq!(groups[1].mask.0[l], 0.0);
            assert_eq!(groups[1].y[l], 1.0);
        }
        // Lane 2 of group 1 is batch slot 10.
        assert_eq!(groups[1].y[2 * LANES + 2], y[10 * c + 2]);
        assert_eq!(groups[1].gamma_logit.0[2], gamma[10]);
    }

    #[test]
    fn adam_lanes_matches_scalar_bitwise_with_ragged_tail() {
        let mut rng = Rng::new(3);
        let n = 37usize; // 4 full lanes + tail of 5
        let g: Vec<f32> =
            (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut p1: Vec<f32> =
            (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let mut m1: Vec<f32> =
            (0..n).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        let mut v1: Vec<f32> =
            (0..n).map(|_| rng.uniform(0.0, 0.1) as f32).collect();
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        let (bc1, bc2) = (1.0 - 0.9f32.powi(3), 1.0 - 0.999f32.powi(3));
        model::adam_update(&mut p1, &g, &mut m1, &mut v1, 1e-3, 1.5, bc1, bc2);
        adam_update_lanes(&mut p2, &g, &mut m2, &mut v2, 1e-3, 1.5, bc1, bc2);
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn masked_lane_gets_exactly_zero_gradients() {
        let shape =
            Shape::new(4, 0, 4, 5, 20, 6, &[vec![1, 2], vec![2, 4]], 6)
                .unwrap();
        let mut rng = Rng::new(9);
        let b = 3usize;
        let c = shape.c;
        let mut y = Vec::new();
        for _ in 0..b {
            y.extend(crate::util::prop::gen_positive_series(&mut rng, c, 4));
        }
        let mut cat = vec![0.0f32; b * 6];
        for i in 0..b {
            cat[i * 6 + i % 6] = 1.0;
        }
        let mask = vec![1.0, 0.0, 1.0];
        let alpha = vec![-0.5f32; b];
        let gamma = vec![-1.0f32; b];
        let log_s = vec![0.05f32; b * 4];
        let groups = marshal_groups(&shape, b, &y, &cat, Some(&mask), &alpha,
                                    &gamma, &[], &log_s);
        assert_eq!(groups.len(), 1);
        let grp = &groups[0];

        let (cells_own, dense_w, dense_b, out_w, out_b) = toy_rnn(&shape, 17);
        let cells: Vec<(&[f32], &[f32])> = cells_own
            .iter()
            .map(|q| (q.0.as_slice(), q.1.as_slice()))
            .collect();
        let rnn = RnnView {
            cells: &cells,
            dense_w: &dense_w,
            dense_b: &dense_b,
            out_w: &out_w,
            out_b: &out_b,
        };
        let fwd = forward_lanes(&shape, grp, &rnn, true);
        let denom = (shape.valid_positions as f32 * 2.0 * shape.h as f32)
            .max(1.0);
        let (_, dout, dz) =
            pinball_seeds_lanes(&shape, &fwd, 0.48, grp.mask, denom);
        let mut grads = RnnGrads::zeros(&shape);
        let sg = backward_lanes(&shape, grp, &rnn, &fwd, &dout, &dz,
                                &mut grads);
        // Masked lane 1 and padding lanes 3.. are exact zeros; live lanes
        // carry gradient.
        for l in [1usize, 3, 4, 5, 6, 7] {
            assert_eq!(sg.alpha_logit.0[l], 0.0, "lane {l}");
            for k in 0..shape.s_total() {
                assert_eq!(sg.log_s_init[k * LANES + l], 0.0,
                           "lane {l} log_s[{k}]");
            }
        }
        assert!(sg.alpha_logit.0[0] != 0.0 || sg.alpha_logit.0[2] != 0.0,
                "live lanes should carry gradient");
    }
}
