//! `NativeBackend` — a pure-Rust execution backend for the ES-RNN
//! programs: no XLA, no AOT artifacts, no Python anywhere.
//!
//! The backend synthesizes its own [`Manifest`] from the Table-1 network
//! configs (so callers observe exactly the contract the PJRT artifact
//! manifest describes: same program names, same tensor leaf names, same
//! shapes) and serves three program kinds:
//!
//! * `init`       — Glorot-uniform RNN weight init seeded from
//!   [`crate::util::rng`] (distributionally equivalent to the JAX init;
//!   bit-exactness with the Threefry artifact is explicitly *not* part of
//!   the backend contract);
//! * `predict`    — the batched forward pass + §3.4 de-normalization;
//! * `train_step` — forward, hand-written backward (validated by finite
//!   differences) and the Adam update with the §3.3 per-series
//!   learning-rate multiplier;
//! * `es`         — the bare Holt-Winters layer (debug/verification
//!   program, mirroring `aot.py::lower_es`).
//!
//! The batch dimension is data-parallel at two levels. The default
//! [`ComputeMode::Lanes`] marshals the batch into structure-of-arrays
//! lane groups of [`crate::simd::LANES`] series and runs the
//! lane-vectorized kernels in [`lanes`] (the paper's §5 vectorization,
//! natively); a persistent [`pool::ComputePool`] then splits the *groups*
//! across parked worker threads (thread × lane two-level parallelism).
//! [`ComputeMode::Scalar`] keeps the original one-series-at-a-time core
//! in [`model`] — the oracle the lane kernels are property-tested
//! against — and splits the batch across threads per series. Per-series
//! gradients are independent; shared-weight gradients are reduced across
//! chunks in ascending batch order, so results are deterministic for a
//! given thread count and vary only at float-association level across
//! thread counts (chunk boundaries move, so the f32 summation
//! parenthesization differs).
//!
//! ## Steady-state hot path
//!
//! Every buffer the per-step compute touches lives in arenas owned by
//! the backend: per-participant [`lanes::LaneScratch`] /
//! [`model::ScalarScratch`] kernel arenas, a step-level scratch for the
//! marshalled lane groups and per-chunk gradient accumulators, and
//! per-program dispatch caches (Adam leaf plan + output plan, resolved
//! once). After a warmup step grows everything to its high-water shape,
//! [`NativeBackend::train_step_inplace`] — which updates params and Adam
//! state in place inside a caller-owned state map — performs **zero heap
//! allocations and zero thread spawns** per step (gated by
//! `rust/tests/steady_state.rs` and BENCH_6). The allocating
//! [`Backend::execute_named`] entry point stays as the compatibility
//! path and parity reference; it shares the same pooled compute core and
//! differs only in emitting fresh output tensors.
//!
//! Scope: every Table-1 frequency — yearly/quarterly/monthly/daily
//! (single seasonality) and the §8.2 hourly dual-seasonality (24h×168h)
//! model, whose coupled ES recurrence runs natively through
//! [`crate::hw::es_dual_filter`] with a `gamma2_logit` leaf and a packed
//! `[S1 | S2]` seasonality block. Only the §8.4 penalty variants remain
//! PJRT-artifact-only; their configs are simply absent from the native
//! manifest, which every caller already handles by name lookup.

pub mod lanes;
pub mod model;
pub mod pool;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Frequency, NetworkConfig};
use crate::simd::LANES;
use crate::util::rng::Rng;

use super::backend::{Backend, BackendStats, HostTensor};
use super::manifest::{FreqManifest, Manifest, ProgramSpec, TensorSpec};

use model::{RnnGrads, RnnView, Shape};

/// Batch sizes the native manifest advertises. Native programs have no
/// compile cost, so the ladder is denser than the artifact sweep — the
/// greedy cover and the forecast service get near-zero padding.
pub const NATIVE_BATCH_SIZES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Batch size of the `es` debug program (mirror of `aot.py`).
const ES_DEBUG_BATCH: usize = 8;

/// Frequencies with native support (all Table-1 shapes, incl. §8.2 hourly
/// dual seasonality; no §8.4 penalty variants).
const NATIVE_FREQS: [Frequency; 5] = [
    Frequency::Yearly,
    Frequency::Quarterly,
    Frequency::Monthly,
    Frequency::Daily,
    Frequency::Hourly,
];

/// Pinball quantile (paper §3.5) and per-series LR multiplier (§3.3) —
/// mirrors `python/compile/configs.py`.
pub const PINBALL_TAU: f32 = 0.48;
pub const PER_SERIES_LR_MULT: f32 = 1.5;

fn f32_spec(name: impl Into<String>, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.into(), shape, dtype: "float32".into() }
}

/// Parameter leaves in manifest (jax flat, i.e. alphabetical) order,
/// named WITHOUT the `params.` prefix.
fn param_leaves(net: &NetworkConfig, b: usize) -> Vec<(String, Vec<usize>)> {
    let hid = net.hidden;
    let h = net.horizon;
    let mut din = net.input_window + 6;
    let mut leaves = Vec::new();
    for i in 0..net.dilations.iter().flatten().count() {
        leaves.push((format!("rnn.cells.{i}.b"), vec![4 * hid]));
        leaves.push((format!("rnn.cells.{i}.w"), vec![din + hid, 4 * hid]));
        din = hid;
    }
    leaves.push(("rnn.dense_b".into(), vec![hid]));
    leaves.push(("rnn.dense_w".into(), vec![hid, hid]));
    leaves.push(("rnn.out_b".into(), vec![h]));
    leaves.push(("rnn.out_w".into(), vec![hid, h]));
    leaves.push(("series.alpha_logit".into(), vec![b]));
    if net.dual() {
        // jax flat (alphabetical) order: `gamma2_logit` < `gamma_logit`
        // because '2' sorts before '_'.
        leaves.push(("series.gamma2_logit".into(), vec![b]));
    }
    leaves.push(("series.gamma_logit".into(), vec![b]));
    leaves.push(("series.log_s_init".into(), vec![b, net.total_seasonality()]));
    leaves
}

fn train_step_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let leaves = param_leaves(net, b);
    let mut inputs = vec![
        f32_spec("data.cat", vec![b, 6]),
        f32_spec("data.mask", vec![b]),
        f32_spec("data.y", vec![b, net.length]),
    ];
    let mut outputs = vec![f32_spec("loss", vec![])];
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("params.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("params.{name}"), shape.clone()));
    }
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("opt.m.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("opt.m.{name}"), shape.clone()));
    }
    inputs.push(f32_spec("opt.step", vec![]));
    outputs.push(f32_spec("opt.step", vec![]));
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("opt.v.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("opt.v.{name}"), shape.clone()));
    }
    inputs.push(f32_spec("lr", vec![]));
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_train_step>"),
        freq: freq.to_string(),
        batch: b,
        kind: "train_step".into(),
        inputs,
        outputs,
    }
}

fn predict_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let mut inputs = vec![
        f32_spec("data.cat", vec![b, 6]),
        f32_spec("data.y", vec![b, net.length]),
    ];
    for (name, shape) in param_leaves(net, b) {
        inputs.push(f32_spec(format!("params.{name}"), shape));
    }
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_predict>"),
        freq: freq.to_string(),
        batch: b,
        kind: "predict".into(),
        inputs,
        outputs: vec![f32_spec("forecast", vec![b, net.horizon])],
    }
}

fn es_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let (c, s1, s2) = (net.length, net.seasonality, net.seasonality2);
    let mut inputs = vec![f32_spec("data.alpha_logit", vec![b])];
    if net.dual() {
        inputs.push(f32_spec("data.gamma2_logit", vec![b]));
    }
    inputs.push(f32_spec("data.gamma_logit", vec![b]));
    inputs.push(f32_spec("data.log_s_init", vec![b, s1 + s2]));
    inputs.push(f32_spec("data.y", vec![b, c]));
    let mut outputs = vec![
        f32_spec("levels", vec![b, c]),
        f32_spec("seas", vec![b, c + s1]),
    ];
    if net.dual() {
        // §8.2: the debug program emits both seasonal tracks.
        outputs.push(f32_spec("seas2", vec![b, c + s2]));
    }
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_es>"),
        freq: freq.to_string(),
        batch: b,
        kind: "es".into(),
        inputs,
        outputs,
    }
}

fn init_spec(freq: &str, net: &NetworkConfig) -> ProgramSpec {
    let outputs = param_leaves(net, 1)
        .into_iter()
        .filter(|(name, _)| name.starts_with("rnn."))
        .map(|(name, shape)| f32_spec(name, shape))
        .collect();
    ProgramSpec {
        file: format!("<native:{freq}_init>"),
        freq: freq.to_string(),
        batch: 0,
        kind: "init".into(),
        inputs: vec![TensorSpec {
            name: "key".into(),
            shape: vec![2],
            dtype: "uint32".into(),
        }],
        outputs,
    }
}

fn native_manifest() -> Manifest {
    let mut configs = HashMap::new();
    let mut programs = HashMap::new();
    for freq in NATIVE_FREQS {
        let net = NetworkConfig::for_freq(freq)
            .expect("native frequencies always have a network config");
        let name = freq.name();
        configs.insert(name.to_string(), FreqManifest {
            seasonality: net.seasonality,
            seasonality2: net.seasonality2,
            horizon: net.horizon,
            input_window: net.input_window,
            length: net.length,
            hidden: net.hidden,
            dilations: net.dilations.clone(),
            positions: net.positions()
                .expect("Table-1 configs always have positions"),
            valid_positions: net.valid_positions()
                .expect("Table-1 configs always have valid positions"),
        });
        programs.insert(Manifest::program_name(name, 0, "init"),
                        init_spec(name, &net));
        programs.insert(Manifest::program_name(name, ES_DEBUG_BATCH, "es"),
                        es_spec(name, &net, ES_DEBUG_BATCH));
        for &b in NATIVE_BATCH_SIZES {
            programs.insert(Manifest::program_name(name, b, "train_step"),
                            train_step_spec(name, &net, b));
            programs.insert(Manifest::program_name(name, b, "predict"),
                            predict_spec(name, &net, b));
        }
    }
    Manifest {
        version: 1,
        variant: "native".into(),
        tau: PINBALL_TAU,
        per_series_lr_mult: PER_SERIES_LR_MULT,
        batch_sizes: NATIVE_BATCH_SIZES.to_vec(),
        configs,
        programs,
    }
}

/// Which native kernel implementation executes batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// One series at a time through [`model`] — the reference/oracle
    /// path the lane kernels are property-tested against.
    Scalar,
    /// Lane-vectorized SoA batch kernels ([`lanes`], default): every hot
    /// path advances [`LANES`] series per step.
    Lanes,
}

/// The pure-Rust execution backend.
pub struct NativeBackend {
    manifest: Manifest,
    threads: usize,
    mode: ComputeMode,
    // lint:lock-name(native.stats)
    stats: Mutex<BackendStats>,
    /// Persistent worker pool (spawned lazily, parked between calls).
    pool: pool::ComputePool,
    /// Per-frequency compute shapes, resolved once at construction so
    /// dispatch never re-derives them.
    shapes: HashMap<String, Shape>,
    /// Per-program dispatch caches (Adam leaf plan + output plan), built
    /// lazily on first execution of each program name.
    // lint:lock-name(native.dispatch)
    dispatch: Mutex<HashMap<String, Arc<ProgramCache>>>,
    /// Per-participant kernel arenas, indexed by pool participant id.
    // lint:lock-name(native.worker_scratch)
    worker_scratch: Vec<Mutex<WorkerScratch>>,
    /// Step-level scratch (lane groups, chunk ranges, gradient
    /// accumulators) for `train_step`.
    // lint:lock-name(native.step)
    step: Mutex<StepScratch>,
    /// Step-level scratch for `predict`.
    // lint:lock-name(native.predict)
    predict: Mutex<PredictScratch>,
}

impl NativeBackend {
    /// Backend using every available core for batch parallelism.
    pub fn new() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Backend with an explicit worker-thread cap (1 = fully sequential).
    /// The kernel mode defaults to [`ComputeMode::Lanes`];
    /// `FAST_ESRNN_NATIVE_MODE=scalar` selects the scalar oracle path
    /// (benches construct both explicitly via [`Self::with_threads_mode`]).
    pub fn with_threads(threads: usize) -> Self {
        let mode = match std::env::var("FAST_ESRNN_NATIVE_MODE").as_deref() {
            Ok("scalar") => ComputeMode::Scalar,
            Ok("lanes") | Err(_) => ComputeMode::Lanes,
            Ok(other) => panic!(
                "FAST_ESRNN_NATIVE_MODE=`{other}` is not a native kernel \
                 mode (expected `scalar` or `lanes`)"),
        };
        Self::with_threads_mode(threads, mode)
    }

    /// Backend with an explicit thread cap and kernel mode.
    pub fn with_threads_mode(threads: usize, mode: ComputeMode) -> Self {
        Self::build(threads, mode, pool::PoolMode::Persistent)
    }

    /// Like [`Self::with_threads_mode`] but spawning fresh workers every
    /// call (the pre-pool behavior) — the BENCH_6 A/B baseline.
    pub fn with_threads_mode_spawn(threads: usize, mode: ComputeMode)
                                   -> Self {
        Self::build(threads, mode, pool::PoolMode::SpawnPerCall)
    }

    fn build(threads: usize, mode: ComputeMode, pmode: pool::PoolMode)
             -> Self {
        let threads = threads.max(1);
        let manifest = native_manifest();
        let mut shapes = HashMap::with_capacity(NATIVE_FREQS.len());
        for freq in NATIVE_FREQS {
            let name = freq.name();
            let cfg = manifest
                .config(name)
                .expect("native manifest covers its own frequencies");
            shapes.insert(
                name.to_string(),
                Shape::new(cfg.seasonality, cfg.seasonality2, cfg.horizon,
                           cfg.input_window, cfg.length, cfg.hidden,
                           &cfg.dilations, 6)
                    .expect("Table-1 configs produce valid shapes"),
            );
        }
        Self {
            manifest,
            threads,
            mode,
            stats: Mutex::new(BackendStats::default()),
            pool: pool::ComputePool::with_mode(threads, pmode),
            shapes,
            dispatch: Mutex::new(HashMap::new()),
            worker_scratch: (0..threads)
                .map(|_| Mutex::new(WorkerScratch::default()))
                .collect(),
            step: Mutex::new(StepScratch::default()),
            predict: Mutex::new(PredictScratch::default()),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn mode(&self) -> ComputeMode {
        self.mode
    }

    fn shape_for(&self, freq: &str) -> Result<&Shape> {
        self.shapes
            .get(freq)
            .ok_or_else(|| anyhow!("no native shape for frequency `{freq}`"))
    }

    /// Dispatch cache for `name`: resolved Adam leaf plan + output plan.
    /// Built once per program name, lookup-only afterwards.
    fn program_cache(&self, name: &str, spec: &ProgramSpec)
                     -> Result<Arc<ProgramCache>> {
        let mut map = self.dispatch.lock().unwrap();
        if let Some(cache) = map.get(name) {
            return Ok(Arc::clone(cache));
        }
        let cache = Arc::new(ProgramCache::for_train_spec(
            spec, self.manifest.per_series_lr_mult)?);
        map.insert(name.to_string(), Arc::clone(&cache));
        Ok(cache)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Fetch an input tensor by name, preserving the underlying lifetime.
fn get_in<'x>(inputs: &HashMap<&str, &'x HostTensor>, name: &str)
              -> Result<&'x HostTensor> {
    inputs
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("missing input `{name}`"))
}

fn get_data<'x>(inputs: &HashMap<&str, &'x HostTensor>, name: &str)
                -> Result<&'x [f32]> {
    Ok(get_in(inputs, name)?.data.as_slice())
}

/// Upper bound on dilated-LSTM layers a native program may carry. The
/// fixed-size array lets [`TrainInputs`] resolve cell leaves without any
/// heap allocation (Table-1 maxes out at 4 layers; 16 is headroom).
const MAX_LAYERS: usize = 16;

/// Warmup executions before [`NativeBackend::train_step_inplace`] starts
/// charging `BackendStats::steady_allocs`: the first steps grow arenas to
/// their high-water shapes, which is expected allocation.
const STEADY_WARMUP: u64 = 3;

/// All input slices a train/predict step consumes, resolved by tensor
/// name with zero heap allocation (no format!-built keys, no per-call
/// Vec). `mask`/`lr`/`opt.step` stay empty/zero for predict programs;
/// `gamma2_logit` is present only for §8.2 dual configs.
struct TrainInputs<'a> {
    y: &'a [f32],
    cat: &'a [f32],
    mask: &'a [f32],
    lr: f32,
    step_old: f32,
    cells: [(&'a [f32], &'a [f32]); MAX_LAYERS],
    n_layers: usize,
    dense_w: &'a [f32],
    dense_b: &'a [f32],
    out_w: &'a [f32],
    out_b: &'a [f32],
    alpha_logit: &'a [f32],
    gamma_logit: &'a [f32],
    gamma2_logit: &'a [f32],
    log_s: &'a [f32],
}

impl<'a> TrainInputs<'a> {
    fn empty() -> Self {
        Self {
            y: &[],
            cat: &[],
            mask: &[],
            lr: 0.0,
            step_old: 0.0,
            cells: [(&[] as &[f32], &[] as &[f32]); MAX_LAYERS],
            n_layers: 0,
            dense_w: &[],
            dense_b: &[],
            out_w: &[],
            out_b: &[],
            alpha_logit: &[],
            gamma_logit: &[],
            gamma2_logit: &[],
            log_s: &[],
        }
    }

    /// Route one named tensor into its slot. Adam state (`opt.m.*` /
    /// `opt.v.*`) is resolved per leaf by the update loop, not here;
    /// unknown names are ignored (the manifest spec is the gatekeeper).
    fn assign(&mut self, name: &str, t: &'a HostTensor) -> Result<()> {
        fn scalar_of(name: &str, d: &[f32]) -> Result<f32> {
            d.first()
                .copied()
                .ok_or_else(|| anyhow!("scalar input `{name}` is empty"))
        }
        let d = t.data.as_slice();
        match name {
            "data.y" => self.y = d,
            "data.cat" => self.cat = d,
            "data.mask" => self.mask = d,
            "lr" => self.lr = scalar_of(name, d)?,
            "opt.step" => self.step_old = scalar_of(name, d)?,
            "params.rnn.dense_w" => self.dense_w = d,
            "params.rnn.dense_b" => self.dense_b = d,
            "params.rnn.out_w" => self.out_w = d,
            "params.rnn.out_b" => self.out_b = d,
            "params.series.alpha_logit" => self.alpha_logit = d,
            "params.series.gamma_logit" => self.gamma_logit = d,
            "params.series.gamma2_logit" => self.gamma2_logit = d,
            "params.series.log_s_init" => self.log_s = d,
            other => {
                if let Some(rest) = other.strip_prefix("params.rnn.cells.") {
                    let (idx, leaf) = rest.split_once('.').ok_or_else(
                        || anyhow!("unparseable cell leaf `{other}`"))?;
                    let i: usize = idx.parse().map_err(
                        |_| anyhow!("bad cell index in `{other}`"))?;
                    if i >= MAX_LAYERS {
                        bail!("cell layer {i} exceeds the native layer \
                               bound {MAX_LAYERS}");
                    }
                    match leaf {
                        "w" => self.cells[i].0 = d,
                        "b" => self.cells[i].1 = d,
                        _ => bail!("unknown cell leaf `{other}`"),
                    }
                    self.n_layers = self.n_layers.max(i + 1);
                }
            }
        }
        Ok(())
    }

    /// Shared-weight view for the compute core.
    fn rnn_view(&self) -> RnnView<'_> {
        RnnView {
            cells: &self.cells[..self.n_layers],
            dense_w: self.dense_w,
            dense_b: self.dense_b,
            out_w: self.out_w,
            out_b: self.out_b,
        }
    }

    /// Bundle slot `i`'s per-series parameters (`w` = packed `[S1|S2]`
    /// width).
    fn hw(&self, i: usize, w: usize) -> model::HwView<'a> {
        model::HwView {
            alpha_logit: self.alpha_logit[i],
            gamma_logit: self.gamma_logit[i],
            gamma2_logit: if self.gamma2_logit.is_empty() {
                0.0
            } else {
                self.gamma2_logit[i]
            },
            log_s_init: &self.log_s[i * w..(i + 1) * w],
        }
    }

    /// Bounds-check every resolved slice against `shape`/`b` so the
    /// compute core can index without surprises. `train` additionally
    /// requires the mask.
    fn validate(&self, shape: &Shape, b: usize, train: bool) -> Result<()> {
        let (hid, w) = (shape.hidden, shape.s_total());
        if self.y.len() != b * shape.c {
            bail!("data.y has {} elems, want {}", self.y.len(), b * shape.c);
        }
        if self.cat.len() != b * 6 {
            bail!("data.cat has {} elems, want {}", self.cat.len(), b * 6);
        }
        if train && self.mask.len() != b {
            bail!("data.mask has {} elems, want {b}", self.mask.len());
        }
        if self.alpha_logit.len() != b || self.gamma_logit.len() != b {
            bail!("per-series logits not sized [{b}]");
        }
        if shape.dual() && self.gamma2_logit.len() != b {
            bail!("dual config without a [{b}] gamma2_logit");
        }
        if self.log_s.len() != b * w {
            bail!("log_s_init has {} elems, want {}", self.log_s.len(), b * w);
        }
        if self.n_layers != shape.n_layers() {
            bail!("resolved {} cell layers, shape has {}", self.n_layers,
                  shape.n_layers());
        }
        for (li, &din) in shape.layer_din.iter().enumerate() {
            let (wt, bt) = self.cells[li];
            if wt.len() != (din + hid) * 4 * hid || bt.len() != 4 * hid {
                bail!("cell {li} weights not sized for din {din}, hid {hid}");
            }
        }
        if self.dense_w.len() != hid * hid || self.dense_b.len() != hid
            || self.out_w.len() != hid * shape.h
            || self.out_b.len() != shape.h
        {
            bail!("head weights not sized for hid {hid}, h {}", shape.h);
        }
        Ok(())
    }
}

/// Which gradient buffer in [`StepScratch`] feeds a parameter leaf's Adam
/// update — parsed from the leaf name once per program, so the hot path
/// never string-matches.
enum GradKey {
    CellW(usize),
    CellB(usize),
    DenseW,
    DenseB,
    OutW,
    OutB,
    Alpha,
    Gamma,
    Gamma2,
    LogS,
}

fn parse_grad_key(leaf: &str) -> Result<GradKey> {
    Ok(match leaf {
        "rnn.dense_w" => GradKey::DenseW,
        "rnn.dense_b" => GradKey::DenseB,
        "rnn.out_w" => GradKey::OutW,
        "rnn.out_b" => GradKey::OutB,
        "series.alpha_logit" => GradKey::Alpha,
        "series.gamma_logit" => GradKey::Gamma,
        "series.gamma2_logit" => GradKey::Gamma2,
        "series.log_s_init" => GradKey::LogS,
        other => {
            let rest = other.strip_prefix("rnn.cells.").ok_or_else(
                || anyhow!("unknown parameter leaf `{other}`"))?;
            let (idx, kind) = rest.split_once('.').ok_or_else(
                || anyhow!("unparseable cell leaf `{other}`"))?;
            let i: usize = idx
                .parse()
                .map_err(|_| anyhow!("bad cell index in `{other}`"))?;
            match kind {
                "w" => GradKey::CellW(i),
                "b" => GradKey::CellB(i),
                _ => bail!("unknown cell leaf `{other}`"),
            }
        }
    })
}

/// One Adam-updated parameter leaf with its pre-resolved tensor names
/// (`params.*` / `opt.m.*` / `opt.v.*`), gradient source and LR
/// multiplier.
struct AdamLeaf {
    pname: String,
    mname: String,
    vname: String,
    key: GradKey,
    mult: f32,
    shape: Vec<usize>,
}

/// Where each program output comes from, aligned with `spec.outputs`.
enum OutSlot {
    Loss,
    Step,
    Param(usize),
    M(usize),
    V(usize),
}

/// Per-program dispatch cache: everything `run_train_step` used to
/// re-derive from strings every call (leaf list, gradient routing,
/// output ordering), resolved once.
struct ProgramCache {
    adam: Vec<AdamLeaf>,
    out_plan: Vec<OutSlot>,
}

impl ProgramCache {
    fn for_train_spec(spec: &ProgramSpec, per_series_mult: f32)
                      -> Result<Self> {
        let mut adam = Vec::new();
        let mut index: HashMap<&str, usize> = HashMap::new();
        for ospec in &spec.outputs {
            let Some(leaf) = ospec.name.strip_prefix("params.") else {
                continue;
            };
            index.insert(leaf, adam.len());
            adam.push(AdamLeaf {
                pname: ospec.name.clone(),
                mname: format!("opt.m.{leaf}"),
                vname: format!("opt.v.{leaf}"),
                key: parse_grad_key(leaf)?,
                mult: if leaf.starts_with("series.") {
                    per_series_mult
                } else {
                    1.0
                },
                shape: ospec.shape.clone(),
            });
        }
        let leaf_idx = |leaf: &str| -> Result<usize> {
            index
                .get(leaf)
                .copied()
                .ok_or_else(|| anyhow!("output leaf `{leaf}` has no \
                                        matching params output"))
        };
        let mut out_plan = Vec::with_capacity(spec.outputs.len());
        for ospec in &spec.outputs {
            let slot = match ospec.name.as_str() {
                "loss" => OutSlot::Loss,
                "opt.step" => OutSlot::Step,
                n => {
                    if let Some(leaf) = n.strip_prefix("params.") {
                        OutSlot::Param(leaf_idx(leaf)?)
                    } else if let Some(leaf) = n.strip_prefix("opt.m.") {
                        OutSlot::M(leaf_idx(leaf)?)
                    } else if let Some(leaf) = n.strip_prefix("opt.v.") {
                        OutSlot::V(leaf_idx(leaf)?)
                    } else {
                        bail!("unroutable train_step output `{n}`");
                    }
                }
            };
            out_plan.push(slot);
        }
        Ok(Self { adam, out_plan })
    }
}

/// Per-participant kernel arenas (one per pool participant id; workers
/// lock only their own entry, so there is no contention on the compute
/// path).
#[derive(Default)]
struct WorkerScratch {
    lane: lanes::LaneScratch,
    scalar: model::ScalarScratch,
}

impl WorkerScratch {
    fn bytes(&self) -> u64 {
        self.lane.bytes() + self.scalar.bytes()
    }
}

/// One chunk's gradient accumulators. Pre-zeroed before every round so
/// chunks whose groups are entirely masked contribute exact zeros
/// without writing; the slot-gradient buffers are chunk-local (offset by
/// the chunk's first batch slot) and copied into [`StepScratch`]'s
/// global buffers during the ascending-order merge.
#[derive(Default)]
struct ChunkOut {
    loss: f64,
    rnn_grads: RnnGrads,
    d_alpha: Vec<f32>,
    d_gamma: Vec<f32>,
    d_gamma2: Vec<f32>,
    d_log_s: Vec<f32>,
}

impl ChunkOut {
    fn bytes(&self) -> u64 {
        self.rnn_grads.bytes()
            + (4 * (self.d_alpha.capacity() + self.d_gamma.capacity()
                    + self.d_gamma2.capacity()
                    + self.d_log_s.capacity())) as u64
    }
}

/// Step-level scratch for `train_step`: marshalled lane groups, chunk
/// ranges, per-chunk accumulators and the merged global gradients. The
/// `chunk_outs` vec only grows; rounds use the first `ranges.len()`
/// entries.
#[derive(Default)]
struct StepScratch {
    groups: Vec<lanes::LaneGroup>,
    ranges: Vec<(usize, usize)>,
    // lint:lock-name(native.chunk_outs)
    chunk_outs: Vec<Mutex<ChunkOut>>,
    rnn_grads: RnnGrads,
    d_alpha: Vec<f32>,
    d_gamma: Vec<f32>,
    d_gamma2: Vec<f32>,
    d_log_s: Vec<f32>,
}

impl StepScratch {
    fn bytes(&self) -> u64 {
        let groups: u64 = self.groups.iter().map(|g| g.bytes()).sum();
        let chunks: u64 = self
            .chunk_outs
            .iter()
            .map(|c| c.lock().unwrap().bytes())
            .sum();
        groups + chunks + self.rnn_grads.bytes()
            + (16 * self.ranges.capacity()) as u64
            + (4 * (self.d_alpha.capacity() + self.d_gamma.capacity()
                    + self.d_gamma2.capacity()
                    + self.d_log_s.capacity())) as u64
    }
}

/// Step-level scratch for `predict`: lane groups, chunk ranges and
/// per-chunk forecast rows (SoA `[H][LANES]` per group for the lane
/// path, `[H]` per series for the scalar path).
#[derive(Default)]
struct PredictScratch {
    groups: Vec<lanes::LaneGroup>,
    ranges: Vec<(usize, usize)>,
    // lint:lock-name(native.chunk_rows)
    chunk_rows: Vec<Mutex<Vec<f32>>>,
}

impl PredictScratch {
    fn bytes(&self) -> u64 {
        let groups: u64 = self.groups.iter().map(|g| g.bytes()).sum();
        let rows: usize = self
            .chunk_rows
            .iter()
            .map(|r| r.lock().unwrap().capacity())
            .sum();
        groups + (4 * rows) as u64 + (16 * self.ranges.capacity()) as u64
    }
}

/// Gradient slice for one Adam leaf out of the merged step scratch.
fn grad_slice<'s>(key: &GradKey, st: &'s StepScratch) -> &'s [f32] {
    match key {
        GradKey::CellW(i) => &st.rnn_grads.cells[*i].0,
        GradKey::CellB(i) => &st.rnn_grads.cells[*i].1,
        GradKey::DenseW => &st.rnn_grads.dense_w,
        GradKey::DenseB => &st.rnn_grads.dense_b,
        GradKey::OutW => &st.rnn_grads.out_w,
        GradKey::OutB => &st.rnn_grads.out_b,
        GradKey::Alpha => &st.d_alpha,
        GradKey::Gamma => &st.d_gamma,
        GradKey::Gamma2 => &st.d_gamma2,
        GradKey::LogS => &st.d_log_s,
    }
}

/// Split `0..n` into `min(threads, n)` contiguous near-equal chunks
/// (sizes differ by at most one), writing into a pooled buffer.
///
/// This replaces a `div_ceil`-based split that could *under-fill* the
/// thread budget: ceil(9/8)=2 elements per chunk yields only 5 chunks
/// for 8 threads, idling 3 of them. The quotient/remainder split always
/// produces exactly `min(threads, n)` chunks.
fn chunks_into(n: usize, threads: usize, out: &mut Vec<(usize, usize)>) {
    out.clear();
    if n == 0 {
        return;
    }
    let k = threads.min(n).max(1);
    let (base, rem) = (n / k, n % k);
    let mut lo = 0;
    for i in 0..k {
        let hi = lo + base + usize::from(i < rem);
        out.push((lo, hi));
        lo = hi;
    }
}

/// Allocating wrapper over [`chunks_into`] (tests and one-shot callers).
#[cfg(test)]
fn chunks(n: usize, threads: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    chunks_into(n, threads, &mut out);
    out
}

impl Backend for NativeBackend {
    fn execute_named<'a>(
        &self,
        name: &str,
        lookup: &mut dyn FnMut(&TensorSpec) -> Result<&'a HostTensor>,
    ) -> Result<Vec<(String, HostTensor)>> {
        // Borrow the spec straight out of the manifest — the pre-pool
        // code cloned the whole ProgramSpec (inputs + outputs vectors)
        // on every dispatch.
        let spec = self.manifest.program(name)?;
        let t0 = Instant::now();
        let mut inputs: HashMap<&str, &'a HostTensor> =
            HashMap::with_capacity(spec.inputs.len());
        for ispec in &spec.inputs {
            if ispec.dtype != "float32" {
                bail!("input `{}` has dtype {}, execute_named only handles \
                       float32", ispec.name, ispec.dtype);
            }
            let host = lookup(ispec)
                .with_context(|| format!("packing input `{}`", ispec.name))?;
            if host.shape != ispec.shape {
                bail!("tensor `{}`: host shape {:?} != manifest shape {:?}",
                      ispec.name, host.shape, ispec.shape);
            }
            inputs.insert(ispec.name.as_str(), host);
        }
        let pack = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let shape = self.shape_for(&spec.freq)?;
        let out = match spec.kind.as_str() {
            "train_step" => self.run_train_step(name, spec, shape, &inputs)?,
            "predict" => self.run_predict(spec, shape, &inputs)?,
            "es" => run_es(spec, shape, &inputs)?,
            other => bail!("native backend cannot execute kind `{other}`"),
        };
        let exec = t1.elapsed().as_secs_f64();

        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.pack_secs += pack;
        st.execute_secs += exec;
        Ok(out)
    }

    fn execute_init(&self, freq: &str, seed: u64) -> Result<Vec<(String, HostTensor)>> {
        let name = Manifest::program_name(freq, 0, "init");
        let spec = self.manifest.program(&name)?;
        // Per-frequency stream: fold the frequency name into the seed so
        // identically-seeded frequencies don't share weights.
        let mut salted = seed ^ 0x9E37_79B9_7F4A_7C15;
        for byte in freq.bytes() {
            salted = salted.wrapping_mul(0x0000_0100_0000_01B3) ^ byte as u64;
        }
        let mut rng = Rng::new(salted);
        let mut out = Vec::with_capacity(spec.outputs.len());
        for ospec in &spec.outputs {
            let n = ospec.elem_count();
            let data = if ospec.name.ends_with(".w")
                || ospec.name.ends_with("_w")
            {
                // Glorot-uniform on (fan_in, fan_out) = (rows, cols).
                let (rows, cols) = (ospec.shape[0], ospec.shape[1]);
                let lim = (6.0 / (rows + cols) as f64).sqrt();
                (0..n).map(|_| rng.uniform(-lim, lim) as f32).collect()
            } else {
                vec![0.0; n] // biases start at zero (init_rnn_params)
            };
            out.push((ospec.name.clone(),
                      HostTensor::new(ospec.shape.clone(), data)?));
        }
        Ok(out)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        let kernels = match self.mode {
            ComputeMode::Scalar => "scalar",
            ComputeMode::Lanes => "lane",
        };
        format!("native-cpu ({} threads, {kernels} kernels)", self.threads)
    }

    fn stats(&self) -> BackendStats {
        // Clone under the stats lock, then augment from the pool and
        // scratch arenas (the statement-temporary guard drops before the
        // scratch locks are taken, so there is no nested-lock ordering).
        let mut st = self.stats.lock().unwrap().clone();
        st.spawns = self.pool.spawns();
        let mut scratch: u64 = self
            .worker_scratch
            .iter()
            .map(|w| w.lock().unwrap().bytes())
            .sum();
        scratch += self.step.lock().unwrap().bytes();
        scratch += self.predict.lock().unwrap().bytes();
        st.scratch_bytes = scratch;
        st
    }
}

impl NativeBackend {
    /// Resolve and bounds-check every input of `spec` out of the
    /// execute_named input table.
    fn resolve_inputs<'a>(&self, spec: &ProgramSpec,
                          inputs: &HashMap<&str, &'a HostTensor>, b: usize,
                          shape: &Shape, train: bool)
                          -> Result<TrainInputs<'a>> {
        let mut ti = TrainInputs::empty();
        for ispec in &spec.inputs {
            ti.assign(&ispec.name, get_in(inputs, &ispec.name)?)?;
        }
        ti.validate(shape, b, train)?;
        Ok(ti)
    }

    fn run_predict(&self, spec: &ProgramSpec, shape: &Shape,
                   inputs: &HashMap<&str, &HostTensor>)
                   -> Result<Vec<(String, HostTensor)>> {
        let b = spec.batch;
        let ti = self.resolve_inputs(spec, inputs, b, shape, false)?;
        let rnn = ti.rnn_view();
        let (c, h, w) = (shape.c, shape.h, shape.s_total());

        // The forecast tensor is handed to the caller, so it is a fresh
        // allocation by design; all intermediate storage is pooled.
        let mut forecast = vec![0.0f32; b * h];
        let mut prp = self.predict.lock().unwrap();
        if self.mode == ComputeMode::Lanes {
            {
                let pr = &mut *prp;
                lanes::marshal_groups_into(
                    &mut pr.groups, shape, b, ti.y, ti.cat, None,
                    ti.alpha_logit, ti.gamma_logit, ti.gamma2_logit,
                    ti.log_s);
                chunks_into(pr.groups.len(), self.threads, &mut pr.ranges);
                while pr.chunk_rows.len() < pr.ranges.len() {
                    pr.chunk_rows.push(Mutex::new(Vec::new()));
                }
                for (ci, &(lo, hi)) in pr.ranges.iter().enumerate() {
                    let mut rows = pr.chunk_rows[ci].lock().unwrap();
                    // Fully overwritten below: every [k][lane] slot is
                    // stored by forecast_from_lanes_into.
                    model::set_len(&mut rows, (hi - lo) * h * LANES);
                }
            }
            let n_chunks = prp.ranges.len();
            let prv: &PredictScratch = &*prp;
            let task = |ci: usize, pid: usize| {
                let (lo, hi) = prv.ranges[ci];
                let mut scr = self.worker_scratch[pid].lock().unwrap();
                let mut rows = prv.chunk_rows[ci].lock().unwrap();
                for gi in lo..hi {
                    scr.lane.forward(shape, &prv.groups[gi], &rnn, false);
                    let off = (gi - lo) * h * LANES;
                    lanes::forecast_from_lanes_into(
                        shape, &scr.lane.fwd,
                        &mut rows[off..off + h * LANES]);
                }
            };
            self.pool.run(n_chunks, &task);
            let pr = &mut *prp;
            for (ci, &(lo, hi)) in pr.ranges.iter().enumerate() {
                let rows = pr.chunk_rows[ci].get_mut().unwrap();
                for gi in lo..hi {
                    let grp = &pr.groups[gi];
                    let off = (gi - lo) * h * LANES;
                    // De-marshal: lane l of the SoA forecast is batch
                    // slot start + l; padding lanes are dropped.
                    for l in 0..grp.fill {
                        for k in 0..h {
                            forecast[(grp.start + l) * h + k] =
                                rows[off + k * LANES + l];
                        }
                    }
                }
            }
        } else {
            {
                let pr = &mut *prp;
                pr.groups.clear();
                chunks_into(b, self.threads, &mut pr.ranges);
                while pr.chunk_rows.len() < pr.ranges.len() {
                    pr.chunk_rows.push(Mutex::new(Vec::new()));
                }
                for (ci, &(lo, hi)) in pr.ranges.iter().enumerate() {
                    let mut rows = pr.chunk_rows[ci].lock().unwrap();
                    model::set_len(&mut rows, (hi - lo) * h);
                }
            }
            let n_chunks = prp.ranges.len();
            let prv: &PredictScratch = &*prp;
            let task = |ci: usize, pid: usize| {
                let (lo, hi) = prv.ranges[ci];
                let mut scr = self.worker_scratch[pid].lock().unwrap();
                let mut rows = prv.chunk_rows[ci].lock().unwrap();
                for i in lo..hi {
                    scr.scalar.forward(
                        shape, &ti.y[i * c..(i + 1) * c],
                        &ti.cat[i * 6..(i + 1) * 6], &rnn, ti.hw(i, w),
                        false);
                    let o = (i - lo) * h;
                    model::forecast_into(shape, &scr.scalar.fwd,
                                         &mut rows[o..o + h]);
                }
            };
            self.pool.run(n_chunks, &task);
            let pr = &mut *prp;
            for (ci, &(lo, hi)) in pr.ranges.iter().enumerate() {
                let rows = pr.chunk_rows[ci].get_mut().unwrap();
                forecast[lo * h..hi * h]
                    .copy_from_slice(&rows[..(hi - lo) * h]);
            }
        }
        drop(prp);
        Ok(vec![("forecast".into(),
                 HostTensor::new(vec![b, h], forecast)?)])
    }

    /// Forward + backward for one batch: pooled compute over the
    /// persistent worker pool, gradients merged into the step scratch in
    /// ascending chunk order (the determinism contract — results are
    /// bitwise-stable for a given thread count). Returns the scalar loss
    /// and the guard on the scratch holding the merged gradients.
    fn train_grads<'s>(&'s self, shape: &Shape, ti: &TrainInputs, b: usize,
                       tau: f32)
                       -> Result<(f32, MutexGuard<'s, StepScratch>)> {
        let w = shape.s_total();
        // Global loss denominator (pinball_ref): Σ mask over (P, B) × H.
        let mask_sum: f32 = ti.mask.iter().sum();
        let denom = ((shape.valid_positions as f32) * mask_sum
                     * shape.h as f32).max(1.0);
        let rnn = ti.rnn_view();

        let mut stp = self.step.lock().unwrap();
        {
            let st = &mut *stp;
            st.rnn_grads.reset(shape);
            model::set_zeroed(&mut st.d_alpha, b);
            model::set_zeroed(&mut st.d_gamma, b);
            model::set_zeroed(&mut st.d_gamma2, b);
            model::set_zeroed(&mut st.d_log_s, b * w);
        }
        let mut loss = 0.0f64;
        if self.mode == ComputeMode::Lanes {
            // lint:hot-path-begin — steady-state training kernel: once the
            // scratch arenas are warm this branch must not allocate (the
            // static twin of the CountingAlloc gate in steady_state.rs).
            // Lane path: marshal into SoA groups, chunk over groups; each
            // worker advances LANES series per kernel step. Chunk ci
            // covers groups [lo, hi) = batch slots [lo*LANES,
            // min(hi*LANES, b)); its gradient buffers are chunk-local at
            // that offset.
            {
                let st = &mut *stp;
                lanes::marshal_groups_into(
                    &mut st.groups, shape, b, ti.y, ti.cat, Some(ti.mask),
                    ti.alpha_logit, ti.gamma_logit, ti.gamma2_logit,
                    ti.log_s);
                chunks_into(st.groups.len(), self.threads, &mut st.ranges);
                while st.chunk_outs.len() < st.ranges.len() {
                    st.chunk_outs.push(Mutex::new(ChunkOut::default()));
                }
                for (ci, &(lo, hi)) in st.ranges.iter().enumerate() {
                    let mut co = st.chunk_outs[ci].lock().unwrap();
                    co.loss = 0.0;
                    co.rnn_grads.reset(shape);
                    let n = (hi * LANES).min(b) - lo * LANES;
                    // Zero-REQUIRED: masked/padded series must
                    // contribute exact-zero gradients without writing.
                    model::set_zeroed(&mut co.d_alpha, n);
                    model::set_zeroed(&mut co.d_gamma, n);
                    model::set_zeroed(&mut co.d_gamma2, n);
                    model::set_zeroed(&mut co.d_log_s, n * w);
                }
            }
            let n_chunks = stp.ranges.len();
            let stv: &StepScratch = &*stp;
            let task = |ci: usize, pid: usize| {
                let (lo, hi) = stv.ranges[ci];
                let mut scr = self.worker_scratch[pid].lock().unwrap();
                let mut co = stv.chunk_outs[ci].lock().unwrap();
                let co = &mut *co;
                let slot_lo = lo * LANES;
                for gi in lo..hi {
                    let grp = &stv.groups[gi];
                    if grp.mask.0.iter().all(|v| *v == 0.0) {
                        // Entirely padded group: the pre-zeroed buffers
                        // already hold the exact-zero contribution.
                        continue;
                    }
                    scr.lane.forward(shape, grp, &rnn, true);
                    co.loss += scr.lane.pinball(shape, tau, grp.mask, denom);
                    scr.lane.backward(shape, grp, &rnn, &mut co.rnn_grads);
                    // De-marshal lane l → batch slot start + l (padding
                    // and masked lanes hold exact zeros).
                    let sg = &scr.lane.sg;
                    for l in 0..grp.fill {
                        let i = grp.start + l - slot_lo;
                        co.d_alpha[i] = sg.alpha_logit.0[l];
                        co.d_gamma[i] = sg.gamma_logit.0[l];
                        co.d_gamma2[i] = sg.gamma2_logit.0[l];
                        for k in 0..w {
                            co.d_log_s[i * w + k] =
                                sg.log_s_init[k * LANES + l];
                        }
                    }
                }
            };
            self.pool.run(n_chunks, &task);
            // Merge in ascending chunk order — fixed f32 association for
            // a given thread count regardless of completion order.
            let st = &mut *stp;
            for (ci, &(lo, hi)) in st.ranges.iter().enumerate() {
                let co = st.chunk_outs[ci].get_mut().unwrap();
                loss += co.loss;
                st.rnn_grads.merge(&co.rnn_grads);
                let (slot_lo, slot_hi) = (lo * LANES, (hi * LANES).min(b));
                let n = slot_hi - slot_lo;
                st.d_alpha[slot_lo..slot_hi]
                    .copy_from_slice(&co.d_alpha[..n]);
                st.d_gamma[slot_lo..slot_hi]
                    .copy_from_slice(&co.d_gamma[..n]);
                st.d_gamma2[slot_lo..slot_hi]
                    .copy_from_slice(&co.d_gamma2[..n]);
                st.d_log_s[slot_lo * w..slot_hi * w]
                    .copy_from_slice(&co.d_log_s[..n * w]);
            }
            // lint:hot-path-end — the scalar oracle branch below keeps its
            // allocating reference signatures by design.
        } else {
            // Scalar oracle path: chunk directly over batch slots. The
            // per-series kernels (`pinball_seeds`, `backward_series`)
            // intentionally keep their original allocating signatures —
            // this is the reference path the lane kernels are
            // property-tested against, not the steady-state hot path.
            let c = shape.c;
            {
                let st = &mut *stp;
                st.groups.clear();
                chunks_into(b, self.threads, &mut st.ranges);
                while st.chunk_outs.len() < st.ranges.len() {
                    st.chunk_outs.push(Mutex::new(ChunkOut::default()));
                }
                for (ci, &(lo, hi)) in st.ranges.iter().enumerate() {
                    let mut co = st.chunk_outs[ci].lock().unwrap();
                    co.loss = 0.0;
                    co.rnn_grads.reset(shape);
                    let n = hi - lo;
                    model::set_zeroed(&mut co.d_alpha, n);
                    model::set_zeroed(&mut co.d_gamma, n);
                    model::set_zeroed(&mut co.d_gamma2, n);
                    model::set_zeroed(&mut co.d_log_s, n * w);
                }
            }
            let n_chunks = stp.ranges.len();
            let stv: &StepScratch = &*stp;
            let task = |ci: usize, pid: usize| {
                let (lo, hi) = stv.ranges[ci];
                let mut scr = self.worker_scratch[pid].lock().unwrap();
                let mut co = stv.chunk_outs[ci].lock().unwrap();
                let co = &mut *co;
                for i in lo..hi {
                    if ti.mask[i] == 0.0 {
                        // Padded slot: zero loss and gradient by
                        // construction, so skip its forward entirely.
                        continue;
                    }
                    let yi = &ti.y[i * c..(i + 1) * c];
                    scr.scalar.forward(shape, yi,
                                       &ti.cat[i * 6..(i + 1) * 6], &rnn,
                                       ti.hw(i, w), true);
                    let (loss_num, dout, dz) = model::pinball_seeds(
                        shape, &scr.scalar.fwd, tau, ti.mask[i], denom);
                    co.loss += loss_num;
                    let sg = model::backward_series(
                        shape, yi, &rnn, &scr.scalar.fwd, &dout, &dz,
                        &mut co.rnn_grads);
                    let o = i - lo;
                    co.d_alpha[o] = sg.alpha_logit;
                    co.d_gamma[o] = sg.gamma_logit;
                    co.d_gamma2[o] = sg.gamma2_logit;
                    co.d_log_s[o * w..(o + 1) * w]
                        .copy_from_slice(&sg.log_s_init);
                }
            };
            self.pool.run(n_chunks, &task);
            let st = &mut *stp;
            for (ci, &(lo, hi)) in st.ranges.iter().enumerate() {
                let co = st.chunk_outs[ci].get_mut().unwrap();
                loss += co.loss;
                st.rnn_grads.merge(&co.rnn_grads);
                let n = hi - lo;
                st.d_alpha[lo..hi].copy_from_slice(&co.d_alpha[..n]);
                st.d_gamma[lo..hi].copy_from_slice(&co.d_gamma[..n]);
                st.d_gamma2[lo..hi].copy_from_slice(&co.d_gamma2[..n]);
                st.d_log_s[lo * w..hi * w]
                    .copy_from_slice(&co.d_log_s[..n * w]);
            }
        }
        let loss = (loss / denom as f64) as f32;
        Ok((loss, stp))
    }

    fn run_train_step(&self, name: &str, spec: &ProgramSpec, shape: &Shape,
                      inputs: &HashMap<&str, &HostTensor>)
                      -> Result<Vec<(String, HostTensor)>> {
        let cache = self.program_cache(name, spec)?;
        let b = spec.batch;
        let ti = self.resolve_inputs(spec, inputs, b, shape, true)?;
        let (lr, step_old) = (ti.lr, ti.step_old);
        let (loss, st) = self.train_grads(shape, &ti, b, self.manifest.tau)?;

        // ---- Adam (model.py::_adam_update) on fresh output copies ----
        let step_new = step_old + 1.0;
        let bc1 = 1.0 - model::ADAM_B1.powf(step_new);
        let bc2 = 1.0 - model::ADAM_B2.powf(step_new);
        let mut ps = Vec::with_capacity(cache.adam.len());
        let mut ms = Vec::with_capacity(cache.adam.len());
        let mut vs = Vec::with_capacity(cache.adam.len());
        for leaf in &cache.adam {
            let g = grad_slice(&leaf.key, &st);
            let mut p = get_data(inputs, &leaf.pname)?.to_vec();
            let mut m = get_data(inputs, &leaf.mname)?.to_vec();
            let mut v = get_data(inputs, &leaf.vname)?.to_vec();
            // Same operation sequence per element either way (the lane
            // update is bit-identical to the scalar one).
            match self.mode {
                ComputeMode::Lanes => lanes::adam_update_lanes(
                    &mut p, g, &mut m, &mut v, lr, leaf.mult, bc1, bc2),
                ComputeMode::Scalar => model::adam_update(
                    &mut p, g, &mut m, &mut v, lr, leaf.mult, bc1, bc2),
            }
            ps.push(Some(p));
            ms.push(Some(m));
            vs.push(Some(v));
        }
        drop(st);

        // ---- emit in spec output order via the cached plan ----
        let taken = |slot: &mut Option<Vec<f32>>, name: &str|
                     -> Result<Vec<f32>> {
            slot.take()
                .ok_or_else(|| anyhow!("output `{name}` routed twice"))
        };
        let mut out = Vec::with_capacity(spec.outputs.len());
        for (slot, ospec) in cache.out_plan.iter().zip(&spec.outputs) {
            let tensor = match slot {
                OutSlot::Loss => HostTensor::scalar(loss),
                OutSlot::Step => HostTensor::scalar(step_new),
                OutSlot::Param(i) => HostTensor::new(
                    cache.adam[*i].shape.clone(),
                    taken(&mut ps[*i], &ospec.name)?)?,
                OutSlot::M(i) => HostTensor::new(
                    cache.adam[*i].shape.clone(),
                    taken(&mut ms[*i], &ospec.name)?)?,
                OutSlot::V(i) => HostTensor::new(
                    cache.adam[*i].shape.clone(),
                    taken(&mut vs[*i], &ospec.name)?)?,
            };
            out.push((ospec.name.clone(), tensor));
        }
        Ok(out)
    }

    /// Steady-state training entry point: one train step of program
    /// `name`, reading the batch from `data` and updating parameters,
    /// Adam moments and `opt.step` **in place** inside the caller-owned
    /// `state` map. Numerically identical to executing the same program
    /// through [`Backend::execute_named`] and writing the outputs back —
    /// but after [`STEADY_WARMUP`] executions have grown the arenas,
    /// each call performs zero heap allocations and zero thread spawns
    /// (gated by `rust/tests/steady_state.rs` and BENCH_6). Returns the
    /// step's pinball loss.
    pub fn train_step_inplace(&self, name: &str,
                              data: &HashMap<String, HostTensor>,
                              state: &mut HashMap<String, HostTensor>)
                              -> Result<f32> {
        let spec = self.manifest.program(name)?;
        if spec.kind != "train_step" {
            bail!("`{name}` is a {} program, not train_step", spec.kind);
        }
        let a0 = crate::util::allocmeter::allocations();
        let t0 = Instant::now();
        let shape = self.shape_for(&spec.freq)?;
        let cache = self.program_cache(name, spec)?;
        let b = spec.batch;

        let mut ti = TrainInputs::empty();
        for ispec in &spec.inputs {
            let t = data
                .get(&ispec.name)
                .or_else(|| state.get(&ispec.name))
                .ok_or_else(|| anyhow!("missing input `{}`", ispec.name))?;
            if t.shape != ispec.shape {
                bail!("tensor `{}`: host shape {:?} != manifest shape {:?}",
                      ispec.name, t.shape, ispec.shape);
            }
            ti.assign(&ispec.name, t)?;
        }
        ti.validate(shape, b, true)?;
        let (lr, step_old) = (ti.lr, ti.step_old);
        let (loss, st) = self.train_grads(shape, &ti, b, self.manifest.tau)?;
        // The input view borrows `state`; release it before mutating.
        drop(ti);

        // lint:hot-path-begin — steady-state optimizer update; must stay
        // allocation-free (CountingAlloc gates it at runtime, rule R3
        // statically).
        // ---- Adam in place: each leaf's tensors leave the map, update
        // against the pooled gradients, and return — the key Strings and
        // map capacity are moved back, so no allocation happens. ----
        let step_new = step_old + 1.0;
        let bc1 = 1.0 - model::ADAM_B1.powf(step_new);
        let bc2 = 1.0 - model::ADAM_B2.powf(step_new);
        for leaf in &cache.adam {
            let g = grad_slice(&leaf.key, &st);
            let (pk, mut pt) = state
                .remove_entry(leaf.pname.as_str())
                .ok_or_else(|| anyhow!("state missing `{}`", leaf.pname))?;
            let (mk, mut mt) = state
                .remove_entry(leaf.mname.as_str())
                .ok_or_else(|| anyhow!("state missing `{}`", leaf.mname))?;
            let (vk, mut vt) = state
                .remove_entry(leaf.vname.as_str())
                .ok_or_else(|| anyhow!("state missing `{}`", leaf.vname))?;
            match self.mode {
                ComputeMode::Lanes => lanes::adam_update_lanes(
                    &mut pt.data, g, &mut mt.data, &mut vt.data, lr,
                    leaf.mult, bc1, bc2),
                ComputeMode::Scalar => model::adam_update(
                    &mut pt.data, g, &mut mt.data, &mut vt.data, lr,
                    leaf.mult, bc1, bc2),
            }
            state.insert(pk, pt);
            state.insert(mk, mt);
            state.insert(vk, vt);
        }
        drop(st);
        state
            .get_mut("opt.step")
            .ok_or_else(|| anyhow!("state missing `opt.step`"))?
            .data[0] = step_new;
        // lint:hot-path-end

        let elapsed = t0.elapsed().as_secs_f64();
        let allocs = crate::util::allocmeter::allocations()
            .saturating_sub(a0);
        let mut bs = self.stats.lock().unwrap();
        // Warmup check precedes the increment: execution 0..STEADY_WARMUP
        // may grow arenas without charging the steady-state counter.
        let warm = bs.executions >= STEADY_WARMUP;
        bs.executions += 1;
        bs.execute_secs += elapsed;
        if warm {
            bs.steady_allocs += allocs;
        }
        Ok(loss)
    }
}

/// The bare ES layer (debug/verification program). Dual configs read
/// `data.gamma2_logit` and a packed `[S1 | S2]` seasonality block and emit
/// both seasonal tracks (`seas`, `seas2`).
fn run_es(spec: &ProgramSpec, shape: &Shape,
          inputs: &HashMap<&str, &HostTensor>)
          -> Result<Vec<(String, HostTensor)>> {
    let b = spec.batch;
    let (c, s, s2) = (shape.c, shape.s, shape.s2);
    let width = shape.s_total();
    let y = get_data(inputs, "data.y")?;
    let alpha_logit = get_data(inputs, "data.alpha_logit")?;
    let gamma_logit = get_data(inputs, "data.gamma_logit")?;
    let gamma2_logit: &[f32] = if shape.dual() {
        get_data(inputs, "data.gamma2_logit")?
    } else {
        &[]
    };
    let log_s = get_data(inputs, "data.log_s_init")?;
    let mut levels = Vec::with_capacity(b * c);
    let mut seas = Vec::with_capacity(b * (c + s));
    let mut seas2 = Vec::with_capacity(if shape.dual() { b * (c + s2) } else { 0 });
    for i in 0..b {
        let yi = &y[i * c..(i + 1) * c];
        let alpha = crate::hw::sigmoid(alpha_logit[i]);
        let row = &log_s[i * width..(i + 1) * width];
        if shape.dual() {
            let gamma = crate::hw::sigmoid(gamma_logit[i]);
            let gamma2 = crate::hw::sigmoid(gamma2_logit[i]);
            let s1_init: Vec<f32> = row[..s].iter().map(|v| v.exp()).collect();
            let s2_init: Vec<f32> = row[s..].iter().map(|v| v.exp()).collect();
            let (lv, e1, e2) = crate::hw::es_dual_filter(
                yi, alpha, gamma, gamma2, &s1_init, &s2_init);
            levels.extend(lv);
            seas.extend(e1);
            seas2.extend(e2);
        } else {
            let (gamma, s_init): (f32, Vec<f32>) = if shape.seasonal {
                (crate::hw::sigmoid(gamma_logit[i]),
                 row.iter().map(|v| v.exp()).collect())
            } else {
                (0.0, vec![1.0; s])
            };
            let es = crate::hw::es_filter(yi, alpha, gamma, &s_init);
            levels.extend(es.levels);
            seas.extend(es.seas);
        }
    }
    let mut out = vec![
        ("levels".to_string(), HostTensor::new(vec![b, c], levels)?),
        ("seas".to_string(), HostTensor::new(vec![b, c + s], seas)?),
    ];
    if shape.dual() {
        out.push(("seas2".to_string(),
                  HostTensor::new(vec![b, c + s2], seas2)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_covers_native_freqs_and_kinds() {
        let backend = NativeBackend::with_threads(2);
        let m = backend.manifest();
        assert_eq!(m.variant, "native");
        for freq in ["yearly", "quarterly", "monthly", "daily", "hourly"] {
            assert!(m.config(freq).is_ok(), "missing config {freq}");
            assert_eq!(m.available_batches(freq, "train_step"),
                       NATIVE_BATCH_SIZES.to_vec());
            assert_eq!(m.available_batches(freq, "predict"),
                       NATIVE_BATCH_SIZES.to_vec());
            assert!(m.program(&format!("{freq}_init")).is_ok());
            assert!(m.program(&format!("{freq}_b8_es")).is_ok());
        }
        // §8.2 dual seasonality is native now; only the §8.4 penalty
        // variants (and unmodeled weekly) stay out of the native manifest.
        assert_eq!(m.config("hourly").unwrap().seasonality2, 168);
        assert!(m.config("quarterly_pen").is_err());
        assert!(m.config("weekly").is_err());
    }

    #[test]
    fn hourly_specs_carry_dual_leaves() {
        let net = NetworkConfig::for_freq(Frequency::Hourly).unwrap();
        let spec = train_step_spec("hourly", &net, 4);
        let names: Vec<&str> =
            spec.inputs.iter().map(|t| t.name.as_str()).collect();
        // jax flat (alphabetical) series order: alpha, gamma2, gamma, log_s.
        let a = names.iter().position(|n| *n == "params.series.alpha_logit")
            .unwrap();
        assert_eq!(names[a + 1], "params.series.gamma2_logit");
        assert_eq!(names[a + 2], "params.series.gamma_logit");
        assert_eq!(names[a + 3], "params.series.log_s_init");
        let log_s = spec.inputs.iter()
            .find(|t| t.name == "params.series.log_s_init").unwrap();
        assert_eq!(log_s.shape, vec![4, 192]);
        // 8 cell leaves + 4 head + 4 series = 16; 1 loss + 3×16 + step.
        assert_eq!(spec.outputs.len(), 1 + 3 * 16 + 1);

        let es = es_spec("hourly", &net, 8);
        let in_names: Vec<&str> =
            es.inputs.iter().map(|t| t.name.as_str()).collect();
        assert!(in_names.contains(&"data.gamma2_logit"));
        let out_names: Vec<&str> =
            es.outputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(out_names, vec!["levels", "seas", "seas2"]);
        assert_eq!(es.outputs[1].shape, vec![8, 336 + 24]);
        assert_eq!(es.outputs[2].shape, vec![8, 336 + 168]);
    }

    #[test]
    fn train_step_spec_leaf_order_is_manifest_flat_order() {
        let net = NetworkConfig::for_freq(Frequency::Quarterly).unwrap();
        let spec = train_step_spec("quarterly", &net, 16);
        let names: Vec<&str> =
            spec.inputs.iter().map(|t| t.name.as_str()).collect();
        // jax flat order: data.{cat,mask,y}, params.*, opt.m.*, opt.step,
        // opt.v.*, lr — with alphabetical leaves inside each subtree.
        assert_eq!(names[0], "data.cat");
        assert_eq!(names[1], "data.mask");
        assert_eq!(names[2], "data.y");
        assert_eq!(names[3], "params.rnn.cells.0.b");
        assert_eq!(names[4], "params.rnn.cells.0.w");
        let params_end = 3 + 8 + 4 + 3; // 4 cells × 2 + 4 head + 3 series
        assert_eq!(names[params_end - 1], "params.series.log_s_init");
        assert_eq!(names.last().unwrap(), &"lr");
        assert!(names.contains(&"opt.step"));
        assert_eq!(spec.outputs[0].name, "loss");
        assert_eq!(spec.outputs.len(), 1 + 3 * 15 + 1);
    }

    #[test]
    fn init_is_deterministic_and_glorot_bounded() {
        let backend = NativeBackend::with_threads(1);
        let a = backend.execute_init("yearly", 42).unwrap();
        let b = backend.execute_init("yearly", 42).unwrap();
        let c = backend.execute_init("yearly", 43).unwrap();
        assert_eq!(a.len(), b.len());
        for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(ta.data, tb.data);
        }
        // different seed ⇒ different weights
        assert!(a.iter().zip(&c).any(|((_, ta), (_, tc))| ta.data != tc.data));
        // biases zero, weights inside the glorot bound
        for (name, t) in &a {
            if name.ends_with('b') {
                assert!(t.data.iter().all(|v| *v == 0.0), "{name} not zero");
            } else {
                let (rows, cols) = (t.shape[0], t.shape[1]);
                let lim = (6.0 / (rows + cols) as f64).sqrt() as f32;
                assert!(t.data.iter().all(|v| v.abs() <= lim),
                        "{name} exceeds glorot bound");
                assert!(t.data.iter().any(|v| *v != 0.0), "{name} all zero");
            }
        }
        // distinct frequencies draw distinct streams under one seed
        let q = backend.execute_init("quarterly", 42).unwrap();
        assert_ne!(a[1].1.data[..8], q[1].1.data[..8]);
    }

    #[test]
    fn chunks_partition_exactly() {
        // Quotient/remainder split: the remainder spreads one element
        // each over the leading chunks.
        assert_eq!(chunks(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(chunks(2, 8), vec![(0, 1), (1, 2)]);
        assert_eq!(chunks(1, 1), vec![(0, 1)]);
        // The case the old div_ceil split got wrong: 9 items on 8
        // threads must fill all 8 chunks, not 5.
        assert_eq!(chunks(9, 8).len(), 8);
        let parts = chunks(257, 16);
        assert_eq!(parts.len(), 16);
        assert_eq!(parts.iter().map(|(lo, hi)| hi - lo).sum::<usize>(), 257);
        assert_eq!(parts.first().unwrap().0, 0);
        assert_eq!(parts.last().unwrap().1, 257);
    }

    #[test]
    fn chunks_grid_is_balanced_ordered_and_exact() {
        for n in 0..=40usize {
            for t in 1..=10usize {
                let parts = chunks(n, t);
                if n == 0 {
                    assert!(parts.is_empty(), "chunks(0, {t}) not empty");
                    continue;
                }
                // Exactly min(n, t) chunks — the thread budget is never
                // under-filled.
                assert_eq!(parts.len(), n.min(t), "chunks({n}, {t}) count");
                // Contiguous ordered partition of 0..n.
                let mut expect_lo = 0;
                for &(lo, hi) in &parts {
                    assert_eq!(lo, expect_lo, "chunks({n}, {t}) gap at {lo}");
                    assert!(hi > lo, "chunks({n}, {t}) empty chunk");
                    expect_lo = hi;
                }
                assert_eq!(expect_lo, n, "chunks({n}, {t}) doesn't end at n");
                // Near-equal: sizes differ by at most one.
                let sizes: Vec<usize> =
                    parts.iter().map(|(lo, hi)| hi - lo).collect();
                let (min, max) = (sizes.iter().min().unwrap(),
                                  sizes.iter().max().unwrap());
                assert!(max - min <= 1,
                        "chunks({n}, {t}) imbalance: {sizes:?}");
            }
        }
    }
}
