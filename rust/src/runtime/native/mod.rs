//! `NativeBackend` — a pure-Rust execution backend for the ES-RNN
//! programs: no XLA, no AOT artifacts, no Python anywhere.
//!
//! The backend synthesizes its own [`Manifest`] from the Table-1 network
//! configs (so callers observe exactly the contract the PJRT artifact
//! manifest describes: same program names, same tensor leaf names, same
//! shapes) and serves three program kinds:
//!
//! * `init`       — Glorot-uniform RNN weight init seeded from
//!   [`crate::util::rng`] (distributionally equivalent to the JAX init;
//!   bit-exactness with the Threefry artifact is explicitly *not* part of
//!   the backend contract);
//! * `predict`    — the batched forward pass + §3.4 de-normalization;
//! * `train_step` — forward, hand-written backward (validated by finite
//!   differences) and the Adam update with the §3.3 per-series
//!   learning-rate multiplier;
//! * `es`         — the bare Holt-Winters layer (debug/verification
//!   program, mirroring `aot.py::lower_es`).
//!
//! The batch dimension is data-parallel at two levels. The default
//! [`ComputeMode::Lanes`] marshals the batch into structure-of-arrays
//! lane groups of [`crate::simd::LANES`] series and runs the
//! lane-vectorized kernels in [`lanes`] (the paper's §5 vectorization,
//! natively); `std::thread` scoped workers then split the *groups*
//! (thread × lane two-level parallelism). [`ComputeMode::Scalar`] keeps
//! the original one-series-at-a-time core in [`model`] — the oracle the
//! lane kernels are property-tested against — and splits the batch
//! across threads per series. Per-series gradients are independent;
//! shared-weight gradients are reduced across chunks in batch order, so
//! results are deterministic for a given thread count and vary only at
//! float-association level across thread counts (chunk boundaries move,
//! so the f32 summation parenthesization differs).
//!
//! Scope: every Table-1 frequency — yearly/quarterly/monthly/daily
//! (single seasonality) and the §8.2 hourly dual-seasonality (24h×168h)
//! model, whose coupled ES recurrence runs natively through
//! [`crate::hw::es_dual_filter`] with a `gamma2_logit` leaf and a packed
//! `[S1 | S2]` seasonality block. Only the §8.4 penalty variants remain
//! PJRT-artifact-only; their configs are simply absent from the native
//! manifest, which every caller already handles by name lookup.

pub mod lanes;
pub mod model;

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Frequency, NetworkConfig};
use crate::simd::LANES;
use crate::util::rng::Rng;

use super::backend::{Backend, BackendStats, HostTensor};
use super::manifest::{FreqManifest, Manifest, ProgramSpec, TensorSpec};

use model::{RnnGrads, RnnView, SeriesGrads, Shape};

/// Batch sizes the native manifest advertises. Native programs have no
/// compile cost, so the ladder is denser than the artifact sweep — the
/// greedy cover and the forecast service get near-zero padding.
pub const NATIVE_BATCH_SIZES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Batch size of the `es` debug program (mirror of `aot.py`).
const ES_DEBUG_BATCH: usize = 8;

/// Frequencies with native support (all Table-1 shapes, incl. §8.2 hourly
/// dual seasonality; no §8.4 penalty variants).
const NATIVE_FREQS: [Frequency; 5] = [
    Frequency::Yearly,
    Frequency::Quarterly,
    Frequency::Monthly,
    Frequency::Daily,
    Frequency::Hourly,
];

/// Pinball quantile (paper §3.5) and per-series LR multiplier (§3.3) —
/// mirrors `python/compile/configs.py`.
pub const PINBALL_TAU: f32 = 0.48;
pub const PER_SERIES_LR_MULT: f32 = 1.5;

fn f32_spec(name: impl Into<String>, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.into(), shape, dtype: "float32".into() }
}

/// Parameter leaves in manifest (jax flat, i.e. alphabetical) order,
/// named WITHOUT the `params.` prefix.
fn param_leaves(net: &NetworkConfig, b: usize) -> Vec<(String, Vec<usize>)> {
    let hid = net.hidden;
    let h = net.horizon;
    let mut din = net.input_window + 6;
    let mut leaves = Vec::new();
    for i in 0..net.dilations.iter().flatten().count() {
        leaves.push((format!("rnn.cells.{i}.b"), vec![4 * hid]));
        leaves.push((format!("rnn.cells.{i}.w"), vec![din + hid, 4 * hid]));
        din = hid;
    }
    leaves.push(("rnn.dense_b".into(), vec![hid]));
    leaves.push(("rnn.dense_w".into(), vec![hid, hid]));
    leaves.push(("rnn.out_b".into(), vec![h]));
    leaves.push(("rnn.out_w".into(), vec![hid, h]));
    leaves.push(("series.alpha_logit".into(), vec![b]));
    if net.dual() {
        // jax flat (alphabetical) order: `gamma2_logit` < `gamma_logit`
        // because '2' sorts before '_'.
        leaves.push(("series.gamma2_logit".into(), vec![b]));
    }
    leaves.push(("series.gamma_logit".into(), vec![b]));
    leaves.push(("series.log_s_init".into(), vec![b, net.total_seasonality()]));
    leaves
}

fn train_step_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let leaves = param_leaves(net, b);
    let mut inputs = vec![
        f32_spec("data.cat", vec![b, 6]),
        f32_spec("data.mask", vec![b]),
        f32_spec("data.y", vec![b, net.length]),
    ];
    let mut outputs = vec![f32_spec("loss", vec![])];
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("params.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("params.{name}"), shape.clone()));
    }
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("opt.m.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("opt.m.{name}"), shape.clone()));
    }
    inputs.push(f32_spec("opt.step", vec![]));
    outputs.push(f32_spec("opt.step", vec![]));
    for (name, shape) in &leaves {
        inputs.push(f32_spec(format!("opt.v.{name}"), shape.clone()));
        outputs.push(f32_spec(format!("opt.v.{name}"), shape.clone()));
    }
    inputs.push(f32_spec("lr", vec![]));
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_train_step>"),
        freq: freq.to_string(),
        batch: b,
        kind: "train_step".into(),
        inputs,
        outputs,
    }
}

fn predict_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let mut inputs = vec![
        f32_spec("data.cat", vec![b, 6]),
        f32_spec("data.y", vec![b, net.length]),
    ];
    for (name, shape) in param_leaves(net, b) {
        inputs.push(f32_spec(format!("params.{name}"), shape));
    }
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_predict>"),
        freq: freq.to_string(),
        batch: b,
        kind: "predict".into(),
        inputs,
        outputs: vec![f32_spec("forecast", vec![b, net.horizon])],
    }
}

fn es_spec(freq: &str, net: &NetworkConfig, b: usize) -> ProgramSpec {
    let (c, s1, s2) = (net.length, net.seasonality, net.seasonality2);
    let mut inputs = vec![f32_spec("data.alpha_logit", vec![b])];
    if net.dual() {
        inputs.push(f32_spec("data.gamma2_logit", vec![b]));
    }
    inputs.push(f32_spec("data.gamma_logit", vec![b]));
    inputs.push(f32_spec("data.log_s_init", vec![b, s1 + s2]));
    inputs.push(f32_spec("data.y", vec![b, c]));
    let mut outputs = vec![
        f32_spec("levels", vec![b, c]),
        f32_spec("seas", vec![b, c + s1]),
    ];
    if net.dual() {
        // §8.2: the debug program emits both seasonal tracks.
        outputs.push(f32_spec("seas2", vec![b, c + s2]));
    }
    ProgramSpec {
        file: format!("<native:{freq}_b{b}_es>"),
        freq: freq.to_string(),
        batch: b,
        kind: "es".into(),
        inputs,
        outputs,
    }
}

fn init_spec(freq: &str, net: &NetworkConfig) -> ProgramSpec {
    let outputs = param_leaves(net, 1)
        .into_iter()
        .filter(|(name, _)| name.starts_with("rnn."))
        .map(|(name, shape)| f32_spec(name, shape))
        .collect();
    ProgramSpec {
        file: format!("<native:{freq}_init>"),
        freq: freq.to_string(),
        batch: 0,
        kind: "init".into(),
        inputs: vec![TensorSpec {
            name: "key".into(),
            shape: vec![2],
            dtype: "uint32".into(),
        }],
        outputs,
    }
}

fn native_manifest() -> Manifest {
    let mut configs = HashMap::new();
    let mut programs = HashMap::new();
    for freq in NATIVE_FREQS {
        let net = NetworkConfig::for_freq(freq)
            .expect("native frequencies always have a network config");
        let name = freq.name();
        configs.insert(name.to_string(), FreqManifest {
            seasonality: net.seasonality,
            seasonality2: net.seasonality2,
            horizon: net.horizon,
            input_window: net.input_window,
            length: net.length,
            hidden: net.hidden,
            dilations: net.dilations.clone(),
            positions: net.positions()
                .expect("Table-1 configs always have positions"),
            valid_positions: net.valid_positions()
                .expect("Table-1 configs always have valid positions"),
        });
        programs.insert(Manifest::program_name(name, 0, "init"),
                        init_spec(name, &net));
        programs.insert(Manifest::program_name(name, ES_DEBUG_BATCH, "es"),
                        es_spec(name, &net, ES_DEBUG_BATCH));
        for &b in NATIVE_BATCH_SIZES {
            programs.insert(Manifest::program_name(name, b, "train_step"),
                            train_step_spec(name, &net, b));
            programs.insert(Manifest::program_name(name, b, "predict"),
                            predict_spec(name, &net, b));
        }
    }
    Manifest {
        version: 1,
        variant: "native".into(),
        tau: PINBALL_TAU,
        per_series_lr_mult: PER_SERIES_LR_MULT,
        batch_sizes: NATIVE_BATCH_SIZES.to_vec(),
        configs,
        programs,
    }
}

/// Which native kernel implementation executes batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// One series at a time through [`model`] — the reference/oracle
    /// path the lane kernels are property-tested against.
    Scalar,
    /// Lane-vectorized SoA batch kernels ([`lanes`], default): every hot
    /// path advances [`LANES`] series per step.
    Lanes,
}

/// The pure-Rust execution backend.
pub struct NativeBackend {
    manifest: Manifest,
    threads: usize,
    mode: ComputeMode,
    stats: Mutex<BackendStats>,
}

impl NativeBackend {
    /// Backend using every available core for batch parallelism.
    pub fn new() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Backend with an explicit worker-thread cap (1 = fully sequential).
    /// The kernel mode defaults to [`ComputeMode::Lanes`];
    /// `FAST_ESRNN_NATIVE_MODE=scalar` selects the scalar oracle path
    /// (benches construct both explicitly via [`Self::with_threads_mode`]).
    pub fn with_threads(threads: usize) -> Self {
        let mode = match std::env::var("FAST_ESRNN_NATIVE_MODE").as_deref() {
            Ok("scalar") => ComputeMode::Scalar,
            Ok("lanes") | Err(_) => ComputeMode::Lanes,
            Ok(other) => panic!(
                "FAST_ESRNN_NATIVE_MODE=`{other}` is not a native kernel \
                 mode (expected `scalar` or `lanes`)"),
        };
        Self::with_threads_mode(threads, mode)
    }

    /// Backend with an explicit thread cap and kernel mode.
    pub fn with_threads_mode(threads: usize, mode: ComputeMode) -> Self {
        Self {
            manifest: native_manifest(),
            threads: threads.max(1),
            mode,
            stats: Mutex::new(BackendStats::default()),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn mode(&self) -> ComputeMode {
        self.mode
    }

    fn shape_for(&self, freq: &str) -> Result<Shape> {
        let cfg = self.manifest.config(freq)?;
        Shape::new(cfg.seasonality, cfg.seasonality2, cfg.horizon,
                   cfg.input_window, cfg.length, cfg.hidden, &cfg.dilations, 6)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Fetch an input tensor by name, preserving the underlying lifetime.
fn get_in<'x>(inputs: &HashMap<&str, &'x HostTensor>, name: &str)
              -> Result<&'x HostTensor> {
    inputs
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("missing input `{name}`"))
}

fn get_data<'x>(inputs: &HashMap<&str, &'x HostTensor>, name: &str)
                -> Result<&'x [f32]> {
    Ok(get_in(inputs, name)?.data.as_slice())
}

/// Resolve the per-series parameter slices for one batch slot.
/// `gamma2_logit` is present only for §8.2 dual configs (empty otherwise).
struct SeriesView<'a> {
    alpha_logit: &'a [f32],
    gamma_logit: &'a [f32],
    gamma2_logit: &'a [f32],
    log_s_init: &'a [f32],
    s_width: usize,
}

impl<'a> SeriesView<'a> {
    fn from_inputs(inputs: &HashMap<&str, &'a HostTensor>, shape: &Shape)
                   -> Result<Self> {
        let gamma2_logit: &'a [f32] = if shape.dual() {
            get_data(inputs, "params.series.gamma2_logit")?
        } else {
            &[]
        };
        Ok(Self {
            alpha_logit: get_data(inputs, "params.series.alpha_logit")?,
            gamma_logit: get_data(inputs, "params.series.gamma_logit")?,
            gamma2_logit,
            log_s_init: get_data(inputs, "params.series.log_s_init")?,
            s_width: shape.s_total(),
        })
    }

    /// Bundle slot `i`'s parameters for the compute core.
    fn hw(&self, i: usize) -> model::HwView<'a> {
        model::HwView {
            alpha_logit: self.alpha_logit[i],
            gamma_logit: self.gamma_logit[i],
            gamma2_logit: if self.gamma2_logit.is_empty() {
                0.0
            } else {
                self.gamma2_logit[i]
            },
            log_s_init: &self.log_s_init[i * self.s_width
                                         ..(i + 1) * self.s_width],
        }
    }
}

/// Owned collection of RNN weight slices; [`RnnParts::view`] borrows it
/// into the [`RnnView`] the compute core consumes.
struct RnnParts<'a> {
    cells: Vec<(&'a [f32], &'a [f32])>,
    dense_w: &'a [f32],
    dense_b: &'a [f32],
    out_w: &'a [f32],
    out_b: &'a [f32],
}

impl<'a> RnnParts<'a> {
    fn from_inputs(inputs: &HashMap<&str, &'a HostTensor>, n_layers: usize)
                   -> Result<Self> {
        let mut cells = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            cells.push((
                get_data(inputs, &format!("params.rnn.cells.{i}.w"))?,
                get_data(inputs, &format!("params.rnn.cells.{i}.b"))?,
            ));
        }
        Ok(Self {
            cells,
            dense_w: get_data(inputs, "params.rnn.dense_w")?,
            dense_b: get_data(inputs, "params.rnn.dense_b")?,
            out_w: get_data(inputs, "params.rnn.out_w")?,
            out_b: get_data(inputs, "params.rnn.out_b")?,
        })
    }

    fn view(&self) -> RnnView<'_> {
        RnnView {
            cells: &self.cells,
            dense_w: self.dense_w,
            dense_b: self.dense_b,
            out_w: self.out_w,
            out_b: self.out_b,
        }
    }
}

/// Split `0..n` into at most `threads` contiguous chunks.
fn chunks(n: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.min(n).max(1);
    let per = n.div_ceil(t);
    (0..t)
        .map(|i| (i * per, ((i + 1) * per).min(n)))
        .filter(|(lo, hi)| lo < hi)
        .collect()
}

impl Backend for NativeBackend {
    fn execute_named<'a>(
        &self,
        name: &str,
        lookup: &mut dyn FnMut(&TensorSpec) -> Result<&'a HostTensor>,
    ) -> Result<Vec<(String, HostTensor)>> {
        let spec = self.manifest.program(name)?.clone();
        let t0 = Instant::now();
        let mut inputs: HashMap<&str, &'a HostTensor> =
            HashMap::with_capacity(spec.inputs.len());
        for ispec in &spec.inputs {
            if ispec.dtype != "float32" {
                bail!("input `{}` has dtype {}, execute_named only handles \
                       float32", ispec.name, ispec.dtype);
            }
            let host = lookup(ispec)
                .with_context(|| format!("packing input `{}`", ispec.name))?;
            if host.shape != ispec.shape {
                bail!("tensor `{}`: host shape {:?} != manifest shape {:?}",
                      ispec.name, host.shape, ispec.shape);
            }
            inputs.insert(ispec.name.as_str(), host);
        }
        let pack = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let shape = self.shape_for(&spec.freq)?;
        let out = match spec.kind.as_str() {
            "train_step" => self.run_train_step(&spec, &shape, &inputs)?,
            "predict" => self.run_predict(&spec, &shape, &inputs)?,
            "es" => run_es(&spec, &shape, &inputs)?,
            other => bail!("native backend cannot execute kind `{other}`"),
        };
        let exec = t1.elapsed().as_secs_f64();

        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.pack_secs += pack;
        st.execute_secs += exec;
        Ok(out)
    }

    fn execute_init(&self, freq: &str, seed: u64) -> Result<Vec<(String, HostTensor)>> {
        let name = Manifest::program_name(freq, 0, "init");
        let spec = self.manifest.program(&name)?.clone();
        // Per-frequency stream: fold the frequency name into the seed so
        // identically-seeded frequencies don't share weights.
        let mut salted = seed ^ 0x9E37_79B9_7F4A_7C15;
        for byte in freq.bytes() {
            salted = salted.wrapping_mul(0x0000_0100_0000_01B3) ^ byte as u64;
        }
        let mut rng = Rng::new(salted);
        let mut out = Vec::with_capacity(spec.outputs.len());
        for ospec in &spec.outputs {
            let n = ospec.elem_count();
            let data = if ospec.name.ends_with(".w")
                || ospec.name.ends_with("_w")
            {
                // Glorot-uniform on (fan_in, fan_out) = (rows, cols).
                let (rows, cols) = (ospec.shape[0], ospec.shape[1]);
                let lim = (6.0 / (rows + cols) as f64).sqrt();
                (0..n).map(|_| rng.uniform(-lim, lim) as f32).collect()
            } else {
                vec![0.0; n] // biases start at zero (init_rnn_params)
            };
            out.push((ospec.name.clone(),
                      HostTensor::new(ospec.shape.clone(), data)?));
        }
        Ok(out)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        let kernels = match self.mode {
            ComputeMode::Scalar => "scalar",
            ComputeMode::Lanes => "lane",
        };
        format!("native-cpu ({} threads, {kernels} kernels)", self.threads)
    }

    fn stats(&self) -> BackendStats {
        self.stats.lock().unwrap().clone()
    }
}

impl NativeBackend {
    fn run_predict(&self, spec: &ProgramSpec, shape: &Shape,
                   inputs: &HashMap<&str, &HostTensor>)
                   -> Result<Vec<(String, HostTensor)>> {
        let b = spec.batch;
        let y = get_data(inputs, "data.y")?;
        let cat = get_data(inputs, "data.cat")?;
        let parts = RnnParts::from_inputs(inputs, shape.n_layers())?;
        let rnn = parts.view();
        let series = SeriesView::from_inputs(inputs, shape)?;
        let (c, h) = (shape.c, shape.h);

        let mut forecast = vec![0.0f32; b * h];
        if self.mode == ComputeMode::Lanes {
            let groups = lanes::marshal_groups(
                shape, b, y, cat, None, series.alpha_logit,
                series.gamma_logit, series.gamma2_logit, series.log_s_init);
            let ranges = chunks(groups.len(), self.threads);
            std::thread::scope(|sc| {
                let groups = &groups;
                let mut handles = Vec::with_capacity(ranges.len());
                for &(lo, hi) in &ranges {
                    let handle = sc.spawn(move || {
                        let mut out = Vec::with_capacity(hi - lo);
                        for grp in &groups[lo..hi] {
                            let fwd = lanes::forward_lanes(shape, grp, &rnn,
                                                           false);
                            out.push((grp.start, grp.fill,
                                      lanes::forecast_from_lanes(shape, &fwd)));
                        }
                        out
                    });
                    handles.push(handle);
                }
                for handle in handles {
                    let worker = handle.join().expect("predict worker panicked");
                    for (start, fill, fc) in worker {
                        // De-marshal: lane l of the SoA forecast is batch
                        // slot start + l; padding lanes are dropped.
                        for l in 0..fill {
                            for k in 0..h {
                                forecast[(start + l) * h + k] =
                                    fc[k * LANES + l];
                            }
                        }
                    }
                }
            });
        } else {
            let ranges = chunks(b, self.threads);
            std::thread::scope(|sc| {
                let mut handles = Vec::with_capacity(ranges.len());
                for &(lo, hi) in &ranges {
                    let series = &series;
                    let handle = sc.spawn(move || {
                        let mut rows = Vec::with_capacity((hi - lo) * h);
                        for i in lo..hi {
                            let fwd = model::forward_series(
                                shape, &y[i * c..(i + 1) * c],
                                &cat[i * 6..(i + 1) * 6], &rnn,
                                series.hw(i), false);
                            rows.extend(model::forecast_from(shape, &fwd));
                        }
                        rows
                    });
                    handles.push((lo, hi, handle));
                }
                for (lo, hi, handle) in handles {
                    let rows = handle.join().expect("predict worker panicked");
                    forecast[lo * h..hi * h].copy_from_slice(&rows);
                }
            });
        }
        Ok(vec![("forecast".into(),
                 HostTensor::new(vec![b, h], forecast)?)])
    }

    fn run_train_step(&self, spec: &ProgramSpec, shape: &Shape,
                      inputs: &HashMap<&str, &HostTensor>)
                      -> Result<Vec<(String, HostTensor)>> {
        let b = spec.batch;
        let c = shape.c;
        let y = get_data(inputs, "data.y")?;
        let cat = get_data(inputs, "data.cat")?;
        let mask = get_data(inputs, "data.mask")?;
        let lr = get_data(inputs, "lr")?[0];
        let step_old = get_data(inputs, "opt.step")?[0];
        let parts = RnnParts::from_inputs(inputs, shape.n_layers())?;
        let rnn = parts.view();
        let series = SeriesView::from_inputs(inputs, shape)?;
        let tau = self.manifest.tau;

        // Global loss denominator (pinball_ref): Σ mask over (P, B) × H.
        let mask_sum: f32 = mask.iter().sum();
        let denom = ((shape.valid_positions as f32) * mask_sum
                     * shape.h as f32).max(1.0);

        // ---- batch-parallel forward + backward ----
        let w = shape.s_total();
        let mut rnn_grads = RnnGrads::zeros(shape);
        let mut loss = 0.0f64;
        let mut d_alpha = vec![0.0f32; b];
        let mut d_gamma = vec![0.0f32; b];
        let mut d_gamma2 = vec![0.0f32; b];
        let mut d_log_s = vec![0.0f32; b * w];
        if self.mode == ComputeMode::Lanes {
            // Lane path: marshal into SoA groups, thread over groups;
            // each worker advances LANES series per kernel step.
            struct GroupChunk {
                loss_num: f64,
                rnn_grads: RnnGrads,
                lane_grads: Vec<(usize, usize, lanes::SeriesGradsLanes)>,
            }
            let groups = lanes::marshal_groups(
                shape, b, y, cat, Some(mask), series.alpha_logit,
                series.gamma_logit, series.gamma2_logit, series.log_s_init);
            let ranges = chunks(groups.len(), self.threads);
            let mut chunks_out: Vec<(usize, GroupChunk)> =
                Vec::with_capacity(ranges.len());
            std::thread::scope(|sc| {
                let groups = &groups;
                let mut handles = Vec::with_capacity(ranges.len());
                for &(lo, hi) in &ranges {
                    let handle = sc.spawn(move || {
                        let mut acc = GroupChunk {
                            loss_num: 0.0,
                            rnn_grads: RnnGrads::zeros(shape),
                            lane_grads: Vec::with_capacity(hi - lo),
                        };
                        for grp in &groups[lo..hi] {
                            if grp.mask.0.iter().all(|v| *v == 0.0) {
                                // Entirely padded group: zero loss and
                                // gradients by construction.
                                acc.lane_grads.push((
                                    grp.start, grp.fill,
                                    lanes::SeriesGradsLanes::zeros(w)));
                                continue;
                            }
                            let fwd = lanes::forward_lanes(shape, grp, &rnn,
                                                           true);
                            let (loss_num, dout, dz) =
                                lanes::pinball_seeds_lanes(
                                    shape, &fwd, tau, grp.mask, denom);
                            acc.loss_num += loss_num;
                            let sg = lanes::backward_lanes(
                                shape, grp, &rnn, &fwd, &dout, &dz,
                                &mut acc.rnn_grads);
                            acc.lane_grads.push((grp.start, grp.fill, sg));
                        }
                        acc
                    });
                    handles.push((lo, handle));
                }
                for (lo, handle) in handles {
                    chunks_out.push(
                        (lo, handle.join().expect("train worker panicked")));
                }
            });
            chunks_out.sort_by_key(|(lo, _)| *lo);
            for (_, chunk) in &chunks_out {
                rnn_grads.merge(&chunk.rnn_grads);
                loss += chunk.loss_num;
                for (start, fill, sg) in &chunk.lane_grads {
                    // De-marshal lane l → batch slot start + l (padding
                    // and masked lanes hold exact zeros).
                    for l in 0..*fill {
                        let i = start + l;
                        d_alpha[i] = sg.alpha_logit.0[l];
                        d_gamma[i] = sg.gamma_logit.0[l];
                        d_gamma2[i] = sg.gamma2_logit.0[l];
                        for k in 0..w {
                            d_log_s[i * w + k] = sg.log_s_init[k * LANES + l];
                        }
                    }
                }
            }
        } else {
            struct Chunk {
                loss_num: f64,
                rnn_grads: RnnGrads,
                series_grads: Vec<SeriesGrads>,
            }
            let ranges = chunks(b, self.threads);
            let mut chunks_out: Vec<(usize, Chunk)> =
                Vec::with_capacity(ranges.len());
            std::thread::scope(|sc| {
                let mut handles = Vec::with_capacity(ranges.len());
                for &(lo, hi) in &ranges {
                    let series = &series;
                    let handle = sc.spawn(move || {
                        let mut acc = Chunk {
                            loss_num: 0.0,
                            rnn_grads: RnnGrads::zeros(shape),
                            series_grads: Vec::with_capacity(hi - lo),
                        };
                        for i in lo..hi {
                            if mask[i] == 0.0 {
                                // Padded slot: zero loss and gradient by
                                // construction (the scatter drops the update
                                // anyway), so skip its forward entirely.
                                acc.series_grads
                                    .push(SeriesGrads::zeros(shape.s_total()));
                                continue;
                            }
                            let yi = &y[i * c..(i + 1) * c];
                            let fwd = model::forward_series(
                                shape, yi, &cat[i * 6..(i + 1) * 6], &rnn,
                                series.hw(i), true);
                            let (loss_num, dout, dz) = model::pinball_seeds(
                                shape, &fwd, tau, mask[i], denom);
                            acc.loss_num += loss_num;
                            acc.series_grads.push(model::backward_series(
                                shape, yi, &rnn, &fwd, &dout, &dz,
                                &mut acc.rnn_grads));
                        }
                        acc
                    });
                    handles.push((lo, handle));
                }
                for (lo, handle) in handles {
                    chunks_out.push(
                        (lo, handle.join().expect("train worker panicked")));
                }
            });
            chunks_out.sort_by_key(|(lo, _)| *lo);
            for (lo, chunk) in &chunks_out {
                rnn_grads.merge(&chunk.rnn_grads);
                loss += chunk.loss_num;
                for (off, sg) in chunk.series_grads.iter().enumerate() {
                    let i = lo + off;
                    d_alpha[i] = sg.alpha_logit;
                    d_gamma[i] = sg.gamma_logit;
                    d_gamma2[i] = sg.gamma2_logit;
                    d_log_s[i * w..(i + 1) * w]
                        .copy_from_slice(&sg.log_s_init);
                }
            }
        }
        let loss = (loss / denom as f64) as f32;

        // ---- gradient table keyed by parameter leaf name ----
        let mut grads: HashMap<String, Vec<f32>> = HashMap::new();
        for (i, (gw, gb)) in rnn_grads.cells.iter().enumerate() {
            grads.insert(format!("rnn.cells.{i}.w"), gw.clone());
            grads.insert(format!("rnn.cells.{i}.b"), gb.clone());
        }
        grads.insert("rnn.dense_w".into(), rnn_grads.dense_w);
        grads.insert("rnn.dense_b".into(), rnn_grads.dense_b);
        grads.insert("rnn.out_w".into(), rnn_grads.out_w);
        grads.insert("rnn.out_b".into(), rnn_grads.out_b);
        grads.insert("series.alpha_logit".into(), d_alpha);
        grads.insert("series.gamma_logit".into(), d_gamma);
        grads.insert("series.gamma2_logit".into(), d_gamma2);
        grads.insert("series.log_s_init".into(), d_log_s);

        // ---- Adam (model.py::_adam_update) ----
        let step_new = step_old + 1.0;
        let bc1 = 1.0 - model::ADAM_B1.powf(step_new);
        let bc2 = 1.0 - model::ADAM_B2.powf(step_new);
        let mut out_map: HashMap<String, HostTensor> = HashMap::new();
        out_map.insert("loss".into(), HostTensor::scalar(loss));
        out_map.insert("opt.step".into(), HostTensor::scalar(step_new));
        for ospec in &spec.outputs {
            let Some(leaf) = ospec.name.strip_prefix("params.") else {
                continue;
            };
            let g = grads
                .get(leaf)
                .ok_or_else(|| anyhow!("no gradient for `{leaf}`"))?;
            let mut p = get_data(inputs, &ospec.name)?.to_vec();
            let mut m = get_data(inputs, &format!("opt.m.{leaf}"))?.to_vec();
            let mut v = get_data(inputs, &format!("opt.v.{leaf}"))?.to_vec();
            let mult = if leaf.starts_with("series.") {
                self.manifest.per_series_lr_mult
            } else {
                1.0
            };
            // Same operation sequence per element either way (the lane
            // update is bit-identical to the scalar one).
            match self.mode {
                ComputeMode::Lanes => lanes::adam_update_lanes(
                    &mut p, g, &mut m, &mut v, lr, mult, bc1, bc2),
                ComputeMode::Scalar => model::adam_update(
                    &mut p, g, &mut m, &mut v, lr, mult, bc1, bc2),
            }
            out_map.insert(ospec.name.clone(),
                           HostTensor::new(ospec.shape.clone(), p)?);
            out_map.insert(format!("opt.m.{leaf}"),
                           HostTensor::new(ospec.shape.clone(), m)?);
            out_map.insert(format!("opt.v.{leaf}"),
                           HostTensor::new(ospec.shape.clone(), v)?);
        }

        spec.outputs
            .iter()
            .map(|ospec| {
                out_map
                    .remove(&ospec.name)
                    .map(|t| (ospec.name.clone(), t))
                    .ok_or_else(|| anyhow!("missing output `{}`", ospec.name))
            })
            .collect()
    }
}

/// The bare ES layer (debug/verification program). Dual configs read
/// `data.gamma2_logit` and a packed `[S1 | S2]` seasonality block and emit
/// both seasonal tracks (`seas`, `seas2`).
fn run_es(spec: &ProgramSpec, shape: &Shape,
          inputs: &HashMap<&str, &HostTensor>)
          -> Result<Vec<(String, HostTensor)>> {
    let b = spec.batch;
    let (c, s, s2) = (shape.c, shape.s, shape.s2);
    let width = shape.s_total();
    let y = get_data(inputs, "data.y")?;
    let alpha_logit = get_data(inputs, "data.alpha_logit")?;
    let gamma_logit = get_data(inputs, "data.gamma_logit")?;
    let gamma2_logit: &[f32] = if shape.dual() {
        get_data(inputs, "data.gamma2_logit")?
    } else {
        &[]
    };
    let log_s = get_data(inputs, "data.log_s_init")?;
    let mut levels = Vec::with_capacity(b * c);
    let mut seas = Vec::with_capacity(b * (c + s));
    let mut seas2 = Vec::with_capacity(if shape.dual() { b * (c + s2) } else { 0 });
    for i in 0..b {
        let yi = &y[i * c..(i + 1) * c];
        let alpha = crate::hw::sigmoid(alpha_logit[i]);
        let row = &log_s[i * width..(i + 1) * width];
        if shape.dual() {
            let gamma = crate::hw::sigmoid(gamma_logit[i]);
            let gamma2 = crate::hw::sigmoid(gamma2_logit[i]);
            let s1_init: Vec<f32> = row[..s].iter().map(|v| v.exp()).collect();
            let s2_init: Vec<f32> = row[s..].iter().map(|v| v.exp()).collect();
            let (lv, e1, e2) = crate::hw::es_dual_filter(
                yi, alpha, gamma, gamma2, &s1_init, &s2_init);
            levels.extend(lv);
            seas.extend(e1);
            seas2.extend(e2);
        } else {
            let (gamma, s_init): (f32, Vec<f32>) = if shape.seasonal {
                (crate::hw::sigmoid(gamma_logit[i]),
                 row.iter().map(|v| v.exp()).collect())
            } else {
                (0.0, vec![1.0; s])
            };
            let es = crate::hw::es_filter(yi, alpha, gamma, &s_init);
            levels.extend(es.levels);
            seas.extend(es.seas);
        }
    }
    let mut out = vec![
        ("levels".to_string(), HostTensor::new(vec![b, c], levels)?),
        ("seas".to_string(), HostTensor::new(vec![b, c + s], seas)?),
    ];
    if shape.dual() {
        out.push(("seas2".to_string(),
                  HostTensor::new(vec![b, c + s2], seas2)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_covers_native_freqs_and_kinds() {
        let backend = NativeBackend::with_threads(2);
        let m = backend.manifest();
        assert_eq!(m.variant, "native");
        for freq in ["yearly", "quarterly", "monthly", "daily", "hourly"] {
            assert!(m.config(freq).is_ok(), "missing config {freq}");
            assert_eq!(m.available_batches(freq, "train_step"),
                       NATIVE_BATCH_SIZES.to_vec());
            assert_eq!(m.available_batches(freq, "predict"),
                       NATIVE_BATCH_SIZES.to_vec());
            assert!(m.program(&format!("{freq}_init")).is_ok());
            assert!(m.program(&format!("{freq}_b8_es")).is_ok());
        }
        // §8.2 dual seasonality is native now; only the §8.4 penalty
        // variants (and unmodeled weekly) stay out of the native manifest.
        assert_eq!(m.config("hourly").unwrap().seasonality2, 168);
        assert!(m.config("quarterly_pen").is_err());
        assert!(m.config("weekly").is_err());
    }

    #[test]
    fn hourly_specs_carry_dual_leaves() {
        let net = NetworkConfig::for_freq(Frequency::Hourly).unwrap();
        let spec = train_step_spec("hourly", &net, 4);
        let names: Vec<&str> =
            spec.inputs.iter().map(|t| t.name.as_str()).collect();
        // jax flat (alphabetical) series order: alpha, gamma2, gamma, log_s.
        let a = names.iter().position(|n| *n == "params.series.alpha_logit")
            .unwrap();
        assert_eq!(names[a + 1], "params.series.gamma2_logit");
        assert_eq!(names[a + 2], "params.series.gamma_logit");
        assert_eq!(names[a + 3], "params.series.log_s_init");
        let log_s = spec.inputs.iter()
            .find(|t| t.name == "params.series.log_s_init").unwrap();
        assert_eq!(log_s.shape, vec![4, 192]);
        // 8 cell leaves + 4 head + 4 series = 16; 1 loss + 3×16 + step.
        assert_eq!(spec.outputs.len(), 1 + 3 * 16 + 1);

        let es = es_spec("hourly", &net, 8);
        let in_names: Vec<&str> =
            es.inputs.iter().map(|t| t.name.as_str()).collect();
        assert!(in_names.contains(&"data.gamma2_logit"));
        let out_names: Vec<&str> =
            es.outputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(out_names, vec!["levels", "seas", "seas2"]);
        assert_eq!(es.outputs[1].shape, vec![8, 336 + 24]);
        assert_eq!(es.outputs[2].shape, vec![8, 336 + 168]);
    }

    #[test]
    fn train_step_spec_leaf_order_is_manifest_flat_order() {
        let net = NetworkConfig::for_freq(Frequency::Quarterly).unwrap();
        let spec = train_step_spec("quarterly", &net, 16);
        let names: Vec<&str> =
            spec.inputs.iter().map(|t| t.name.as_str()).collect();
        // jax flat order: data.{cat,mask,y}, params.*, opt.m.*, opt.step,
        // opt.v.*, lr — with alphabetical leaves inside each subtree.
        assert_eq!(names[0], "data.cat");
        assert_eq!(names[1], "data.mask");
        assert_eq!(names[2], "data.y");
        assert_eq!(names[3], "params.rnn.cells.0.b");
        assert_eq!(names[4], "params.rnn.cells.0.w");
        let params_end = 3 + 8 + 4 + 3; // 4 cells × 2 + 4 head + 3 series
        assert_eq!(names[params_end - 1], "params.series.log_s_init");
        assert_eq!(names.last().unwrap(), &"lr");
        assert!(names.contains(&"opt.step"));
        assert_eq!(spec.outputs[0].name, "loss");
        assert_eq!(spec.outputs.len(), 1 + 3 * 15 + 1);
    }

    #[test]
    fn init_is_deterministic_and_glorot_bounded() {
        let backend = NativeBackend::with_threads(1);
        let a = backend.execute_init("yearly", 42).unwrap();
        let b = backend.execute_init("yearly", 42).unwrap();
        let c = backend.execute_init("yearly", 43).unwrap();
        assert_eq!(a.len(), b.len());
        for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(ta.data, tb.data);
        }
        // different seed ⇒ different weights
        assert!(a.iter().zip(&c).any(|((_, ta), (_, tc))| ta.data != tc.data));
        // biases zero, weights inside the glorot bound
        for (name, t) in &a {
            if name.ends_with('b') {
                assert!(t.data.iter().all(|v| *v == 0.0), "{name} not zero");
            } else {
                let (rows, cols) = (t.shape[0], t.shape[1]);
                let lim = (6.0 / (rows + cols) as f64).sqrt() as f32;
                assert!(t.data.iter().all(|v| v.abs() <= lim),
                        "{name} exceeds glorot bound");
                assert!(t.data.iter().any(|v| *v != 0.0), "{name} all zero");
            }
        }
        // distinct frequencies draw distinct streams under one seed
        let q = backend.execute_init("quarterly", 42).unwrap();
        assert_ne!(a[1].1.data[..8], q[1].1.data[..8]);
    }

    #[test]
    fn chunks_partition_exactly() {
        assert_eq!(chunks(10, 3), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(chunks(2, 8), vec![(0, 1), (1, 2)]);
        assert_eq!(chunks(1, 1), vec![(0, 1)]);
        let parts = chunks(257, 16);
        assert_eq!(parts.iter().map(|(lo, hi)| hi - lo).sum::<usize>(), 257);
        assert_eq!(parts.first().unwrap().0, 0);
        assert_eq!(parts.last().unwrap().1, 257);
    }
}
