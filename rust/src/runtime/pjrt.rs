//! PJRT execution backend: loads AOT HLO artifacts and runs them
//! (`--features pjrt`).
//!
//! The request-path half of the AOT bridge: `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`. Executables are compiled lazily on first
//! use and cached for the life of the backend, so a training run pays one
//! compile per (frequency, batch-size) program.
//!
//! All tensors are f32 on the wire except the `init` program's uint32 PRNG
//! key. Packing/unpacking to [`xla::Literal`] is centralized here so the
//! rest of the crate never touches XLA types directly — everything above
//! this module talks [`Backend`] + [`HostTensor`].

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::backend::{Backend, BackendStats, HostTensor};
use super::manifest::{Manifest, TensorSpec};

/// Convert a host tensor to an XLA literal matching `spec` (validates shape).
fn to_literal(host: &HostTensor, spec: &TensorSpec) -> Result<xla::Literal> {
    if host.shape != spec.shape {
        return Err(anyhow!("tensor `{}`: host shape {:?} != manifest shape {:?}",
                         spec.name, host.shape, spec.shape));
    }
    let lit = xla::Literal::vec1(&host.data);
    if spec.shape.is_empty() {
        // rank-0: reshape to scalar
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
    let data = lit.to_vec::<f32>()?;
    if data.len() != spec.elem_count() {
        return Err(anyhow!("tensor `{}`: literal has {} elems, manifest says {}",
                         spec.name, data.len(), spec.elem_count()));
    }
    Ok(HostTensor { shape: spec.shape.clone(), data })
}

/// Lazily-compiling PJRT backend over an artifact directory.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<BackendStats>,
}

impl PjrtBackend {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(BackendStats::default()),
        })
    }

    /// Compile (or fetch from cache) a program by manifest name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.program(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

impl Backend for PjrtBackend {
    fn execute_named<'a>(
        &self,
        name: &str,
        lookup: &mut dyn FnMut(&TensorSpec) -> Result<&'a HostTensor>,
    ) -> Result<Vec<(String, HostTensor)>> {
        let spec = self.manifest.program(name)?.clone();
        let exe = self.executable(name)?;

        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            if input.dtype != "float32" {
                return Err(anyhow!("input `{}` has dtype {}, execute_named only \
                                  handles float32",
                                 input.name, input.dtype));
            }
            let host = lookup(input)
                .with_context(|| format!("packing input `{}`", input.name))?;
            lits.push(to_literal(host, input)?);
        }
        let pack = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing `{name}`: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of `{name}`: {e}"))?;
        let exec = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling `{name}`: {e}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!("`{name}` returned {} outputs, manifest says {}",
                             parts.len(), spec.outputs.len()));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&spec.outputs) {
            out.push((ospec.name.clone(), from_literal(lit, ospec)?));
        }
        let unpack = t2.elapsed().as_secs_f64();

        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.pack_secs += pack;
        st.execute_secs += exec;
        st.unpack_secs += unpack;
        Ok(out)
    }

    fn execute_init(&self, freq: &str, seed: u64) -> Result<Vec<(String, HostTensor)>> {
        let name = Manifest::program_name(freq, 0, "init");
        let spec = self.manifest.program(&name)?.clone();
        let exe = self.executable(&name)?;
        let key = [(seed >> 32) as u32, seed as u32];
        let lit = xla::Literal::vec1(&key);
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("executing `{name}`: {e}"))?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!("`{name}` returned {} outputs, manifest says {}",
                             parts.len(), spec.outputs.len()));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&spec.outputs) {
            out.push((ospec.name.clone(), from_literal(lit, ospec)?));
        }
        Ok(out)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        format!("pjrt ({})", self.client.platform_name())
    }

    fn stats(&self) -> BackendStats {
        self.stats.lock().unwrap().clone()
    }
}
