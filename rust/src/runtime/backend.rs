//! The execution-backend abstraction: everything the coordinator, the
//! forecast service and the CLI need from "something that can run the
//! ES-RNN programs", with the program *catalog* (the [`Manifest`]) as the
//! shared contract.
//!
//! Two implementations ship in-tree:
//! * [`crate::runtime::native::NativeBackend`] — pure Rust, no external
//!   runtime, batch-parallel on std threads (the default);
//! * [`crate::runtime::pjrt::PjrtBackend`] — the AOT HLO artifact path via
//!   the PJRT C API (`--features pjrt`).
//!
//! The contract is name-driven: programs are addressed by manifest name
//! (`{freq}_b{batch}_{kind}`), tensors by manifest leaf name (the
//! `data.*` / `params.rnn.*` / `params.series.*` / `opt.{m,v}.*` /
//! `opt.step` / `lr` scheme described in `DESIGN.md`). Callers never see
//! backend-internal types.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::manifest::{Manifest, TensorSpec};

/// A host-resident tensor (f32, row-major) with its shape.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} needs {} elems, got {}", shape, n, data.len()));
        }
        Ok(Self { shape, data })
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn elem_count(&self) -> usize {
        self.data.len()
    }
}

/// Timing/counter totals the telemetry layer scrapes. `compiles` /
/// `compile_secs` stay zero for backends with no compilation step;
/// the steady-state counters (`spawns`, `steady_allocs`,
/// `scratch_bytes`) stay zero for backends without a persistent
/// compute pool.
#[derive(Debug, Default, Clone)]
pub struct BackendStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    pub pack_secs: f64,
    pub unpack_secs: f64,
    /// OS threads spawned since construction. For a persistent pool this
    /// plateaus at `threads - 1` no matter how many steps run.
    pub spawns: u64,
    /// Heap allocations charged to post-warmup steady-state train steps
    /// (counted only when the process installs the counting allocator —
    /// `rust/tests/steady_state.rs` and the BENCH_6 harness; zero
    /// otherwise).
    pub steady_allocs: u64,
    /// Approximate bytes pinned by the backend's reusable arenas
    /// (worker kernel scratch + step/predict scratch).
    pub scratch_bytes: u64,
}

/// A pluggable execution backend.
///
/// Implementations must honor the manifest contract:
/// * `execute_named` calls `lookup` once per program input, in manifest
///   order, validates shapes against the specs, and returns outputs as
///   `(leaf name, tensor)` pairs in manifest output order;
/// * `execute_init` runs the per-frequency `init` program, returning RNN
///   weight leaves named `rnn.*` (no `params.` prefix — the caller owns
///   the prefixing);
/// * `stats` returns cumulative totals since construction.
pub trait Backend {
    /// Execute a program with f32 host tensors supplied by name.
    fn execute_named<'a>(
        &self,
        name: &str,
        lookup: &mut dyn FnMut(&TensorSpec) -> Result<&'a HostTensor>,
    ) -> Result<Vec<(String, HostTensor)>>;

    /// Run the per-frequency `init` program: PRNG seed → RNN weights.
    fn execute_init(&self, freq: &str, seed: u64) -> Result<Vec<(String, HostTensor)>>;

    /// The program catalog this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Human-readable platform identifier (e.g. `native-cpu (8 threads)`).
    fn platform(&self) -> String;

    /// Cumulative execution statistics.
    fn stats(&self) -> BackendStats;
}

/// Convenience for the common call shape: execute with inputs drawn from
/// one or two name→tensor maps (the second typically being persistent
/// model state).
pub fn execute_with_maps(
    backend: &dyn Backend,
    name: &str,
    inputs: &HashMap<String, HostTensor>,
    state: &HashMap<String, HostTensor>,
) -> Result<Vec<(String, HostTensor)>> {
    backend.execute_named(name, &mut |spec| {
        inputs
            .get(&spec.name)
            .or_else(|| state.get(&spec.name))
            .ok_or_else(|| anyhow!("no source for input `{}`", spec.name))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_validation() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(HostTensor::scalar(1.5).elem_count(), 1);
        assert_eq!(HostTensor::zeros(vec![4, 2]).data.len(), 8);
    }
}
