//! Layer-3 execution runtime: the [`Backend`] abstraction plus its two
//! implementations and the manifest contract they share.
//!
//! * [`backend`]  — the `Backend` trait, [`HostTensor`] and stats;
//! * [`manifest`] — the program catalog (names, shapes, leaf order);
//! * [`native`]   — pure-Rust CPU backend (default; no XLA, no Python);
//! * [`pjrt`]     — AOT HLO artifacts via the PJRT C API
//!   (`--features pjrt`).

pub mod backend;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{execute_with_maps, Backend, BackendStats, HostTensor};
pub use manifest::{FreqManifest, Manifest, ProgramSpec, TensorSpec};
pub use native::{ComputeMode, NativeBackend};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use anyhow::Result;

/// Build the backend selected by the environment:
///
/// * `FAST_ESRNN_BACKEND=native` (or unset) — [`NativeBackend`];
/// * `FAST_ESRNN_BACKEND=pjrt` — [`PjrtBackend`] over the artifact dir in
///   `FAST_ESRNN_ARTIFACTS` (default `artifacts/`); requires the `pjrt`
///   feature.
///
/// Examples and benches use this so one binary exercises either backend.
pub fn default_backend() -> Result<Box<dyn Backend>> {
    let which = std::env::var("FAST_ESRNN_BACKEND")
        .unwrap_or_else(|_| "native".to_string());
    backend_by_name(&which)
}

/// Build a backend by name (`native` or `pjrt`), used by the CLI's
/// `--backend` option as well as [`default_backend`].
pub fn backend_by_name(name: &str) -> Result<Box<dyn Backend>> {
    backend_with_artifacts(name, None)
}

/// Like [`backend_by_name`] with an explicit artifact directory for the
/// PJRT backend (`None` falls back to `FAST_ESRNN_ARTIFACTS`, then
/// `artifacts/`).
pub fn backend_with_artifacts(name: &str,
                              artifacts: Option<&std::path::Path>)
                              -> Result<Box<dyn Backend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let dir: std::path::PathBuf = match artifacts {
                Some(p) => p.to_path_buf(),
                None => std::env::var("FAST_ESRNN_ARTIFACTS")
                    .unwrap_or_else(|_| "artifacts".to_string())
                    .into(),
            };
            Ok(Box::new(PjrtBackend::load(dir)?))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            let _ = artifacts;
            anyhow::bail!("backend `pjrt` requires building with --features pjrt")
        }
        other => anyhow::bail!(
            "unknown backend `{other}` (expected `native` or `pjrt`)"),
    }
}
