//! Layer-3 ↔ Layer-2 bridge: load and execute the AOT-compiled HLO
//! artifacts via the PJRT C API (`xla` crate).
//!
//! Python never runs at train/serve time: `make artifacts` lowers the JAX
//! model (with its Pallas kernels) to HLO text once, and everything in this
//! module consumes those files.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineStats, HostTensor};
pub use manifest::{FreqManifest, Manifest, ProgramSpec, TensorSpec};
