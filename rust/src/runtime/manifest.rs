//! Artifact manifest: the contract between the Python AOT pipeline and the
//! Rust coordinator.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! lowered program: its HLO file and the exact flattened order of input and
//! output leaves (name, shape, dtype). Rust packs literals by walking the
//! manifest — it never hardcodes pytree layouts, so the two sides can evolve
//! independently as long as leaf *names* stay stable.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One tensor leaf in a program's flattened input or output list.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    /// Total number of elements (1 for rank-0).
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_usize_vec()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled program (train_step / predict / init).
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: String,
    pub freq: String,
    pub batch: usize,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ProgramSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            freq: v.get("freq")?.as_str()?.to_string(),
            batch: v.get("batch")?.as_usize()?,
            kind: v.get("kind")?.as_str()?.to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
        })
    }
}

/// Per-frequency compile-time configuration (mirror of `configs.py`).
#[derive(Debug, Clone)]
pub struct FreqManifest {
    pub seasonality: usize,
    /// §8.2 second seasonality (0 = single; absent in old manifests).
    pub seasonality2: usize,
    pub horizon: usize,
    pub input_window: usize,
    pub length: usize,
    pub hidden: usize,
    pub dilations: Vec<Vec<usize>>,
    pub positions: usize,
    pub valid_positions: usize,
}

impl FreqManifest {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            seasonality: v.get("seasonality")?.as_usize()?,
            seasonality2: v.opt("seasonality2")
                .map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            horizon: v.get("horizon")?.as_usize()?,
            input_window: v.get("input_window")?.as_usize()?,
            length: v.get("length")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            dilations: v
                .get("dilations")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize_vec())
                .collect::<Result<_>>()?,
            positions: v.get("positions")?.as_usize()?,
            valid_positions: v.get("valid_positions")?.as_usize()?,
        })
    }
}

/// The whole `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub variant: String,
    pub tau: f32,
    pub per_series_lr_mult: f32,
    pub batch_sizes: Vec<usize>,
    pub configs: HashMap<String, FreqManifest>,
    pub programs: HashMap<String, ProgramSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut configs = HashMap::new();
        for (k, c) in v.get("configs")?.as_obj()? {
            configs.insert(k.clone(), FreqManifest::from_json(c)?);
        }
        let mut programs = HashMap::new();
        for (k, p) in v.get("programs")?.as_obj()? {
            programs.insert(k.clone(), ProgramSpec::from_json(p)?);
        }
        Ok(Self {
            version: v.get("version")?.as_usize()?,
            variant: v.get("variant")?.as_str()?.to_string(),
            tau: v.get("tau")?.as_f32()?,
            per_series_lr_mult: v.get("per_series_lr_mult")?.as_f32()?,
            batch_sizes: v.get("batch_sizes")?.as_usize_vec()?,
            configs,
            programs,
        })
    }

    /// Program name for a given frequency / batch size / kind.
    pub fn program_name(freq: &str, batch: usize, kind: &str) -> String {
        match kind {
            "init" => format!("{freq}_init"),
            _ => format!("{freq}_b{batch}_{kind}"),
        }
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs.get(name).ok_or_else(|| {
            anyhow!("program `{name}` not in manifest (have: {:?})",
                    self.programs.keys().collect::<Vec<_>>())
        })
    }

    pub fn config(&self, freq: &str) -> Result<&FreqManifest> {
        self.configs
            .get(freq)
            .ok_or_else(|| anyhow!("frequency `{freq}` not in manifest"))
    }

    /// Frequencies present, sorted.
    pub fn freqs(&self) -> Vec<String> {
        let mut v: Vec<String> = self.configs.keys().cloned().collect();
        v.sort();
        v
    }

    /// Batch sizes available for a (freq, kind) pair, ascending.
    pub fn available_batches(&self, freq: &str, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .programs
            .values()
            .filter(|p| p.freq == freq && p.kind == kind)
            .map(|p| p.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "variant": "pallas", "tau": 0.48,
      "per_series_lr_mult": 1.5, "batch_sizes": [1, 16],
      "configs": {"yearly": {"seasonality": 1, "horizon": 6,
        "input_window": 4, "length": 24, "hidden": 30,
        "dilations": [[1,2],[2,6]], "positions": 21, "valid_positions": 15}},
      "programs": {"yearly_b16_train_step": {
        "file": "yearly_b16_train_step.hlo.txt", "freq": "yearly",
        "batch": 16, "kind": "train_step",
        "inputs": [{"name": "data.y", "shape": [16, 24], "dtype": "float32"}],
        "outputs": [{"name": "loss", "shape": [], "dtype": "float32"}]}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tau, 0.48);
        let cfg = m.config("yearly").unwrap();
        assert_eq!(cfg.dilations, vec![vec![1, 2], vec![2, 6]]);
        let p = m.program("yearly_b16_train_step").unwrap();
        assert_eq!(p.inputs[0].elem_count(), 384);
        assert_eq!(p.outputs[0].elem_count(), 1);
        assert_eq!(m.available_batches("yearly", "train_step"), vec![16]);
        assert!(m.program("nope").is_err());
        assert!(m.config("weekly").is_err());
    }

    #[test]
    fn program_name_formats() {
        assert_eq!(Manifest::program_name("monthly", 64, "train_step"),
                   "monthly_b64_train_step");
        assert_eq!(Manifest::program_name("yearly", 0, "init"), "yearly_init");
    }
}
