//! Classical forecasting baselines.
//!
//! Table 4 compares ES-RNN against the M4 benchmark **Comb** — the simple
//! average of Simple, Holt and Damped exponential smoothing (Makridakis et
//! al. 2018). We implement those three exactly, plus Naive, Seasonal Naive,
//! full Holt-Winters and Theta as additional reference points. All methods
//! operate on a seasonally-adjusted series when the frequency is seasonal
//! (the M4 benchmark convention: classical decomposition → forecast →
//! re-seasonalize).

use crate::hw::seasonal_indices;

mod theta;
pub use theta::Theta;

/// A point-forecast method: history → H-step forecast.
pub trait Forecaster {
    fn name(&self) -> &'static str;
    /// `y` is strictly positive history; returns `horizon` forecasts.
    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32>;
}

// ---------------------------------------------------------------------
// Seasonal adjustment shared by the ES-family baselines (M4 convention).
// ---------------------------------------------------------------------

/// Deseasonalize; returns (adjusted series, indices).
fn deseasonalize(y: &[f32], period: usize) -> (Vec<f32>, Vec<f32>) {
    if period <= 1 {
        return (y.to_vec(), vec![1.0]);
    }
    let idx = seasonal_indices(y, period);
    let adj: Vec<f32> = y
        .iter()
        .enumerate()
        .map(|(t, v)| v / idx[t % period].max(1e-6))
        .collect();
    (adj, idx)
}

/// Re-seasonalize an H-step forecast started at position `n`.
fn reseasonalize(fc: &mut [f32], idx: &[f32], n: usize, period: usize) {
    if period <= 1 {
        return;
    }
    for (h, v) in fc.iter_mut().enumerate() {
        *v *= idx[(n + h) % period];
    }
}

// ---------------------------------------------------------------------
// Core exponential-smoothing fits (SSE-grid-optimized like the M4 code).
// ---------------------------------------------------------------------

/// Simple exponential smoothing with fixed alpha; returns (fitted level,
/// one-step SSE).
fn ses_sse(y: &[f32], alpha: f32) -> (f32, f64) {
    let mut l = y[0];
    let mut sse = 0.0f64;
    for &v in &y[1..] {
        sse += ((v - l) as f64).powi(2);
        l = alpha * v + (1.0 - alpha) * l;
    }
    (l, sse)
}

/// Grid-search alpha for SES (the M4 benchmark optimizes smoothing
/// parameters; a fine grid is equivalent for our purposes).
fn fit_ses(y: &[f32]) -> (f32, f32) {
    let mut best = (0.1f32, f64::INFINITY, y[0]);
    for i in 1..=99 {
        let a = i as f32 / 100.0;
        let (l, sse) = ses_sse(y, a);
        if sse < best.1 {
            best = (a, sse, l);
        }
    }
    (best.0, best.2)
}

/// Holt's linear trend (optionally damped by phi); returns (level, trend,
/// SSE) for given (alpha, beta).
fn holt_sse(y: &[f32], alpha: f32, beta: f32, phi: f32) -> (f32, f32, f64) {
    let mut l = y[0];
    let mut b = if y.len() > 1 { y[1] - y[0] } else { 0.0 };
    let mut sse = 0.0f64;
    for &v in &y[1..] {
        let pred = l + phi * b;
        sse += ((v - pred) as f64).powi(2);
        let l_new = alpha * v + (1.0 - alpha) * pred;
        b = beta * (l_new - l) + (1.0 - beta) * phi * b;
        l = l_new;
    }
    (l, b, sse)
}

/// Coarse grid fit for Holt / Damped-Holt.
fn fit_holt(y: &[f32], phi: f32) -> (f32, f32, f32, f32) {
    let mut best = (0.2f32, 0.05f32, f64::INFINITY, (y[0], 0.0f32));
    for ai in 1..=19 {
        let a = ai as f32 * 0.05;
        for bi in 0..=10 {
            let b = bi as f32 * 0.05;
            let (l, tr, sse) = holt_sse(y, a, b, phi);
            if sse < best.2 {
                best = (a, b, sse, (l, tr));
            }
        }
    }
    (best.0, best.1, best.3 .0, best.3 .1)
}

// ---------------------------------------------------------------------
// Public methods
// ---------------------------------------------------------------------

/// Repeat the last observation.
pub struct Naive;

impl Forecaster for Naive {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn forecast(&self, y: &[f32], _period: usize, horizon: usize) -> Vec<f32> {
        vec![*y.last().unwrap(); horizon]
    }
}

/// Repeat the last seasonal cycle (M4's Naive2 on raw data).
pub struct SeasonalNaive;

impl Forecaster for SeasonalNaive {
    fn name(&self) -> &'static str {
        "SeasonalNaive"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        let p = period.max(1).min(y.len());
        (0..horizon).map(|h| y[y.len() - p + (h % p)]).collect()
    }
}

/// Simple exponential smoothing on the seasonally-adjusted series.
pub struct Ses;

impl Forecaster for Ses {
    fn name(&self) -> &'static str {
        "SES"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        let (adj, idx) = deseasonalize(y, period);
        let (_, l) = fit_ses(&adj);
        let mut fc = vec![l; horizon];
        reseasonalize(&mut fc, &idx, y.len(), period);
        fc
    }
}

/// Holt's linear trend on the adjusted series.
pub struct Holt;

impl Forecaster for Holt {
    fn name(&self) -> &'static str {
        "Holt"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        let (adj, idx) = deseasonalize(y, period);
        let (_, _, l, b) = fit_holt(&adj, 1.0);
        let mut fc: Vec<f32> =
            (1..=horizon).map(|h| l + h as f32 * b).collect();
        reseasonalize(&mut fc, &idx, y.len(), period);
        fc
    }
}

/// Damped-trend Holt (phi = 0.9, the Comb convention).
pub struct DampedHolt;

impl Forecaster for DampedHolt {
    fn name(&self) -> &'static str {
        "Damped"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        const PHI: f32 = 0.9;
        let (adj, idx) = deseasonalize(y, period);
        let (_, _, l, b) = fit_holt(&adj, PHI);
        let mut fc = Vec::with_capacity(horizon);
        let mut damp = 0.0f32;
        for h in 1..=horizon {
            damp += PHI.powi(h as i32);
            fc.push(l + damp * b);
        }
        reseasonalize(&mut fc, &idx, y.len(), period);
        fc
    }
}

/// The M4 benchmark: average of SES, Holt and Damped (paper §6 "Comb").
pub struct Comb;

impl Forecaster for Comb {
    fn name(&self) -> &'static str {
        "Comb"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        let a = Ses.forecast(y, period, horizon);
        let b = Holt.forecast(y, period, horizon);
        let c = DampedHolt.forecast(y, period, horizon);
        (0..horizon)
            .map(|h| (a[h] + b[h] + c[h]) / 3.0)
            .collect()
    }
}

/// Full multiplicative Holt-Winters (level + trend + seasonality) — the
/// textbook Eqs. 1–4 with a fixed small parameter set.
pub struct HoltWinters;

impl Forecaster for HoltWinters {
    fn name(&self) -> &'static str {
        "HoltWinters"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        let p = period.max(1);
        if p == 1 || y.len() < 2 * p {
            return DampedHolt.forecast(y, 1, horizon);
        }
        let (alpha, beta, gamma) = (0.3f32, 0.05f32, 0.2f32);
        let mut s: Vec<f32> = seasonal_indices(y, p);
        let mut l = y[..p].iter().sum::<f32>() / p as f32;
        let mut b = (y[p..2 * p].iter().sum::<f32>()
                     - y[..p].iter().sum::<f32>())
            / (p * p) as f32;
        for (t, &v) in y.iter().enumerate() {
            let s_t = s[t % p];
            let l_new = alpha * v / s_t.max(1e-6) + (1.0 - alpha) * (l + b);
            b = beta * (l_new - l) + (1.0 - beta) * b;
            s[t % p] = gamma * v / l_new.max(1e-6) + (1.0 - gamma) * s_t;
            l = l_new;
        }
        (1..=horizon)
            .map(|h| (l + h as f32 * b) * s[(y.len() + h - 1) % p])
            .collect()
    }
}

/// All baselines in display order.
pub fn all_baselines() -> Vec<Box<dyn Forecaster>> {
    vec![
        Box::new(Naive),
        Box::new(SeasonalNaive),
        Box::new(Ses),
        Box::new(Holt),
        Box::new(DampedHolt),
        Box::new(Comb),
        Box::new(HoltWinters),
        Box::new(theta::Theta),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seasonal_series(n: usize) -> Vec<f32> {
        let s = [0.8f32, 1.1, 1.25, 0.85];
        (0..n).map(|t| (100.0 + t as f32) * s[t % 4]).collect()
    }

    #[test]
    fn naive_repeats_last() {
        let fc = Naive.forecast(&[1.0, 2.0, 7.0], 1, 3);
        assert_eq!(fc, vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn seasonal_naive_repeats_cycle() {
        let y = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let fc = SeasonalNaive.forecast(&y, 4, 6);
        assert_eq!(fc, vec![10.0, 20.0, 30.0, 40.0, 10.0, 20.0]);
    }

    #[test]
    fn ses_constant_series_exact() {
        let fc = Ses.forecast(&vec![5.0; 30], 1, 4);
        for v in fc {
            assert!((v - 5.0).abs() < 1e-4);
        }
    }

    #[test]
    fn holt_extrapolates_linear_trend() {
        let y: Vec<f32> = (0..40).map(|t| 10.0 + 2.0 * t as f32).collect();
        let fc = Holt.forecast(&y, 1, 4);
        for (h, v) in fc.iter().enumerate() {
            let expect = 10.0 + 2.0 * (39 + h + 1) as f32;
            assert!((v - expect).abs() < 0.5, "h={h}: {v} vs {expect}");
        }
    }

    #[test]
    fn damped_growth_slower_than_holt() {
        let y: Vec<f32> = (0..40).map(|t| 10.0 + 2.0 * t as f32).collect();
        let h = Holt.forecast(&y, 1, 8);
        let d = DampedHolt.forecast(&y, 1, 8);
        assert!(d[7] < h[7], "damped {} should trail holt {}", d[7], h[7]);
        assert!(d[7] > *y.last().unwrap(), "damped still grows");
    }

    #[test]
    fn comb_is_mean_of_components() {
        let y = seasonal_series(60);
        let comb = Comb.forecast(&y, 4, 4);
        let s = Ses.forecast(&y, 4, 4);
        let h = Holt.forecast(&y, 4, 4);
        let d = DampedHolt.forecast(&y, 4, 4);
        for i in 0..4 {
            assert!((comb[i] - (s[i] + h[i] + d[i]) / 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn seasonal_methods_capture_seasonality() {
        let y = seasonal_series(80);
        for m in [&Comb as &dyn Forecaster, &HoltWinters, &Ses] {
            let fc = m.forecast(&y, 4, 4);
            // Forecast phase pattern should match planted indices:
            // position 80 is phase 0 (0.8), 82 is phase 2 (1.25).
            assert!(fc[2] > fc[0],
                    "{}: expected phase-2 > phase-0, got {fc:?}", m.name());
        }
    }

    #[test]
    fn forecasts_are_finite_positive_on_generated_corpus() {
        use crate::data::{generate, GenOptions};
        let corpus = generate(&GenOptions { scale: 2000, ..Default::default() }).unwrap();
        for s in &corpus.series {
            if s.len() < 10 {
                continue;
            }
            for m in all_baselines() {
                let fc = m.forecast(&s.values, s.freq.seasonality().min(s.len() / 2),
                                    s.freq.horizon());
                assert!(fc.iter().all(|v| v.is_finite()),
                        "{} produced non-finite on {}", m.name(), s.id);
            }
        }
    }
}
