//! Theta method (Assimakopoulos & Nikolopoulos 2000) — the M4 reference
//! statistical method (it won M3; Hyndman's meta-learner ensembles it).
//!
//! Standard two-line formulation: the theta=0 line is the linear
//! regression on time (pure trend), the theta=2 line is `2y - line0`,
//! forecast = average of (extrapolated line0, SES forecast of line2),
//! applied to the seasonally-adjusted series.

use super::{Forecaster};
use crate::hw::seasonal_indices;

/// Least-squares line a + b*t over the series.
fn linfit(y: &[f32]) -> (f64, f64) {
    let n = y.len() as f64;
    let sum_t = (0..y.len()).sum::<usize>() as f64;
    let sum_y: f64 = y.iter().map(|v| *v as f64).sum();
    let sum_tt: f64 = (0..y.len()).map(|t| (t * t) as f64).sum();
    let sum_ty: f64 = y.iter().enumerate().map(|(t, v)| t as f64 * *v as f64).sum();
    let denom = n * sum_tt - sum_t * sum_t;
    if denom.abs() < 1e-12 {
        return (sum_y / n, 0.0);
    }
    let b = (n * sum_ty - sum_t * sum_y) / denom;
    let a = (sum_y - b * sum_t) / n;
    (a, b)
}

/// SES with grid-fit alpha; returns final level.
fn ses_level(y: &[f32]) -> f32 {
    let mut best = (f64::INFINITY, y[0]);
    for i in 1..=99 {
        let alpha = i as f32 / 100.0;
        let mut l = y[0];
        let mut sse = 0.0f64;
        for &v in &y[1..] {
            sse += ((v - l) as f64).powi(2);
            l = alpha * v + (1.0 - alpha) * l;
        }
        if sse < best.0 {
            best = (sse, l);
        }
    }
    best.1
}

pub struct Theta;

impl Forecaster for Theta {
    fn name(&self) -> &'static str {
        "Theta"
    }

    fn forecast(&self, y: &[f32], period: usize, horizon: usize) -> Vec<f32> {
        // Seasonal adjustment (multiplicative, M4 convention).
        let p = period.max(1);
        let (adj, idx): (Vec<f32>, Vec<f32>) = if p > 1 {
            let idx = seasonal_indices(y, p);
            (
                y.iter()
                    .enumerate()
                    .map(|(t, v)| v / idx[t % p].max(1e-6))
                    .collect(),
                idx,
            )
        } else {
            (y.to_vec(), vec![1.0])
        };

        let n = adj.len();
        let (a, b) = linfit(&adj);
        // theta = 2 line: 2*y - line0.
        let line2: Vec<f32> = adj
            .iter()
            .enumerate()
            .map(|(t, v)| 2.0 * v - (a + b * t as f64) as f32)
            .collect();
        let l2 = ses_level(&line2);

        (0..horizon)
            .map(|h| {
                let t = (n + h) as f64;
                let line0 = (a + b * t) as f32;
                let f = 0.5 * (line0 + l2);
                if p > 1 {
                    f * idx[(n + h) % p]
                } else {
                    f
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linfit_recovers_line() {
        let y: Vec<f32> = (0..30).map(|t| 3.0 + 0.5 * t as f32).collect();
        let (a, b) = linfit(&y);
        assert!((a - 3.0).abs() < 1e-6);
        assert!((b - 0.5).abs() < 1e-8);
    }

    #[test]
    fn theta_on_linear_trend_tracks_it() {
        let y: Vec<f32> = (0..40).map(|t| 10.0 + 2.0 * t as f32).collect();
        let fc = Theta.forecast(&y, 1, 4);
        // Theta halves the trend slope relative to pure extrapolation
        // (line0 grows, SES line flat) — forecasts must keep rising but
        // stay between last value and full extrapolation.
        let last = *y.last().unwrap();
        for (h, v) in fc.iter().enumerate() {
            let full = 10.0 + 2.0 * (40 + h) as f32;
            assert!(*v > last - 1.0 && *v <= full + 1e-3,
                    "h={h}: {v} not in ({last}, {full}]");
        }
        assert!(fc[3] > fc[0]);
    }

    #[test]
    fn theta_seasonal_phase_preserved() {
        let s = [0.7f32, 1.3];
        let y: Vec<f32> = (0..60).map(|t| (50.0 + t as f32) * s[t % 2]).collect();
        let fc = Theta.forecast(&y, 2, 4);
        assert!(fc[1] > fc[0], "phase 1 should exceed phase 0: {fc:?}");
        assert!(fc[3] > fc[2]);
    }
}
