//! Zero-dependency metrics registry with Prometheus text rendering.
//!
//! The instruments are deliberately minimal and lock-free on the hot
//! path: a [`Counter`] / [`Gauge`] is one relaxed atomic, a
//! [`Histogram`] is a fixed array of log-spaced buckets plus a
//! nanosecond sum — no locks, no allocation, no floating-point math
//! beyond the bucket search. Layers that keep stats create their
//! instruments up front and hand clones to the [`Registry`], which
//! only stores the mapping `family name → labeled series`; the scrape
//! path (`GET /v1/metrics`) walks that mapping and renders the
//! Prometheus text exposition format (0.0.4) into a single `String` —
//! the response buffer is the only allocation a scrape performs.
//!
//! Series are keyed by their (sorted) label pairs, so re-registering
//! the same name+labels replaces the instrument in place (idempotent
//! shard re-add), and [`Registry::unregister`] drops every series of a
//! departing shard by its `shard="..."` label pair.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of finite histogram buckets (the `+Inf` overflow bucket is
/// tracked separately).
pub const BUCKETS: usize = 20;

/// Upper bounds (seconds) of the finite histogram buckets: log-spaced
/// ×2 from 100µs to ~52s, which brackets everything from a cache-warm
/// native forecast to a pathologically stalled queue. Literal values
/// so they render exactly the same way they are written here.
pub const BUCKET_BOUNDS: [f64; BUCKETS] = [
    1e-4, 2e-4, 4e-4, 8e-4, 1.6e-3, 3.2e-3, 6.4e-3, 1.28e-2, 2.56e-2,
    5.12e-2, 1.024e-1, 2.048e-1, 4.096e-1, 8.192e-1, 1.6384, 3.2768,
    6.5536, 13.1072, 26.2144, 52.4288,
];

/// Monotonically increasing event count. Clones share the same cell,
/// so a layer keeps one copy for its hot path and registers another.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A fresh counter at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (queue depth, generation, …).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// A fresh gauge at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistogramInner {
    /// Per-bucket (non-cumulative) observation counts; rendering
    /// accumulates them into the cumulative `_bucket{le=...}` form.
    counts: [AtomicU64; BUCKETS],
    /// Observations above the largest finite bound (`+Inf` bucket).
    overflow: AtomicU64,
    /// Sum of observations in integer nanoseconds, so `observe` needs
    /// no float atomics; rendered back as seconds.
    sum_nanos: AtomicU64,
}

/// Fixed log-bucketed latency histogram (seconds). Complements the
/// exact-quantile [`Quantiles`](super::Quantiles) ring: the ring feeds
/// `/v1/stats` p50/p95/p99, the histogram feeds `/v1/metrics` so
/// scrapers can aggregate across shards and compute rates.
#[derive(Debug, Clone)]
pub struct Histogram {
    inner: Arc<HistogramInner>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// A fresh, empty histogram over [`BUCKET_BOUNDS`].
    pub fn new() -> Self {
        Histogram {
            inner: Arc::new(HistogramInner {
                counts: std::array::from_fn(|_| AtomicU64::new(0)),
                overflow: AtomicU64::new(0),
                sum_nanos: AtomicU64::new(0),
            }),
        }
    }

    /// Record one observation in seconds. Negative and NaN inputs
    /// contribute zero to the sum; NaN lands in the `+Inf` bucket.
    pub fn observe(&self, secs: f64) {
        let nanos = (secs.max(0.0) * 1e9) as u64;
        self.inner.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        match BUCKET_BOUNDS.iter().position(|b| secs <= *b) {
            Some(i) => {
                self.inner.counts[i].fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.inner.overflow.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        let inner = &self.inner;
        let mut total = inner.overflow.load(Ordering::Relaxed);
        for c in &inner.counts {
            total += c.load(Ordering::Relaxed);
        }
        total
    }

    /// One pass over the atomics: per-bucket counts with the `+Inf`
    /// overflow appended last, plus the sum in seconds.
    fn snapshot(&self) -> ([u64; BUCKETS + 1], f64) {
        let mut counts = [0u64; BUCKETS + 1];
        for (i, c) in self.inner.counts.iter().enumerate() {
            counts[i] = c.load(Ordering::Relaxed);
        }
        counts[BUCKETS] = self.inner.overflow.load(Ordering::Relaxed);
        let sum = self.inner.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        (counts, sum)
    }
}

#[derive(Debug, Clone)]
enum Instrument {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug)]
struct Family {
    help: &'static str,
    kind: &'static str,
    /// Labeled series, kept sorted by label set for a deterministic
    /// exposition order.
    series: Vec<(Vec<(String, String)>, Instrument)>,
}

/// The metric catalog (one per sharded serving stack): family
/// metadata plus every bound labeled series. Registration is rare
/// (shard add/remove, server start); scrapes take the one mutex
/// briefly and never touch the instruments' hot paths.
#[derive(Debug, Default)]
pub struct Registry {
    // Leaf lock: held only while mutating/walking the catalog, never
    // while acquiring another lock.
    // lint:lock-name(telemetry.registry)
    families: Mutex<BTreeMap<&'static str, Family>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn register(
        &self,
        name: &'static str,
        help: &'static str,
        kind: &'static str,
        labels: &[(&str, &str)],
        inst: Instrument,
    ) {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        let mut families = self.families.lock().unwrap();
        let fam = families.entry(name).or_insert_with(|| Family {
            help,
            kind,
            series: Vec::new(),
        });
        match fam.series.iter_mut().find(|(l, _)| *l == labels) {
            Some(slot) => slot.1 = inst,
            None => {
                fam.series.push((labels, inst));
                fam.series.sort_by(|a, b| a.0.cmp(&b.0));
            }
        }
    }

    /// Bind `counter` as `name{labels}`. Idempotent: the same
    /// name+labels replaces the previous instrument.
    pub fn register_counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        counter: &Counter,
    ) {
        self.register(name, help, "counter", labels,
                      Instrument::Counter(counter.clone()));
    }

    /// Bind `gauge` as `name{labels}`; idempotent like
    /// [`Registry::register_counter`].
    pub fn register_gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        gauge: &Gauge,
    ) {
        self.register(name, help, "gauge", labels,
                      Instrument::Gauge(gauge.clone()));
    }

    /// Bind `hist` as `name{labels}`; idempotent like
    /// [`Registry::register_counter`].
    pub fn register_histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        hist: &Histogram,
    ) {
        self.register(name, help, "histogram", labels,
                      Instrument::Histogram(hist.clone()));
    }

    /// Drop every series carrying the label pair `key="value"` — e.g.
    /// `unregister("shard", "alpha")` removes a drained shard's whole
    /// slice of the exposition. Families left empty disappear with it.
    pub fn unregister(&self, key: &str, value: &str) {
        let mut families = self.families.lock().unwrap();
        for fam in families.values_mut() {
            fam.series.retain(|(labels, _)| {
                !labels.iter().any(|(k, v)| k == key && v == value)
            });
        }
        families.retain(|_, fam| !fam.series.is_empty());
    }

    /// Render the whole catalog in the Prometheus text exposition
    /// format (0.0.4): `# HELP` / `# TYPE` per family, then each
    /// labeled series; histograms expand to cumulative
    /// `_bucket{le=...}` samples plus `_sum` / `_count`. The returned
    /// `String` is the only allocation.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        let families = self.families.lock().unwrap();
        for (name, fam) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
            for (labels, inst) in &fam.series {
                match inst {
                    Instrument::Counter(c) => {
                        write_plain(&mut out, name, labels, c.get());
                    }
                    Instrument::Gauge(g) => {
                        write_plain(&mut out, name, labels, g.get());
                    }
                    Instrument::Histogram(h) => {
                        let (counts, sum) = h.snapshot();
                        let mut cum = 0u64;
                        for (i, c) in counts.iter().enumerate() {
                            cum += c;
                            out.push_str(name);
                            out.push_str("_bucket{");
                            for (k, v) in labels {
                                push_label(&mut out, k, v);
                                out.push(',');
                            }
                            if i < BUCKETS {
                                let _ = writeln!(
                                    out, "le=\"{}\"}} {cum}",
                                    BUCKET_BOUNDS[i]
                                );
                            } else {
                                let _ =
                                    writeln!(out, "le=\"+Inf\"}} {cum}");
                            }
                        }
                        out.push_str(name);
                        out.push_str("_sum");
                        write_label_block(&mut out, labels);
                        let _ = writeln!(out, " {sum}");
                        out.push_str(name);
                        out.push_str("_count");
                        write_label_block(&mut out, labels);
                        let _ = writeln!(out, " {cum}");
                    }
                }
            }
        }
        out
    }
}

fn push_label(out: &mut String, key: &str, value: &str) {
    out.push_str(key);
    out.push_str("=\"");
    for ch in value.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_label_block(out: &mut String, labels: &[(String, String)]) {
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_label(out, k, v);
    }
    out.push('}');
}

fn write_plain(
    out: &mut String,
    name: &str,
    labels: &[(String, String)],
    value: u64,
) {
    use std::fmt::Write as _;
    out.push_str(name);
    write_label_block(out, labels);
    let _ = writeln!(out, " {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_render_with_sorted_labels() {
        let reg = Registry::new();
        let c = Counter::new();
        c.add(3);
        reg.register_counter("t_requests_total", "Requests.",
                             &[("shard", "s0"), ("freq", "monthly")], &c);
        let g = Gauge::new();
        g.set(7);
        reg.register_gauge("t_depth", "Depth.", &[], &g);
        let text = reg.render();
        assert!(text.contains("# HELP t_requests_total Requests."));
        assert!(text.contains("# TYPE t_requests_total counter"));
        assert!(text.contains(
            "t_requests_total{freq=\"monthly\",shard=\"s0\"} 3"
        ));
        assert!(text.contains("# TYPE t_depth gauge"));
        assert!(text.contains("\nt_depth 7\n"));
        c.inc();
        assert!(reg.render().contains("shard=\"s0\"} 4"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_sum_in_seconds() {
        let reg = Registry::new();
        let h = Histogram::new();
        h.observe(0.00005); // below first bound -> bucket 0
        h.observe(0.0003); // bucket le=0.0004
        h.observe(1000.0); // +Inf overflow
        h.observe(f64::NAN); // +Inf, zero sum contribution
        h.observe(-1.0); // bucket 0 (<= first bound), zero sum
        reg.register_histogram("t_lat_seconds", "Latency.", &[], &h);
        assert_eq!(h.count(), 5);
        let text = reg.render();
        assert!(text.contains("# TYPE t_lat_seconds histogram"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"0.0001\"} 2"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"0.0002\"} 2"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"0.0004\"} 3"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"52.4288\"} 3"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("t_lat_seconds_count 5"));
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("t_lat_seconds_sum "))
            .unwrap();
        let sum: f64 = sum_line.split(' ').nth(1).unwrap().parse().unwrap();
        assert!((sum - 1000.00035).abs() < 1e-6, "sum = {sum}");
    }

    #[test]
    fn rebind_replaces_and_unregister_drops_by_label() {
        let reg = Registry::new();
        let a = Counter::new();
        a.add(10);
        reg.register_counter("t_total", "T.", &[("shard", "a")], &a);
        let b = Counter::new();
        b.add(2);
        // Same name+labels: replaces instrument `a` in place.
        reg.register_counter("t_total", "T.", &[("shard", "a")], &b);
        let c = Counter::new();
        c.add(5);
        reg.register_counter("t_total", "T.", &[("shard", "b")], &c);
        let text = reg.render();
        assert!(text.contains("t_total{shard=\"a\"} 2"));
        assert!(text.contains("t_total{shard=\"b\"} 5"));
        reg.unregister("shard", "a");
        let text = reg.render();
        assert!(!text.contains("shard=\"a\""));
        assert!(text.contains("t_total{shard=\"b\"} 5"));
        reg.unregister("shard", "b");
        assert_eq!(reg.render(), "");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        let g = Gauge::new();
        reg.register_gauge("t_esc", "E.", &[("k", "a\\b\"c\nd")], &g);
        assert!(reg.render().contains("t_esc{k=\"a\\\\b\\\"c\\nd\"} 0"));
    }
}
