//! Strict parser for the Prometheus text exposition format (0.0.4)
//! plus a `histogram_quantile` helper.
//!
//! Shared by the `fast-esrnn top` live dashboard and the
//! `metrics_conformance` integration test, so "every `/v1/metrics`
//! line is valid Prometheus text" means exactly one thing in both
//! places. The parser is stricter than real scrapers: every sample
//! must follow a `# TYPE` line for its family (histogram samples may
//! carry the `_bucket` / `_sum` / `_count` suffix), metric and label
//! names must match the Prometheus charset, label values must use the
//! `\\` / `\"` / `\n` escapes, and counter samples must be finite and
//! non-negative.

use anyhow::{anyhow, bail, Result};

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Name as written on the sample line (histogram samples keep
    /// their `_bucket` / `_sum` / `_count` suffix).
    pub name: String,
    /// The family the sample belongs to (the `# TYPE` line's name).
    pub family: String,
    /// Family kind from the `# TYPE` line (`counter`, `gauge`,
    /// `histogram`, ...).
    pub kind: String,
    /// Label pairs in line order (`le` included for buckets).
    pub labels: Vec<(String, String)>,
    /// Sample value (`+Inf` / `-Inf` / `NaN` parse to the f64 specials).
    pub value: f64,
}

impl Sample {
    /// Value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse a full exposition. Fails (with a line number) on the first
/// malformed line.
pub fn parse(text: &str) -> Result<Vec<Sample>> {
    let mut out = Vec::new();
    // (family name, kind) of the most recent # TYPE line.
    let mut family: Option<(String, String)> = None;
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, _help) = rest
                .split_once(' ')
                .ok_or_else(|| anyhow!("line {n}: # HELP without text"))?;
            if !valid_name(name) {
                bail!("line {n}: invalid metric name `{name}` in # HELP");
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .ok_or_else(|| anyhow!("line {n}: # TYPE without kind"))?;
            if !valid_name(name) {
                bail!("line {n}: invalid metric name `{name}` in # TYPE");
            }
            if !matches!(kind, "counter" | "gauge" | "histogram"
                               | "summary" | "untyped")
            {
                bail!("line {n}: unknown metric type `{kind}`");
            }
            family = Some((name.to_string(), kind.to_string()));
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        let sample = parse_sample(line, family.as_ref())
            .map_err(|e| anyhow!("line {n}: {e}"))?;
        out.push(sample);
    }
    Ok(out)
}

fn parse_sample(
    line: &str,
    family: Option<&(String, String)>,
) -> Result<Sample> {
    let (name, labels, rest) = if let Some(brace) = line.find('{') {
        let (labels, after) = parse_labels(&line[brace + 1..])?;
        (&line[..brace], labels, after)
    } else {
        let sp = line
            .find(' ')
            .ok_or_else(|| anyhow!("sample line has no value"))?;
        (&line[..sp], Vec::new(), &line[sp..])
    };
    if !valid_name(name) {
        bail!("invalid metric name `{name}`");
    }
    let mut fields = rest.split_whitespace();
    let value_tok =
        fields.next().ok_or_else(|| anyhow!("missing sample value"))?;
    let value = parse_value(value_tok)?;
    // An optional integer timestamp is tolerated; anything else is not.
    if let Some(ts) = fields.next() {
        if ts.parse::<i64>().is_err() || fields.next().is_some() {
            bail!("trailing garbage after sample value");
        }
    }
    let (fam_name, kind) = family
        .ok_or_else(|| anyhow!("sample `{name}` before any # TYPE line"))?;
    let member = if kind == "histogram" {
        name == fam_name
            || name.strip_prefix(fam_name.as_str()).is_some_and(|suffix| {
                matches!(suffix, "_bucket" | "_sum" | "_count")
            })
    } else {
        name == fam_name
    };
    if !member {
        bail!("sample `{name}` does not belong to the preceding # TYPE \
               family `{fam_name}`");
    }
    if kind == "counter" && !(value.is_finite() && value >= 0.0) {
        bail!("counter `{name}` has invalid value {value}");
    }
    Ok(Sample {
        name: name.to_string(),
        family: fam_name.clone(),
        kind: kind.clone(),
        labels,
        value,
    })
}

/// Parse `k="v",...}` (the text after the opening `{`); returns the
/// label pairs and the remainder of the line after the closing `}`.
fn parse_labels(s: &str) -> Result<(Vec<(String, String)>, &str)> {
    let mut labels = Vec::new();
    let b = s.as_bytes();
    let mut i = 0usize;
    loop {
        if i < b.len() && b[i] == b'}' {
            return Ok((labels, &s[i + 1..]));
        }
        let start = i;
        while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
        {
            i += 1;
        }
        if i == start {
            bail!("empty label name");
        }
        let key = s[start..i].to_string();
        if i + 1 >= b.len() || b[i] != b'=' || b[i + 1] != b'"' {
            bail!("label `{key}` is not followed by =\"");
        }
        i += 2;
        let mut val = String::new();
        loop {
            if i >= b.len() {
                bail!("unterminated label value");
            }
            match b[i] {
                b'"' => {
                    i += 1;
                    break;
                }
                b'\\' => {
                    let esc = *b
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("dangling escape"))?;
                    match esc {
                        b'\\' => val.push('\\'),
                        b'"' => val.push('"'),
                        b'n' => val.push('\n'),
                        other => {
                            bail!("unknown escape \\{}", other as char)
                        }
                    }
                    i += 2;
                }
                _ => {
                    let ch = s[i..]
                        .chars()
                        .next()
                        .ok_or_else(|| anyhow!("invalid UTF-8"))?;
                    val.push(ch);
                    i += ch.len_utf8();
                }
            }
        }
        labels.push((key, val));
        if i < b.len() && b[i] == b',' {
            i += 1;
            continue;
        }
        if i < b.len() && b[i] == b'}' {
            return Ok((labels, &s[i + 1..]));
        }
        bail!("expected `,` or `}}` after label value");
    }
}

fn parse_value(tok: &str) -> Result<f64> {
    match tok {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        t => t
            .parse::<f64>()
            .map_err(|_| anyhow!("bad sample value `{t}`")),
    }
}

fn valid_name(name: &str) -> bool {
    let mut cs = name.chars();
    match cs.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    cs.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Value of the unique sample `name` whose labels include every pair
/// in `labels`; 0.0 when absent.
pub fn value(samples: &[Sample], name: &str, labels: &[(&str, &str)]) -> f64 {
    samples
        .iter()
        .find(|s| {
            s.name == name
                && labels.iter().all(|(k, v)| s.label(k) == Some(*v))
        })
        .map_or(0.0, |s| s.value)
}

/// Prometheus-style `histogram_quantile(q, ...)` over the
/// `<family>_bucket` samples matching `labels`: linear interpolation
/// inside the bucket that crosses rank `q`; the highest finite bound
/// when the crossing bucket is `+Inf`; 0.0 with no observations.
pub fn histogram_quantile(
    samples: &[Sample],
    family: &str,
    labels: &[(&str, &str)],
    q: f64,
) -> f64 {
    let bucket_name = format!("{family}_bucket");
    let mut buckets: Vec<(f64, f64)> = samples
        .iter()
        .filter(|s| {
            s.name == bucket_name
                && labels.iter().all(|(k, v)| s.label(k) == Some(*v))
        })
        .filter_map(|s| {
            let le = s.label("le")?;
            let bound = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().ok()?
            };
            Some((bound, s.value))
        })
        .collect();
    buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total = match buckets.last() {
        Some((_, t)) if *t > 0.0 => *t,
        _ => return 0.0,
    };
    let rank = q.clamp(0.0, 1.0) * total;
    let mut prev_bound = 0.0;
    let mut prev_cum = 0.0;
    for (bound, cum) in &buckets {
        if *cum >= rank {
            if bound.is_infinite() {
                return prev_bound;
            }
            let in_bucket = cum - prev_cum;
            if in_bucket <= 0.0 {
                return *bound;
            }
            return prev_bound
                + (bound - prev_bound) * ((rank - prev_cum) / in_bucket);
        }
        prev_bound = *bound;
        prev_cum = *cum;
    }
    prev_bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{Counter, Histogram, Registry};

    #[test]
    fn parses_counters_gauges_and_histograms() {
        let text = "\
# HELP req_total Requests.\n\
# TYPE req_total counter\n\
req_total{shard=\"a\",freq=\"monthly\"} 12\n\
req_total{shard=\"b\",freq=\"monthly\"} 3\n\
# TYPE depth gauge\n\
depth 7\n\
# TYPE lat_seconds histogram\n\
lat_seconds_bucket{le=\"0.1\"} 2\n\
lat_seconds_bucket{le=\"+Inf\"} 3\n\
lat_seconds_sum 0.25\n\
lat_seconds_count 3\n";
        let samples = parse(text).unwrap();
        assert_eq!(samples.len(), 7);
        assert_eq!(value(&samples, "req_total", &[("shard", "a")]), 12.0);
        assert_eq!(value(&samples, "depth", &[]), 7.0);
        let inf = samples
            .iter()
            .find(|s| s.name == "lat_seconds_bucket"
                      && s.label("le") == Some("+Inf"))
            .unwrap();
        assert_eq!(inf.value, 3.0);
        assert_eq!(inf.family, "lat_seconds");
        assert_eq!(inf.kind, "histogram");
        // Escapes round-trip.
        let esc = parse("# TYPE g gauge\ng{k=\"a\\\\b\\\"c\\nd\"} 1\n")
            .unwrap();
        assert_eq!(esc[0].label("k").unwrap(), "a\\b\"c\nd");
    }

    #[test]
    fn rejects_malformed_expositions() {
        // Sample before any # TYPE line.
        assert!(parse("x_total 1\n").is_err());
        // Name outside the declared family.
        assert!(parse("# TYPE a counter\nb_total 1\n").is_err());
        // Negative counter.
        assert!(parse("# TYPE a counter\na -1\n").is_err());
        // Missing value.
        assert!(parse("# TYPE a gauge\na\n").is_err());
        // Unterminated label value.
        assert!(parse("# TYPE a gauge\na{k=\"v} 1\n").is_err());
        // Trailing garbage after the value.
        assert!(parse("# TYPE a gauge\na 1 2 3\n").is_err());
        // Bad metric type.
        assert!(parse("# TYPE a enum\na 1\n").is_err());
        // Histograms accept exactly the three suffixes.
        assert!(parse("# TYPE h histogram\nh_min 1\n").is_err());
        assert!(parse("# TYPE h histogram\nh_count 1\n").is_ok());
    }

    #[test]
    fn registry_render_round_trips_through_parse() {
        let reg = Registry::new();
        let c = Counter::new();
        c.add(5);
        reg.register_counter("rt_total", "T.", &[("shard", "s")], &c);
        let h = Histogram::new();
        h.observe(0.003);
        h.observe(0.2);
        reg.register_histogram("rt_seconds", "L.",
                               &[("shard", "s")], &h);
        let samples = parse(&reg.render()).unwrap();
        assert_eq!(value(&samples, "rt_total", &[("shard", "s")]), 5.0);
        assert_eq!(value(&samples, "rt_seconds_count",
                         &[("shard", "s")]), 2.0);
        let p50 =
            histogram_quantile(&samples, "rt_seconds", &[("shard", "s")],
                               0.5);
        assert!(p50 > 0.0 && p50 <= 0.0032, "p50 = {p50}");
    }

    #[test]
    fn histogram_quantile_interpolates() {
        let text = "\
# TYPE h histogram\n\
h_bucket{le=\"1\"} 0\n\
h_bucket{le=\"2\"} 10\n\
h_bucket{le=\"4\"} 10\n\
h_bucket{le=\"+Inf\"} 10\n";
        let samples = parse(text).unwrap();
        let p50 = histogram_quantile(&samples, "h", &[], 0.5);
        assert!((p50 - 1.5).abs() < 1e-12, "p50 = {p50}");
        // Rank falls in +Inf -> highest finite bound.
        let text = "\
# TYPE h histogram\n\
h_bucket{le=\"1\"} 1\n\
h_bucket{le=\"+Inf\"} 4\n";
        let samples = parse(text).unwrap();
        assert_eq!(histogram_quantile(&samples, "h", &[], 0.99), 1.0);
        // Empty histogram.
        assert_eq!(histogram_quantile(&samples, "nope", &[], 0.5), 0.0);
    }
}
