//! Lightweight telemetry: phase timers and counters for the training loop
//! and forecast service. The §Perf pass reads these to find hot phases.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

/// Accumulates wall-clock per named phase plus call counts.
#[derive(Debug, Default)]
pub struct Telemetry {
    phases: BTreeMap<String, (f64, u64)>, // (total secs, calls)
    counters: BTreeMap<String, u64>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add_time(phase, t.elapsed().as_secs_f64());
        out
    }

    pub fn add_time(&mut self, phase: &str, secs: f64) {
        let e = self.phases.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn incr(&mut self, counter: &str, by: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += by;
    }

    pub fn total_secs(&self, phase: &str) -> f64 {
        self.phases.get(phase).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn calls(&self, phase: &str) -> u64 {
        self.phases.get(phase).map(|e| e.1).unwrap_or(0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Human-readable phase breakdown sorted by total time.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.phases.iter().collect();
        rows.sort_by(|a, b| b.1 .0.partial_cmp(&a.1 .0).unwrap());
        let total: f64 = rows.iter().map(|(_, (s, _))| s).sum();
        let mut out = String::new();
        let _ = writeln!(out, "{:<28} {:>10} {:>8} {:>10} {:>6}",
                         "phase", "total", "calls", "per-call", "share");
        for (name, (secs, calls)) in rows {
            let _ = writeln!(out, "{:<28} {:>9.3}s {:>8} {:>9.2}ms {:>5.1}%",
                             name, secs, calls,
                             1e3 * secs / (*calls).max(1) as f64,
                             100.0 * secs / total.max(1e-12));
        }
        if !self.counters.is_empty() {
            let _ = writeln!(out, "counters:");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "  {k} = {v}");
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_time_and_counts() {
        let mut t = Telemetry::new();
        let x = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        t.time("work", || ());
        assert_eq!(t.calls("work"), 2);
        assert!(t.total_secs("work") >= 0.005);
        t.incr("steps", 3);
        t.incr("steps", 1);
        assert_eq!(t.counter("steps"), 4);
        let rep = t.report();
        assert!(rep.contains("work"));
        assert!(rep.contains("steps = 4"));
    }
}
