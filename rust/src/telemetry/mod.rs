//! Lightweight telemetry: phase timers, counters and latency quantile
//! recorders for the training loop and forecast service. The §Perf pass
//! reads these to find hot phases; the serving stack's `/v1/stats`
//! endpoint reports the quantiles. The [`registry`] submodule adds the
//! lock-cheap counters/gauges/histograms behind `GET /v1/metrics`
//! (Prometheus text exposition), and [`promtext`] parses that format
//! back for the `fast-esrnn top` dashboard and the conformance test.

pub mod promtext;
pub mod registry;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

/// Accumulates wall-clock per named phase plus call counts.
#[derive(Debug, Default)]
pub struct Telemetry {
    phases: BTreeMap<String, (f64, u64)>, // (total secs, calls)
    counters: BTreeMap<String, u64>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add_time(phase, t.elapsed().as_secs_f64());
        out
    }

    pub fn add_time(&mut self, phase: &str, secs: f64) {
        let e = self.phases.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn incr(&mut self, counter: &str, by: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += by;
    }

    pub fn total_secs(&self, phase: &str) -> f64 {
        self.phases.get(phase).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn calls(&self, phase: &str) -> u64 {
        self.phases.get(phase).map(|e| e.1).unwrap_or(0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Human-readable phase breakdown sorted by total time.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.phases.iter().collect();
        // total_cmp: a NaN accumulation (e.g. from a poisoned timer) must
        // not abort the report — same contract as util::bench.
        rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
        let total: f64 = rows.iter().map(|(_, (s, _))| s).sum();
        let mut out = String::new();
        let _ = writeln!(out, "{:<28} {:>10} {:>8} {:>10} {:>6}",
                         "phase", "total", "calls", "per-call", "share");
        for (name, (secs, calls)) in rows {
            let _ = writeln!(out, "{:<28} {:>9.3}s {:>8} {:>9.2}ms {:>5.1}%",
                             name, secs, calls,
                             1e3 * secs / (*calls).max(1) as f64,
                             100.0 * secs / total.max(1e-12));
        }
        if !self.counters.is_empty() {
            let _ = writeln!(out, "counters:");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "  {k} = {v}");
            }
        }
        out
    }
}

/// Computed percentile snapshot of a [`Quantiles`] recorder, in seconds.
/// `count` is the total number of samples ever recorded (the recorder
/// itself keeps at most its ring capacity).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LatencySummary {
    /// Fold another summary into this one for shard aggregation: counts
    /// sum, each percentile takes the worse (larger) shard. Percentiles
    /// cannot be merged exactly without the underlying samples, so the
    /// aggregate is deliberately conservative — an SLO judged on it can
    /// only be stricter than reality, never laxer.
    pub fn absorb_worst(&mut self, other: &LatencySummary) {
        self.count += other.count;
        self.p50 = self.p50.max(other.p50);
        self.p95 = self.p95.max(other.p95);
        self.p99 = self.p99.max(other.p99);
    }
}

/// Bounded-memory latency quantile recorder: keeps the most recent
/// `cap` samples in a ring and computes percentiles over that window.
/// A sliding window (rather than a lossy sketch) is the right trade for
/// serving stats: reloads and load shifts should show up in p99 quickly
/// instead of being averaged into history.
#[derive(Debug, Clone)]
pub struct Quantiles {
    samples: Vec<f64>,
    cap: usize,
    next: usize,
    count: u64,
}

impl Quantiles {
    pub fn new(cap: usize) -> Self {
        Self { samples: Vec::new(), cap: cap.max(1), next: 0, count: 0 }
    }

    pub fn record(&mut self, secs: f64) {
        if self.samples.len() < self.cap {
            self.samples.push(secs);
        } else {
            self.samples[self.next] = secs;
            self.next = (self.next + 1) % self.cap;
        }
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Percentile over the retained window (nearest-rank on the sorted
    /// samples); 0.0 when nothing has been recorded. `total_cmp` keeps a
    /// NaN sample from aborting the stats endpoint.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        rank(&sorted, q)
    }

    /// One clone + one sort for all three ranks — `stats_snapshot` calls
    /// this for three recorders while holding the pool's stats mutex, so
    /// it must not re-sort per percentile.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary { count: self.count, ..Default::default() };
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        LatencySummary {
            count: self.count,
            p50: rank(&sorted, 0.50),
            p95: rank(&sorted, 0.95),
            p99: rank(&sorted, 0.99),
        }
    }
}

impl Default for Quantiles {
    /// 4096-sample window: enough to make p99 meaningful, small enough
    /// that one recorder costs 32 KiB.
    fn default() -> Self {
        Self::new(4096)
    }
}

/// Nearest-rank lookup in an already-sorted sample window.
fn rank(sorted: &[f64], q: f64) -> f64 {
    let pos = (sorted.len() as f64 - 1.0) * q.clamp(0.0, 1.0);
    sorted[pos.round() as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_time_and_counts() {
        let mut t = Telemetry::new();
        let x = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        t.time("work", || ());
        assert_eq!(t.calls("work"), 2);
        assert!(t.total_secs("work") >= 0.005);
        t.incr("steps", 3);
        t.incr("steps", 1);
        assert_eq!(t.counter("steps"), 4);
        let rep = t.report();
        assert!(rep.contains("work"));
        assert!(rep.contains("steps = 4"));
    }

    #[test]
    fn quantiles_basic_percentiles() {
        let mut q = Quantiles::new(1000);
        assert_eq!(q.quantile(0.5), 0.0); // empty → 0
        for i in 1..=100 {
            q.record(i as f64);
        }
        assert_eq!(q.count(), 100);
        let s = q.summary();
        assert!((s.p50 - 50.0).abs() <= 1.0, "p50 {}", s.p50);
        assert!((s.p95 - 95.0).abs() <= 1.0, "p95 {}", s.p95);
        assert!((s.p99 - 99.0).abs() <= 1.0, "p99 {}", s.p99);
        assert_eq!(s.count, 100);
    }

    #[test]
    fn quantiles_ring_keeps_recent_window() {
        let mut q = Quantiles::new(4);
        for v in [100.0, 100.0, 100.0, 100.0, 1.0, 1.0, 1.0, 1.0] {
            q.record(v);
        }
        // The four old 100.0 samples have been overwritten.
        assert_eq!(q.quantile(0.99), 1.0);
        assert_eq!(q.count(), 8);
    }

    #[test]
    fn summary_absorb_takes_worst_percentiles_and_sums_counts() {
        let mut a = LatencySummary { count: 10, p50: 0.002, p95: 0.010,
                                     p99: 0.020 };
        let b = LatencySummary { count: 4, p50: 0.003, p95: 0.008,
                                 p99: 0.050 };
        a.absorb_worst(&b);
        assert_eq!(a.count, 14);
        assert_eq!(a.p50, 0.003);
        assert_eq!(a.p95, 0.010);
        assert_eq!(a.p99, 0.050);
    }

    #[test]
    fn quantiles_survive_nan_samples() {
        let mut q = Quantiles::new(8);
        q.record(1.0);
        q.record(f64::NAN);
        q.record(2.0);
        // Must not panic; NaN sorts last under total_cmp.
        let _ = q.summary();
        assert_eq!(q.quantile(0.0), 1.0);
    }
}
