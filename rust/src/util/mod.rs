//! In-tree substrates: the build environment is offline with no third-party
//! crates beyond `xla`/`anyhow`, so JSON, CLI parsing, RNG, the bench
//! harness and the property-test driver live here (DESIGN.md §Substitutions).

pub mod allocmeter;
pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
