//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate offline, so we carry our own: SplitMix64 for seeding and
//! xoshiro256++ for the stream (both public-domain algorithms). Everything
//! downstream (corpus generation, batch shuffling, property tests) is
//! seeded, so runs are exactly reproducible.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. one per series).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded rejection-free mapping (slight bias is
        // irrelevant at our n ≪ 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forks_are_independent_streams() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
