//! Minimal property-testing driver (no `proptest` offline).
//!
//! `forall(seed, cases, gen, check)` draws `cases` random inputs from `gen`
//! and asserts `check`; on failure it reruns the generator to report the
//! failing case index and seed so the exact input can be reproduced by
//! plugging the printed seed back in.

use crate::util::rng::Rng;

/// Run a property over `cases` generated inputs.
///
/// Panics with the failing case's seed/index on the first violation, so
/// `Rng::new(seed)` + `case_idx` reproduces it deterministically.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for i in 0..cases {
        let mut case_rng = root.fork(i as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed (seed={seed}, case={i}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Common generator: a positive series of length `len` with multiplicative
/// seasonality — the invariant-bearing shape most of our properties need.
pub fn gen_positive_series(rng: &mut Rng, len: usize, period: usize) -> Vec<f32> {
    let base = rng.uniform(10.0, 1000.0);
    let trend = rng.uniform(-0.01, 0.02);
    let amp = rng.uniform(0.0, 0.4);
    let noise = rng.uniform(0.0, 0.1);
    (0..len)
        .map(|t| {
            let seas = if period > 1 {
                1.0 + amp * (2.0 * std::f64::consts::PI * (t % period) as f64
                             / period as f64).sin()
            } else {
                1.0
            };
            let eps = (1.0 + noise * rng.normal()).max(0.05);
            (base * (1.0 + trend).powi(t as i32) * seas * eps).max(1e-3) as f32
        })
        .collect()
}

/// [`gen_positive_series`] with a second planted multiplicative cycle of
/// period `period2` (amplitude 5–20%), so §8.2 dual-seasonality
/// properties have signal on both tracks. `period2 == 0` degrades to the
/// single-cycle generator (and draws nothing extra from `rng`, so
/// single/dual call sites stay reproducible independently).
pub fn gen_positive_series_dual(rng: &mut Rng, len: usize, period: usize,
                                period2: usize) -> Vec<f32> {
    let base = gen_positive_series(rng, len, period);
    if period2 == 0 {
        return base;
    }
    let amp2 = rng.uniform(0.05, 0.2);
    base.iter()
        .enumerate()
        .map(|(t, v)| {
            let w = std::f64::consts::TAU * (t % period2) as f64
                / period2 as f64;
            (*v as f64 * (1.0 + amp2 * w.sin())) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 100, |r| r.uniform(0.0, 1.0), |x| {
            if (0.0..1.0).contains(x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(1, 100, |r| r.below(10), |x| {
            if *x < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn generated_series_is_positive() {
        let mut r = Rng::new(2);
        for _ in 0..50 {
            let s = gen_positive_series(&mut r, 60, 12);
            assert_eq!(s.len(), 60);
            assert!(s.iter().all(|v| *v > 0.0));
        }
    }

    #[test]
    fn dual_series_is_positive_and_degrades_to_single() {
        let mut r = Rng::new(3);
        let s = gen_positive_series_dual(&mut r, 72, 4, 6);
        assert_eq!(s.len(), 72);
        assert!(s.iter().all(|v| *v > 0.0));
        // period2 == 0 reproduces the single-cycle stream exactly.
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let a = gen_positive_series(&mut r1, 40, 7);
        let b = gen_positive_series_dual(&mut r2, 40, 7, 0);
        assert_eq!(a, b);
    }
}
