//! Minimal JSON parser / serializer.
//!
//! The offline build environment vendors no `serde`/`serde_json`, so this
//! in-tree implementation covers the project's needs: the artifact
//! manifest, checkpoints, bench reports and corpus metadata. It implements
//! the full JSON grammar (RFC 8259) minus `\u` surrogate-pair edge cases
//! beyond the BMP, which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order) — handy for golden tests and diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("expected object while looking up `{key}`"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// `[1,2,3]` → `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f32()).collect()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, got `{}`",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected `{}` at byte {}", c as char, self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?);
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // Re-assemble multi-byte UTF-8 (we iterate bytes).
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c)?;
                        if start + len > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        out.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("d").unwrap().as_bool().unwrap(), true);
        // serialize → reparse → equal
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert_eq!(Json::parse("-1.5").unwrap().as_f64().unwrap(), -1.5);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
    }

    #[test]
    fn nested_and_unicode() {
        let v = Json::parse(r#"{"k": {"inner": ["héllo", "A"]}}"#).unwrap();
        let arr = v.get("k").unwrap().get("inner").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str().unwrap(), "héllo");
        assert_eq!(arr[1].as_str().unwrap(), "A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn deterministic_output() {
        let v = Json::obj(vec![("z", Json::num(1.0)), ("a", Json::num(2.0))]);
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
