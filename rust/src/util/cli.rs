//! Tiny declarative CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with generated `--help` text. Only what the `fast-esrnn`
//! binary and the bench harnesses need.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Declared option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative parser for one (sub)command.
#[derive(Debug, Default)]
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parse result: option values + positionals.
#[derive(Debug)]
pub struct Args {
    values: HashMap<&'static str, String>,
    flags: HashMap<&'static str, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str,
               help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{head:28}{}{def}\n", o.help));
        }
        s
    }

    /// Parse a raw arg list (without argv[0] / the subcommand word).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut values: HashMap<&'static str, String> = HashMap::new();
        let mut flags: HashMap<&'static str, bool> = HashMap::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name, false);
            } else if let Some(d) = o.default {
                values.insert(o.name, d.to_string());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option `--{key}`\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("flag `--{key}` takes no value");
                    }
                    flags.insert(spec.name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("`--{key}` needs a value"))?
                        }
                    };
                    values.insert(spec.name, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(o.name) {
                bail!("missing required option `--{}`\n\n{}", o.name, self.usage());
            }
        }
        Ok(Args { values, flags, positional })
    }
}

impl Args {
    pub fn get(&self, name: &'static str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option `{name}` was never declared"))
    }

    pub fn get_flag(&self, name: &'static str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag `{name}` was never declared"))
    }

    pub fn get_usize(&self, name: &'static str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: not an integer: {e}"))
    }

    pub fn get_f32(&self, name: &'static str) -> Result<f32> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: not a number: {e}"))
    }

    pub fn get_f64(&self, name: &'static str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: not a number: {e}"))
    }

    pub fn get_u64(&self, name: &'static str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: not an integer: {e}"))
    }

    /// Comma-separated list, e.g. `--batch-sizes 1,16,64`.
    pub fn get_usize_list(&self, name: &'static str) -> Result<Vec<usize>> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse()
                 .map_err(|e| anyhow!("--{name}: bad entry `{s}`: {e}")))
            .collect()
    }

    pub fn get_str_list(&self, name: &'static str) -> Vec<String> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "test command")
            .opt("epochs", "15", "number of epochs")
            .opt("freqs", "yearly,monthly", "frequencies")
            .flag("verbose", "chatty output")
            .req("out", "output path")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&s(&["--out", "x.json"])).unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 15);
        assert!(!a.get_flag("verbose"));
        let a = cli()
            .parse(&s(&["--epochs=3", "--verbose", "--out", "x", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 3);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn lists() {
        let a = cli().parse(&s(&["--out", "x", "--freqs", "a, b,c"])).unwrap();
        assert_eq!(a.get_str_list("freqs"), vec!["a", "b", "c"]);
    }

    #[test]
    fn errors() {
        assert!(cli().parse(&s(&[])).is_err()); // missing --out
        assert!(cli().parse(&s(&["--out", "x", "--nope"])).is_err());
        assert!(cli().parse(&s(&["--out"])).is_err()); // dangling value
    }
}
