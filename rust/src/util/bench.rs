//! Micro/macro benchmark harness (no `criterion` offline).
//!
//! Measures wall-clock over a warmup + N timed iterations, reports
//! min/median/mean/p95 and throughput. Used by every `benches/` target and
//! by the §Perf profiling pass.

use std::time::Instant;

/// Summary statistics for one benchmark case, all in seconds.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub total: f64,
}

impl BenchStats {
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        // total_cmp: a NaN sample (e.g. a clock anomaly or a bad run
        // being measured) sorts last and shows up in the report instead
        // of aborting the whole bench gate.
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let total: f64 = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
        Self {
            name: name.to_string(),
            iters: n,
            min: samples[0],
            median: pct(0.5),
            mean: total / n as f64,
            p95: pct(0.95),
            total,
        }
    }

    /// One formatted row: `name  median  mean  p95  [unit/s]`.
    pub fn row(&self, per_iter_items: f64) -> String {
        let thr = if per_iter_items > 0.0 {
            format!("{:>12.1} items/s", per_iter_items / self.median)
        } else {
            String::new()
        };
        format!("{:<44} {:>10} {:>10} {:>10} {thr}",
                self.name,
                fmt_secs(self.median),
                fmt_secs(self.mean),
                fmt_secs(self.p95))
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(name, samples)
}

/// Time a single long-running closure (for end-to-end cases where one
/// iteration is already seconds long).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Pretty table header matching `BenchStats::row`.
pub fn header() -> String {
    format!("{:<44} {:>10} {:>10} {:>10}", "case", "median", "mean", "p95")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let st = BenchStats::from_samples("x", vec![3.0, 1.0, 2.0]);
        assert_eq!(st.min, 1.0);
        assert_eq!(st.median, 2.0);
        assert!((st.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let st = bench("inc", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(st.iters, 5);
    }

    #[test]
    fn nan_samples_report_instead_of_panicking() {
        let st = BenchStats::from_samples("nan", vec![1.0, f64::NAN, 2.0]);
        assert_eq!(st.min, 1.0);
        // NaN sorts last under total_cmp, so p95 lands on it — the
        // report shows the anomaly rather than the harness aborting.
        assert!(st.p95.is_nan());
    }

    #[test]
    fn formats() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-5).ends_with("µs"));
        assert!(fmt_secs(2e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
