//! Counting global allocator for the zero-allocation steady-state gate.
//!
//! [`CountingAlloc`] wraps the system allocator and bumps two relaxed
//! atomic counters on every allocation. It is *not* installed in the
//! library — production binaries keep the plain system allocator and pay
//! nothing. Test and bench binaries that need to measure allocations per
//! step install it themselves:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: fast_esrnn::util::allocmeter::CountingAlloc =
//!     fast_esrnn::util::allocmeter::CountingAlloc::new();
//! ```
//!
//! `rust/tests/steady_state.rs` and `benches/micro_hotpath.rs` do exactly
//! this; the BENCH_6 gate then asserts that a warm lanes-mode
//! `train_step` moves [`allocations`] by zero. Deallocations are not
//! counted — the gate is about *new* heap traffic, and a free-only path
//! would still indicate a buffer being dropped that should have been
//! pooled (it would show up as a matching allocation on the next step).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Process-wide allocation count since start (0 unless a binary installed
/// [`CountingAlloc`] as its `#[global_allocator]`).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Process-wide bytes requested since start (same caveat as
/// [`allocations`]).
pub fn allocated_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// System-allocator wrapper that counts allocations. Zero overhead unless
/// a binary opts in via `#[global_allocator]`.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: every method forwards to `System` with its arguments unchanged,
// so the `GlobalAlloc` contract — layout fidelity across
// alloc/realloc/dealloc, no unwinding, valid-or-null returns — is
// inherited wholesale from the system allocator. The only added behavior
// is two relaxed atomic counter bumps, which touch no allocator state and
// have no effect on the returned memory; the type itself is a stateless
// unit struct, so concurrent use as `#[global_allocator]` from any number
// of threads adds no synchronization hazards beyond `System`'s own.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: forwarded verbatim — our caller's obligations (non-zero
        // `layout` size) are exactly `System.alloc`'s.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: forwarded verbatim; same contract as `alloc` above.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        // A realloc that grows is exactly the churn the steady-state gate
        // exists to catch; count it like a fresh allocation.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        // SAFETY: forwarded verbatim — `ptr`/`layout` pairing and the
        // non-zero `new_size` requirement are the caller's obligations,
        // passed through unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: forwarded verbatim; `ptr` was produced by this allocator
        // (i.e. by `System`) with this `layout`, per the caller's contract.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test rather than one per method: the counters are
    // process-global, so splitting these asserts across parallel test
    // threads would race. This is also the Miri target for the allocator
    // wrapper (`cargo miri test --lib allocmeter`).
    #[test]
    fn counts_alloc_realloc_and_zeroing() {
        let a = CountingAlloc::new();
        let layout = Layout::from_size_align(64, 8).unwrap();
        let grown = Layout::from_size_align(128, 8).unwrap();
        let before = (allocations(), allocated_bytes());
        // SAFETY: both layouts are non-zero-sized; each pointer is used
        // only with the layout it was (re)allocated with and freed exactly
        // once; writes stay inside the 64 bytes just allocated.
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            p.write_bytes(0xAB, layout.size());
            let q = a.realloc(p, layout, grown.size());
            assert!(!q.is_null());
            a.dealloc(q, grown);
            let z = a.alloc_zeroed(layout);
            assert!(!z.is_null());
            for off in 0..layout.size() {
                assert_eq!(*z.add(off), 0, "alloc_zeroed must zero");
            }
            a.dealloc(z, layout);
        }
        let after = (allocations(), allocated_bytes());
        // alloc + realloc + alloc_zeroed; deallocs are deliberately not
        // counted (see module docs).
        assert_eq!(after.0 - before.0, 3);
        assert_eq!(after.1 - before.1, 64 + 128 + 64);
    }
}
