//! Counting global allocator for the zero-allocation steady-state gate.
//!
//! [`CountingAlloc`] wraps the system allocator and bumps two relaxed
//! atomic counters on every allocation. It is *not* installed in the
//! library — production binaries keep the plain system allocator and pay
//! nothing. Test and bench binaries that need to measure allocations per
//! step install it themselves:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: fast_esrnn::util::allocmeter::CountingAlloc =
//!     fast_esrnn::util::allocmeter::CountingAlloc::new();
//! ```
//!
//! `rust/tests/steady_state.rs` and `benches/micro_hotpath.rs` do exactly
//! this; the BENCH_6 gate then asserts that a warm lanes-mode
//! `train_step` moves [`allocations`] by zero. Deallocations are not
//! counted — the gate is about *new* heap traffic, and a free-only path
//! would still indicate a buffer being dropped that should have been
//! pooled (it would show up as a matching allocation on the next step).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Process-wide allocation count since start (0 unless a binary installed
/// [`CountingAlloc`] as its `#[global_allocator]`).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Process-wide bytes requested since start (same caveat as
/// [`allocations`]).
pub fn allocated_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// System-allocator wrapper that counts allocations. Zero overhead unless
/// a binary opts in via `#[global_allocator]`.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: defers every contract-bearing operation to `System`; the
// counter updates have no effect on the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        // A realloc that grows is exactly the churn the steady-state gate
        // exists to catch; count it like a fresh allocation.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
