//! Fixed-width f32 lane abstraction for the batch-vectorized native
//! kernels (the paper's §5 vectorization step, done in portable Rust).
//!
//! [`Lanes`] is an 8-wide `[f32; 8]` newtype with elementwise arithmetic,
//! written so stable rustc auto-vectorizes every operation (fixed-length
//! array loops, no data-dependent branches). The native backend processes
//! series in lane *groups* of [`LANES`]: structure-of-arrays buffers hold
//! one value per series per lane slot, and every step of the ES-RNN
//! forward/backward executes once per group instead of once per series.
//! Porting to `std::simd` (or a wgpu subgroup) later is a type swap, not
//! a kernel rewrite.
//!
//! Transcendentals (`exp`, `ln`, `tanh`, `sigmoid`) are branch-free
//! polynomial approximations rather than libm calls — libm is scalar and
//! dominates the LSTM gate cost otherwise. Accuracy (validated against
//! f64 references over the kernels' input ranges):
//!
//! * `exp`  — ≤ 3e-7 relative on [-87, 88] (clamped outside, no inf/NaN);
//! * `ln`   — ≤ 2e-7 relative for |ln x| ≥ 1, ≤ 2e-6 absolute overall;
//! * `tanh` — ≤ 3e-7 absolute, exact ±1 saturation;
//! * `sigmoid` — ≤ 3e-7 absolute.
//!
//! The scalar compute core ([`crate::runtime::native::model`]) keeps
//! using libm and serves as the oracle the lane kernels are
//! property-tested against (`rust/tests/simd_parity.rs`).

/// Lane width of the batch kernels. 8 × f32 = one AVX2 register (two
/// SSE/NEON registers); wide enough to saturate typical CPU FMA units,
/// small enough that ragged batch tails waste little work.
pub const LANES: usize = 8;

const EXP_CLAMP_LO: f32 = -87.0;
const EXP_CLAMP_HI: f32 = 88.0;
const LOG2E: f32 = 1.442_695_f32;
/// ln(2) split hi/lo so `x - n*ln2` stays accurate near the break points.
const LN2_HI: f32 = 0.693_359_375_f32;
const LN2_LO: f32 = -2.121_944_4e-4_f32;
const SQRT_HALF: f32 = 0.707_106_78_f32;

/// Branch-free f32 exp: 2^n · P(r) with n = round(x·log2 e), r = x − n·ln 2,
/// P the degree-6 Taylor polynomial of e^r on |r| ≤ ln2/2, and the 2^n
/// scale built directly in the exponent bits. Inputs are clamped to
/// [-87, 88], so the result is always finite and positive.
#[inline]
fn exp_f32(x: f32) -> f32 {
    let x = x.clamp(EXP_CLAMP_LO, EXP_CLAMP_HI);
    let n = (x * LOG2E + 0.5).floor();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let mut p = 1.0 / 720.0;
    for c in [1.0 / 120.0, 1.0 / 24.0, 1.0 / 6.0, 0.5, 1.0, 1.0] {
        p = p * r + c;
    }
    let bits = (((n as i32) + 127) << 23) as u32;
    p * f32::from_bits(bits)
}

/// Branch-free f32 ln for positive normal inputs: decompose x = m·2^e
/// with m ∈ [√½, √2) via exponent-bit surgery, then
/// ln m = 2·atanh(t), t = (m−1)/(m+1), by a 5-term odd series.
/// Non-positive or denormal inputs are undefined (the kernels clamp to
/// EPS = 1e-8 > f32::MIN_POSITIVE first).
#[inline]
fn ln_f32(x: f32) -> f32 {
    let bits = x.to_bits();
    let mut e = (((bits >> 23) & 0xff) as i32 - 126) as f32;
    let mut m = f32::from_bits((bits & 0x007f_ffff) | 0x3f00_0000);
    if m < SQRT_HALF {
        m *= 2.0;
        e -= 1.0;
    }
    let t = (m - 1.0) / (m + 1.0);
    let t2 = t * t;
    let mut p = 1.0 / 9.0;
    for c in [1.0 / 7.0, 1.0 / 5.0, 1.0 / 3.0, 1.0] {
        p = p * t2 + c;
    }
    let lnm = 2.0 * t * p;
    e * LN2_HI + (lnm + e * LN2_LO)
}

/// An 8-wide bundle of f32 values: one per series in a lane group.
///
/// All arithmetic is elementwise. The type is `Copy` and all operations
/// take `self` by value so the compiler keeps lanes in registers.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(transparent)]
pub struct Lanes(pub [f32; LANES]);

impl Lanes {
    pub const ZERO: Lanes = Lanes([0.0; LANES]);
    pub const ONE: Lanes = Lanes([1.0; LANES]);

    /// Broadcast one scalar to every lane.
    #[inline]
    pub fn splat(v: f32) -> Lanes {
        Lanes([v; LANES])
    }

    /// Load the first [`LANES`] elements of `s` (panics if shorter).
    #[inline]
    pub fn load(s: &[f32]) -> Lanes {
        Lanes(s[..LANES].try_into().expect("lane load"))
    }

    /// Store into the first [`LANES`] elements of `out`.
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    /// Elementwise map (kept for one-off lane math; hot paths use the
    /// dedicated methods below so the polynomial kernels inline).
    #[inline]
    pub fn map(self, f: impl Fn(f32) -> f32) -> Lanes {
        let mut out = self.0;
        for v in &mut out {
            *v = f(*v);
        }
        Lanes(out)
    }

    /// Horizontal sum over the lanes (fixed lane order 0..LANES, so the
    /// result is deterministic and thread-count independent).
    #[inline]
    pub fn sum(self) -> f32 {
        let mut acc = 0.0f32;
        for v in self.0 {
            acc += v;
        }
        acc
    }

    #[inline]
    pub fn max(self, o: Lanes) -> Lanes {
        let mut out = self.0;
        for (v, w) in out.iter_mut().zip(o.0) {
            *v = v.max(w);
        }
        Lanes(out)
    }

    #[inline]
    pub fn sqrt(self) -> Lanes {
        self.map(f32::sqrt)
    }

    /// Fast elementwise exp (≤ 3e-7 relative; clamped to [-87, 88]).
    #[inline]
    pub fn exp(self) -> Lanes {
        self.map(exp_f32)
    }

    /// Fast elementwise ln for positive normal inputs.
    #[inline]
    pub fn ln(self) -> Lanes {
        self.map(ln_f32)
    }

    /// Fast elementwise tanh via exp(2x): (e−1)/(e+1) with e = e^{2x};
    /// saturates to exactly ±1 for |x| ≳ 13.
    #[inline]
    pub fn tanh(self) -> Lanes {
        self.map(|x| {
            let e = exp_f32(2.0 * x);
            (e - 1.0) / (e + 1.0)
        })
    }

    /// Fast elementwise logistic sigmoid 1/(1 + e^{−x}).
    #[inline]
    pub fn sigmoid(self) -> Lanes {
        self.map(|x| 1.0 / (1.0 + exp_f32(-x)))
    }

    /// Per-lane select: `if self[l] >= 0 { if_ge[l] } else { if_lt[l] }`.
    #[inline]
    pub fn select_ge_zero(self, if_ge: Lanes, if_lt: Lanes) -> Lanes {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = if self.0[l] >= 0.0 { if_ge.0[l] } else { if_lt.0[l] };
        }
        Lanes(out)
    }

    /// 1.0 where `self > thresh`, else 0.0 — the gate convention the
    /// kernels use instead of bool masks (gradient gating by multiply).
    #[inline]
    pub fn gt_gate(self, thresh: Lanes) -> Lanes {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = if self.0[l] > thresh.0[l] { 1.0 } else { 0.0 };
        }
        Lanes(out)
    }
}

macro_rules! lane_binop {
    ($trait:ident, $method:ident, $op:tt) => {
        impl std::ops::$trait for Lanes {
            type Output = Lanes;
            #[inline]
            fn $method(self, rhs: Lanes) -> Lanes {
                let mut out = self.0;
                for (v, w) in out.iter_mut().zip(rhs.0) {
                    *v = *v $op w;
                }
                Lanes(out)
            }
        }
    };
}

lane_binop!(Add, add, +);
lane_binop!(Sub, sub, -);
lane_binop!(Mul, mul, *);
lane_binop!(Div, div, /);

impl std::ops::Neg for Lanes {
    type Output = Lanes;
    #[inline]
    fn neg(self) -> Lanes {
        let mut out = self.0;
        for v in &mut out {
            *v = -*v;
        }
        Lanes(out)
    }
}

impl std::ops::AddAssign for Lanes {
    #[inline]
    fn add_assign(&mut self, rhs: Lanes) {
        for (v, w) in self.0.iter_mut().zip(rhs.0) {
            *v += w;
        }
    }
}

impl std::ops::SubAssign for Lanes {
    #[inline]
    fn sub_assign(&mut self, rhs: Lanes) {
        for (v, w) in self.0.iter_mut().zip(rhs.0) {
            *v -= w;
        }
    }
}

/// `dst[i] += src[i]` over two equal-length SoA slices — the elementwise
/// accumulation the kernels use for residual adds and gradient merges
/// (plain indexed f32 loop: contiguous, auto-vectorizes).
#[inline]
pub fn add_assign_slice(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
        assert!((a - b).abs() <= tol, "{what}: {a} vs {b} (tol {tol})");
    }

    #[test]
    fn arithmetic_is_elementwise() {
        let a = Lanes([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = Lanes::splat(2.0);
        assert_eq!((a + b).0[3], 6.0);
        assert_eq!((a - b).0[0], -1.0);
        assert_eq!((a * b).0[7], 16.0);
        assert_eq!((a / b).0[1], 1.0);
        assert_eq!((-a).0[2], -3.0);
        let mut c = a;
        c += b;
        assert_eq!(c.0[5], 8.0);
        c -= b;
        assert_eq!(c.0, a.0);
        assert_eq!(a.sum(), 36.0);
        assert_eq!(a.max(Lanes::splat(4.5)).0[2], 4.5);
        assert_eq!(a.max(Lanes::splat(4.5)).0[6], 7.0);
    }

    #[test]
    fn load_store_roundtrip() {
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let v = Lanes::load(&src[2..]);
        assert_eq!(v.0[0], 2.0);
        assert_eq!(v.0[7], 9.0);
        let mut dst = vec![0.0f32; 10];
        v.store(&mut dst[1..]);
        assert_eq!(dst[1], 2.0);
        assert_eq!(dst[8], 9.0);
        assert_eq!(dst[9], 0.0);
    }

    #[test]
    fn exp_matches_libm_within_3e7_relative() {
        let mut x = -20.0f32;
        while x <= 20.0 {
            let got = Lanes::splat(x).exp().0[0];
            let want = x.exp();
            assert!((got - want).abs() <= 5e-7 * want,
                    "exp({x}): {got} vs {want}");
            x += 0.003;
        }
        // Clamp region: finite, positive, monotone-ish extremes.
        let lo = Lanes::splat(-1000.0).exp().0[0];
        let hi = Lanes::splat(1000.0).exp().0[0];
        assert!(lo > 0.0 && lo < 1e-37);
        assert!(hi.is_finite() && hi > 1e38);
        assert_eq!(Lanes::splat(0.0).exp().0[0], 1.0);
    }

    #[test]
    fn ln_matches_libm() {
        let mut u = 1e-8f64;
        while u < 1e8 {
            let uf = u as f32;
            let got = Lanes::splat(uf).ln().0[0];
            let want = (uf as f64).ln();
            let tol = 2e-7 * want.abs().max(1.0);
            assert!((got as f64 - want).abs() <= tol,
                    "ln({uf}): {got} vs {want}");
            u *= 1.37;
        }
        // Near 1 (normalized window ratios live here).
        let mut v = 0.5f32;
        while v < 2.0 {
            let got = Lanes::splat(v).ln().0[0];
            let want = (v as f64).ln();
            assert!((got as f64 - want).abs() <= 2e-7,
                    "ln({v}): {got} vs {want}");
            v += 0.001;
        }
        assert_eq!(Lanes::splat(1.0).ln().0[0], 0.0);
    }

    #[test]
    fn tanh_sigmoid_match_libm_and_saturate() {
        let mut x = -30.0f32;
        while x <= 30.0 {
            let t = Lanes::splat(x).tanh().0[0];
            let s = Lanes::splat(x).sigmoid().0[0];
            assert_close(t, x.tanh(), 3e-7, "tanh");
            assert_close(s, 1.0 / (1.0 + (-x).exp()), 3e-7, "sigmoid");
            x += 0.007;
        }
        assert_eq!(Lanes::splat(100.0).tanh().0[0], 1.0);
        assert_eq!(Lanes::splat(-100.0).tanh().0[0], -1.0);
        assert_eq!(Lanes::splat(0.0).tanh().0[0], 0.0);
        assert_eq!(Lanes::splat(200.0).sigmoid().0[0], 1.0);
        assert!(Lanes::splat(-200.0).sigmoid().0[0] >= 0.0);
    }

    #[test]
    fn select_and_gate() {
        let d = Lanes([-1.0, 0.0, 2.0, -0.5, 3.0, -4.0, 5.0, 0.0]);
        let s = d.select_ge_zero(Lanes::splat(10.0), Lanes::splat(-10.0));
        assert_eq!(s.0, [-10.0, 10.0, 10.0, -10.0, 10.0, -10.0, 10.0, 10.0]);
        let g = d.gt_gate(Lanes::ZERO);
        assert_eq!(g.0, [0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn add_assign_slice_accumulates() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        add_assign_slice(&mut a, &[0.5, 0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5, 3.5]);
    }
}
