//! # Fast ES-RNN
//!
//! A production-grade reproduction of *"Fast ES-RNN: A GPU Implementation of
//! the ES-RNN Algorithm"* (Redd, Khin & Marini, 2019): the M4-winning hybrid
//! of per-series Holt-Winters exponential smoothing and a shared
//! dilated-residual LSTM, vectorized so the per-series parameters become
//! batch-dimension tensor slices.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L1** — Pallas kernels (batched ES recurrence, fused LSTM cell,
//!   pinball loss), compiled into
//! * **L2** — the JAX ES-RNN compute graph, AOT-lowered to HLO text, loaded
//!   and executed by
//! * **L3** — this crate: dataset pipeline, per-series parameter store,
//!   batch scheduler, training driver, evaluation, classical baselines,
//!   forecast service and CLI.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod forecast;
pub mod hw;
pub mod metrics;
pub mod runtime;
pub mod telemetry;
pub mod util;
