//! # Fast ES-RNN
//!
//! A production-grade reproduction of *"Fast ES-RNN: A GPU Implementation of
//! the ES-RNN Algorithm"* (Redd, Khin & Marini, 2019): the M4-winning hybrid
//! of per-series Holt-Winters exponential smoothing and a shared
//! dilated-residual LSTM, vectorized so the per-series parameters become
//! batch-dimension tensor slices.
//!
//! Architecture (three layers; Python never on the request path — and with
//! the default backend, never anywhere):
//! * **L1** — kernels implementing the batched ES recurrence, fused LSTM
//!   cell and pinball loss: either Pallas (compiled into the AOT
//!   artifacts) or the pure-Rust mirrors in [`runtime::native::model`];
//! * **L2** — the ES-RNN compute graph: the AOT-lowered JAX/HLO programs
//!   (`--features pjrt`) or the native Rust graph, both served behind the
//!   [`runtime::Backend`] trait under identical manifest contracts;
//! * **L3** — this crate: dataset pipeline, per-series parameter store,
//!   batch scheduler, training driver, evaluation, classical baselines,
//!   the serving stack (per-frequency worker pools, generation-tagged
//!   model hot-swap, HTTP front-end) and CLI — all backend-agnostic.
//!
//! See `DESIGN.md` for the full system inventory, the `Backend` trait
//! contract and the tensor naming scheme; `ROADMAP.md` tracks open items.

// Every `unsafe` operation inside an `unsafe fn` needs its own block (and
// per DESIGN.md §"Static analysis" its own `// SAFETY:` comment — rule R4
// of fesrnn-lint, plus clippy's `undocumented_unsafe_blocks` in CI).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod forecast;
pub mod hw;
pub mod metrics;
pub mod runtime;
pub mod simd;
pub mod telemetry;
pub mod util;
