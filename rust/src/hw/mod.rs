//! Classical Holt-Winters machinery on the Rust side.
//!
//! Two jobs:
//! 1. **Primer** (paper §3.3): before joint training starts, each series
//!    gets classical estimates of its initial seasonality indices (ratio-
//!    to-moving-average decomposition) and starting smoothing coefficients.
//!    These seed the per-series parameter store; joint training then tunes
//!    them by gradient descent.
//! 2. **Filter**: a pure-Rust mirror of the L1 Pallas recurrence
//!    (`es_smoothing`), used by property tests to cross-check the artifact
//!    numerics and by the classical baselines.

use crate::simd::{Lanes, LANES};
use crate::util::rng::Rng;

/// Inverse sigmoid.
pub fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Default starting smoothing coefficients (tuned mild; training moves
/// them per series).
pub const INIT_ALPHA: f32 = 0.30;
pub const INIT_GAMMA: f32 = 0.10;

/// Per-series primer output: what the coordinator writes into the store.
#[derive(Debug, Clone)]
pub struct Primer {
    pub alpha_logit: f32,
    pub gamma_logit: f32,
    /// §8.2 second smoothing coefficient (unused when seasonality2 = 0).
    pub gamma2_logit: f32,
    /// log of the initial seasonality indices: `[S1]`, or `[S1 | S2]`
    /// packed back-to-back for dual-seasonality configs.
    pub log_s_init: Vec<f32>,
}

/// Ratio-to-moving-average seasonal decomposition (multiplicative).
///
/// Returns `period` seasonality indices normalized to mean 1. For
/// `period == 1` (non-seasonal) returns `[1.0]`.
pub fn seasonal_indices(y: &[f32], period: usize) -> Vec<f32> {
    if period <= 1 || y.len() < 2 * period {
        return vec![1.0; period.max(1)];
    }
    // Centered moving average of window `period`.
    let n = y.len();
    let half = period / 2;
    let mut ratios: Vec<Vec<f64>> = vec![Vec::new(); period];
    for t in half..n - half {
        let (lo, hi) = (t - half, t + half);
        // Centered MA: plain window for odd periods, 2×S (half-weighted
        // endpoints) for even periods — the standard decomposition MA.
        let ma: f64 = if period % 2 == 0 {
            let mid: f64 = y[lo + 1..hi].iter().map(|v| *v as f64).sum();
            (0.5 * y[lo] as f64 + mid + 0.5 * y[hi] as f64) / period as f64
        } else {
            y[lo..=hi].iter().map(|v| *v as f64).sum::<f64>()
                / (hi - lo + 1) as f64
        };
        if ma > 0.0 {
            ratios[t % period].push(y[t] as f64 / ma);
        }
    }
    let mut idx: Vec<f64> = ratios
        .iter()
        .map(|r| {
            if r.is_empty() {
                1.0
            } else {
                r.iter().sum::<f64>() / r.len() as f64
            }
        })
        .collect();
    // Normalize to mean 1 (multiplicative convention).
    let mean = idx.iter().sum::<f64>() / period as f64;
    if mean > 0.0 {
        for v in &mut idx {
            *v /= mean;
        }
    }
    idx.iter().map(|v| (*v as f32).clamp(0.05, 20.0)).collect()
}

/// Build the primer for one series (paper §3.3 "primer estimate").
pub fn primer(y: &[f32], period: usize) -> Primer {
    let s = seasonal_indices(y, period);
    Primer {
        alpha_logit: logit(INIT_ALPHA),
        gamma_logit: logit(INIT_GAMMA),
        gamma2_logit: logit(INIT_GAMMA),
        log_s_init: s.iter().map(|v| v.max(1e-6).ln()).collect(),
    }
}

/// §8.2 dual-seasonality primer: decompose the primary cycle first, then
/// the secondary cycle on the residual (Gould et al. 2008 ordering).
pub fn primer_dual(y: &[f32], s1: usize, s2: usize) -> Primer {
    let idx1 = seasonal_indices(y, s1);
    let residual: Vec<f32> = y
        .iter()
        .enumerate()
        .map(|(t, v)| v / idx1[t % s1].max(1e-6))
        .collect();
    let idx2 = seasonal_indices(&residual, s2);
    let mut log_s = Vec::with_capacity(s1 + s2);
    log_s.extend(idx1.iter().map(|v| v.max(1e-6).ln()));
    log_s.extend(idx2.iter().map(|v| v.max(1e-6).ln()));
    Primer {
        alpha_logit: logit(INIT_ALPHA),
        gamma_logit: logit(INIT_GAMMA),
        gamma2_logit: logit(INIT_GAMMA),
        log_s_init: log_s,
    }
}

/// Primer dispatch on the network config shape.
pub fn primer_for(y: &[f32], s1: usize, s2: usize) -> Primer {
    if s2 > 0 {
        primer_dual(y, s1, s2)
    } else {
        primer(y, s1)
    }
}

/// Optionally jitter a primer (symmetry breaking across identical series).
///
/// Routes through [`primer_for`] so §8.2 dual configs (`s2 > 0`) get the
/// full packed `[S1 | S2]` seasonality block (a plain [`primer`] call
/// would emit a length-S1 block that the store's width check rejects),
/// and jitters `gamma2_logit` alongside the other smoothing coefficients.
pub fn primer_jittered(y: &[f32], s1: usize, s2: usize, rng: &mut Rng)
                       -> Primer {
    let mut p = primer_for(y, s1, s2);
    p.alpha_logit += rng.normal_scaled(0.0, 0.05) as f32;
    p.gamma_logit += rng.normal_scaled(0.0, 0.05) as f32;
    if s2 > 0 {
        p.gamma2_logit += rng.normal_scaled(0.0, 0.05) as f32;
    }
    p
}

/// Pure-Rust mirror of the dual-seasonality recurrence (`es_dual`),
/// §8.2. Returns (levels, seas1 [C+S1], seas2 [C+S2]).
pub fn es_dual_filter(y: &[f32], alpha: f32, gamma1: f32, gamma2: f32,
                      s1_init: &[f32], s2_init: &[f32])
                      -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut levels, mut seas1, mut seas2) = (Vec::new(), Vec::new(), Vec::new());
    es_dual_filter_into(y, alpha, gamma1, gamma2, s1_init, s2_init,
                        &mut levels, &mut seas1, &mut seas2);
    (levels, seas1, seas2)
}

/// [`es_dual_filter`] writing into caller-owned buffers (cleared and
/// refilled) so a steady-state hot path can reuse its arenas.
#[allow(clippy::too_many_arguments)]
pub fn es_dual_filter_into(y: &[f32], alpha: f32, gamma1: f32, gamma2: f32,
                           s1_init: &[f32], s2_init: &[f32],
                           levels: &mut Vec<f32>, seas1: &mut Vec<f32>,
                           seas2: &mut Vec<f32>) {
    let c = y.len();
    let (s1, s2) = (s1_init.len(), s2_init.len());
    seas1.clear();
    seas1.reserve(c + s1);
    seas1.extend_from_slice(s1_init);
    seas2.clear();
    seas2.reserve(c + s2);
    seas2.extend_from_slice(s2_init);
    levels.clear();
    levels.reserve(c);
    let mut l_prev = 0.0f32;
    for t in 0..c {
        let s1_t = seas1[t];
        let s2_t = seas2[t];
        let denom = s1_t * s2_t;
        let l_t = if t == 0 {
            y[0] / denom
        } else {
            alpha * y[t] / denom + (1.0 - alpha) * l_prev
        };
        seas1.push(gamma1 * y[t] / (l_t * s2_t) + (1.0 - gamma1) * s1_t);
        seas2.push(gamma2 * y[t] / (l_t * s1_t) + (1.0 - gamma2) * s2_t);
        levels.push(l_t);
        l_prev = l_t;
    }
}

/// Lane-vectorized mirror of [`es_filter`]: one recurrence step updates
/// [`LANES`] series at once.
///
/// Structure-of-arrays layout: `y` is `[C][LANES]` (`y[t*LANES + l]` is
/// series `l` at time `t`), `s_init` is `[S][LANES]`; returns
/// (levels `[C][LANES]`, seas `[(C+S)][LANES]`). `alpha`/`gamma` carry
/// one smoothing coefficient per lane. The per-lane arithmetic sequence
/// is identical to the scalar filter, so each lane matches [`es_filter`]
/// on that series to f32 rounding.
pub fn es_filter_lanes(y: &[f32], c: usize, alpha: Lanes, gamma: Lanes,
                       s_init: &[f32], s: usize) -> (Vec<f32>, Vec<f32>) {
    let (mut levels, mut seas) = (Vec::new(), Vec::new());
    es_filter_lanes_into(y, c, alpha, gamma, s_init, s, &mut levels,
                         &mut seas);
    (levels, seas)
}

/// [`es_filter_lanes`] writing into caller-owned buffers (resized and
/// fully overwritten) for the steady-state arena path.
#[allow(clippy::too_many_arguments)]
pub fn es_filter_lanes_into(y: &[f32], c: usize, alpha: Lanes, gamma: Lanes,
                            s_init: &[f32], s: usize, levels: &mut Vec<f32>,
                            seas: &mut Vec<f32>) {
    debug_assert_eq!(y.len(), c * LANES);
    debug_assert_eq!(s_init.len(), s * LANES);
    let one = Lanes::ONE;
    // Every element is stored by the recurrence below, so a plain resize
    // (no re-zeroing) is safe on reuse.
    seas.resize((c + s) * LANES, 0.0);
    seas[..s * LANES].copy_from_slice(s_init);
    levels.resize(c * LANES, 0.0);
    let mut l_prev = Lanes::ZERO;
    for t in 0..c {
        let y_t = Lanes::load(&y[t * LANES..]);
        let s_t = Lanes::load(&seas[t * LANES..]);
        let l_t = if t == 0 {
            y_t / s_t
        } else {
            alpha * y_t / s_t + (one - alpha) * l_prev
        };
        let s_next = gamma * y_t / l_t + (one - gamma) * s_t;
        s_next.store(&mut seas[(t + s) * LANES..]);
        l_t.store(&mut levels[t * LANES..]);
        l_prev = l_t;
    }
}

/// Lane-vectorized mirror of [`es_dual_filter`] (§8.2 coupled 24h×168h
/// recurrence), same SoA conventions as [`es_filter_lanes`]. Returns
/// (levels `[C][LANES]`, seas1 `[(C+S1)][LANES]`, seas2 `[(C+S2)][LANES]`).
pub fn es_dual_filter_lanes(y: &[f32], c: usize, alpha: Lanes, gamma1: Lanes,
                            gamma2: Lanes, s1_init: &[f32], s1: usize,
                            s2_init: &[f32], s2: usize)
                            -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut levels, mut seas1, mut seas2) = (Vec::new(), Vec::new(), Vec::new());
    es_dual_filter_lanes_into(y, c, alpha, gamma1, gamma2, s1_init, s1,
                              s2_init, s2, &mut levels, &mut seas1,
                              &mut seas2);
    (levels, seas1, seas2)
}

/// [`es_dual_filter_lanes`] writing into caller-owned buffers (resized
/// and fully overwritten) for the steady-state arena path.
#[allow(clippy::too_many_arguments)]
pub fn es_dual_filter_lanes_into(y: &[f32], c: usize, alpha: Lanes,
                                 gamma1: Lanes, gamma2: Lanes,
                                 s1_init: &[f32], s1: usize, s2_init: &[f32],
                                 s2: usize, levels: &mut Vec<f32>,
                                 seas1: &mut Vec<f32>, seas2: &mut Vec<f32>) {
    debug_assert_eq!(y.len(), c * LANES);
    debug_assert_eq!(s1_init.len(), s1 * LANES);
    debug_assert_eq!(s2_init.len(), s2 * LANES);
    let one = Lanes::ONE;
    seas1.resize((c + s1) * LANES, 0.0);
    seas1[..s1 * LANES].copy_from_slice(s1_init);
    seas2.resize((c + s2) * LANES, 0.0);
    seas2[..s2 * LANES].copy_from_slice(s2_init);
    levels.resize(c * LANES, 0.0);
    let mut l_prev = Lanes::ZERO;
    for t in 0..c {
        let y_t = Lanes::load(&y[t * LANES..]);
        let s1_t = Lanes::load(&seas1[t * LANES..]);
        let s2_t = Lanes::load(&seas2[t * LANES..]);
        let denom = s1_t * s2_t;
        let l_t = if t == 0 {
            y_t / denom
        } else {
            alpha * y_t / denom + (one - alpha) * l_prev
        };
        (gamma1 * y_t / (l_t * s2_t) + (one - gamma1) * s1_t)
            .store(&mut seas1[(t + s1) * LANES..]);
        (gamma2 * y_t / (l_t * s1_t) + (one - gamma2) * s2_t)
            .store(&mut seas2[(t + s2) * LANES..]);
        l_t.store(&mut levels[t * LANES..]);
        l_prev = l_t;
    }
}

/// Output of the ES filter (mirror of the Pallas kernel contract).
#[derive(Debug, Clone)]
pub struct EsOutput {
    /// l_t for t = 0..C-1.
    pub levels: Vec<f32>,
    /// s_t for t = 0..C+S-1 (first S = initial indices).
    pub seas: Vec<f32>,
}

/// Pure-Rust mirror of the L1 `es_smoothing` recurrence (Eqs. 1, 3 with
/// the trend term removed). Must stay in lock-step with
/// `python/compile/kernels/ref.py::es_smoothing_ref` — the integration
/// tests compare artifact output against this.
pub fn es_filter(y: &[f32], alpha: f32, gamma: f32, s_init: &[f32]) -> EsOutput {
    let (mut levels, mut seas) = (Vec::new(), Vec::new());
    es_filter_into(y, alpha, gamma, s_init, &mut levels, &mut seas);
    EsOutput { levels, seas }
}

/// [`es_filter`] writing into caller-owned buffers (cleared and refilled)
/// for the steady-state arena path.
pub fn es_filter_into(y: &[f32], alpha: f32, gamma: f32, s_init: &[f32],
                      levels: &mut Vec<f32>, seas: &mut Vec<f32>) {
    let c = y.len();
    let s_len = s_init.len().max(1);
    seas.clear();
    seas.reserve(c + s_len);
    seas.extend_from_slice(s_init);
    levels.clear();
    levels.reserve(c);
    let mut l_prev = 0.0f32;
    for t in 0..c {
        let s_t = seas[t];
        let l_t = if t == 0 {
            y[0] / s_t
        } else {
            alpha * y[t] / s_t + (1.0 - alpha) * l_prev
        };
        let s_next = gamma * y[t] / l_t + (1.0 - gamma) * s_t;
        seas.push(s_next);
        levels.push(l_t);
        l_prev = l_t;
    }
}

/// Holt-Winters point forecast from filter state (Eq. 4 with b ≡ 1, i.e.
/// the ES-RNN pre-processing's own h-step forecast — used as a baseline
/// sanity check and in tests).
pub fn es_forecast(out: &EsOutput, period: usize, horizon: usize) -> Vec<f32> {
    let c = out.levels.len();
    let l = out.levels[c - 1];
    let s_len = period.max(1);
    (0..horizon)
        .map(|h| {
            let idx = c + (h % s_len);
            l * out.seas.get(idx).copied().unwrap_or(1.0)
        })
        .collect()
}

/// Live per-series ES state for the stateful serving path (online
/// observe → forecast without retraining).
///
/// The seasonal state is held as a *phase ring*: `ring1[t % S1]` is the
/// most recent seasonal value for phase `t % S1`. Because the batch
/// recurrence reads `seas[t]` and writes `seas[t + S]` — the same phase
/// slot — advancing the ring in place replays **exactly** the f32
/// operation sequence of [`es_filter`] / [`es_dual_filter`], so an
/// incremental advance from stored state is bit-identical to filtering
/// the full extended history with the same seed.
#[derive(Debug, Clone, PartialEq)]
pub struct EsState {
    /// Most recent smoothed level `l_t`.
    pub level: f32,
    /// Primary seasonal ring, length S1 (`[1.0]` for non-seasonal).
    pub ring1: Vec<f32>,
    /// Secondary seasonal ring, length S2; empty for single-seasonality.
    pub ring2: Vec<f32>,
    /// Number of observations consumed so far (the next time index).
    pub observed: u64,
}

impl EsState {
    /// Advance the recurrence over `y`, starting at time `self.observed`.
    ///
    /// Mirrors the `t > 0` branch of [`es_filter_into`] (single) or
    /// [`es_dual_filter_into`] (dual, when `ring2` is non-empty) exactly;
    /// the `t == 0` branch fires only on a freshly seeded state.
    pub fn advance(&mut self, y: &[f32], alpha: f32, gamma1: f32,
                   gamma2: f32) {
        let s1 = self.ring1.len().max(1) as u64;
        if self.ring2.is_empty() {
            for (i, &y_t) in y.iter().enumerate() {
                let t = self.observed + i as u64;
                let p1 = (t % s1) as usize;
                let s_t = self.ring1[p1];
                let l_t = if t == 0 {
                    y_t / s_t
                } else {
                    alpha * y_t / s_t + (1.0 - alpha) * self.level
                };
                self.ring1[p1] = gamma1 * y_t / l_t + (1.0 - gamma1) * s_t;
                self.level = l_t;
            }
        } else {
            let s2 = self.ring2.len() as u64;
            for (i, &y_t) in y.iter().enumerate() {
                let t = self.observed + i as u64;
                let p1 = (t % s1) as usize;
                let p2 = (t % s2) as usize;
                let s1_t = self.ring1[p1];
                let s2_t = self.ring2[p2];
                let denom = s1_t * s2_t;
                let l_t = if t == 0 {
                    y_t / denom
                } else {
                    alpha * y_t / denom + (1.0 - alpha) * self.level
                };
                self.ring1[p1] =
                    gamma1 * y_t / (l_t * s2_t) + (1.0 - gamma1) * s1_t;
                self.ring2[p2] =
                    gamma2 * y_t / (l_t * s1_t) + (1.0 - gamma2) * s2_t;
                self.level = l_t;
            }
        }
        self.observed += y.len() as u64;
    }

    /// Holt-Winters h-step forecast from the live state.
    ///
    /// For horizon step `h` the applicable phase is `(observed + h) % S`,
    /// which is the same seasonal value [`es_forecast`] reads at
    /// `seas[c + h % S]` — so a state advanced over history `y` forecasts
    /// bit-identically to `es_forecast(&es_filter(y, ..), ..)`.
    pub fn forecast(&self, horizon: usize) -> Vec<f32> {
        let s1 = self.ring1.len().max(1) as u64;
        (0..horizon as u64)
            .map(|h| {
                let t = self.observed + h;
                let mut v = self.level * self.ring1[(t % s1) as usize];
                if !self.ring2.is_empty() {
                    v *= self.ring2[(t % self.ring2.len() as u64) as usize];
                }
                v
            })
            .collect()
    }
}

/// Seed a fresh [`EsState`] from a series' first observation batch.
///
/// The seasonal rings come from the same ratio-to-moving-average
/// decomposition as [`primer_for`] (dual configs decompose the primary
/// cycle first, then the residual), but are used directly — no log-space
/// round trip — so the seeded state, the forecast-from-extended-history
/// oracle, and the lanes cross-check all share one derivation. The
/// smoothing coefficients are the serving-path constants
/// ([`INIT_ALPHA`], [`INIT_GAMMA`]); training refines per-series
/// coefficients, the observe path deliberately does not.
pub fn es_state_seed(y: &[f32], s1: usize, s2: usize) -> EsState {
    let s1 = s1.max(1);
    let (ring1, ring2) = if s2 > 0 {
        let idx1 = seasonal_indices(y, s1);
        let residual: Vec<f32> = y
            .iter()
            .enumerate()
            .map(|(t, v)| v / idx1[t % s1].max(1e-6))
            .collect();
        (idx1, seasonal_indices(&residual, s2))
    } else {
        (seasonal_indices(y, s1), Vec::new())
    };
    let mut st = EsState { level: 0.0, ring1, ring2, observed: 0 };
    st.advance(y, INIT_ALPHA, INIT_GAMMA, INIT_GAMMA);
    st
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logit_sigmoid_roundtrip() {
        for p in [0.1f32, 0.3, 0.5, 0.9] {
            assert!((sigmoid(logit(p)) - p).abs() < 1e-5);
        }
    }

    #[test]
    fn seasonal_indices_recover_planted_pattern() {
        // y_t = 100 * s_{t%4}, s = [0.8, 1.1, 1.2, 0.9]
        let s_true = [0.8f32, 1.1, 1.2, 0.9];
        let y: Vec<f32> = (0..48).map(|t| 100.0 * s_true[t % 4]).collect();
        let idx = seasonal_indices(&y, 4);
        for (est, truth) in idx.iter().zip(&s_true) {
            assert!((est - truth).abs() < 0.02, "est {est} vs {truth}");
        }
        // mean-1 normalization
        let mean: f32 = idx.iter().sum::<f32>() / 4.0;
        assert!((mean - 1.0).abs() < 1e-3);
    }

    #[test]
    fn seasonal_indices_nonseasonal_is_ones() {
        let y = vec![5.0f32; 30];
        assert_eq!(seasonal_indices(&y, 1), vec![1.0]);
        // Too-short series also degrade gracefully.
        assert_eq!(seasonal_indices(&y[..5], 12), vec![1.0; 12]);
    }

    #[test]
    fn es_filter_constant_series_is_flat() {
        let y = vec![10.0f32; 20];
        let out = es_filter(&y, 0.3, 0.1, &[1.0]);
        for l in &out.levels {
            assert!((l - 10.0).abs() < 1e-4);
        }
        for s in &out.seas {
            assert!((s - 1.0).abs() < 1e-4);
        }
        let fc = es_forecast(&out, 1, 4);
        assert!(fc.iter().all(|v| (v - 10.0).abs() < 1e-3));
    }

    #[test]
    fn es_filter_tracks_level_shift() {
        let mut y = vec![10.0f32; 10];
        y.extend(vec![20.0f32; 30]);
        let out = es_filter(&y, 0.5, 0.0, &[1.0]);
        assert!((out.levels.last().unwrap() - 20.0).abs() < 0.1);
    }

    #[test]
    fn primer_matches_decomposition() {
        let s_true = [0.8f32, 1.2];
        let y: Vec<f32> = (0..40).map(|t| 50.0 * s_true[t % 2]).collect();
        let p = primer(&y, 2);
        assert_eq!(p.log_s_init.len(), 2);
        assert!((p.log_s_init[0].exp() - 0.8).abs() < 0.05);
        assert!((sigmoid(p.alpha_logit) - INIT_ALPHA).abs() < 1e-5);
    }

    #[test]
    fn es_dual_filter_constant_series_is_flat() {
        let y = vec![25.0f32; 60];
        let (levels, s1, s2) =
            es_dual_filter(&y, 0.3, 0.1, 0.05, &[1.0; 4], &[1.0; 6]);
        for l in &levels {
            assert!((l - 25.0).abs() < 1e-3, "level {l}");
        }
        for v in s1.iter().chain(s2.iter()) {
            assert!((v - 1.0).abs() < 1e-3, "seasonality {v}");
        }
    }

    #[test]
    fn es_dual_filter_recovers_planted_dual_cycles() {
        // Two planted multiplicative cycles (24×168-style structure, kept
        // tiny): filtering with the true inits keeps both tracks pinned.
        let s1_true = [0.8f32, 1.0, 1.2, 1.0];
        let s2_true = [0.9f32, 1.05, 1.1, 1.05, 0.95, 0.95];
        let y: Vec<f32> = (0..120)
            .map(|t| 200.0 * s1_true[t % 4] * s2_true[t % 6])
            .collect();
        let (levels, e1, e2) =
            es_dual_filter(&y, 0.2, 0.2, 0.2, &s1_true, &s2_true);
        let c = y.len();
        for l in &levels {
            assert!((l - 200.0).abs() < 2.0, "level {l} drifted from 200");
        }
        // Final seasonal states stay near the planted patterns (up to the
        // usual multiplicative scale ambiguity — compare adjacent ratios;
        // e_i[c + k] is the state for absolute time c + k, phase
        // (c + k) % S_i).
        for k in 0..3 {
            let got = e1[c + k] / e1[c + k + 1];
            let want = s1_true[(c + k) % 4] / s1_true[(c + k + 1) % 4];
            assert!((got / want - 1.0).abs() < 0.05,
                    "s1 ratio {k}: {got} vs {want}");
        }
        for k in 0..5 {
            let got = e2[c + k] / e2[c + k + 1];
            let want = s2_true[(c + k) % 6] / s2_true[(c + k + 1) % 6];
            assert!((got / want - 1.0).abs() < 0.05,
                    "s2 ratio {k}: {got} vs {want}");
        }
    }

    #[test]
    fn es_dual_filter_degenerates_to_single() {
        // gamma2 = 0 and s2_init ≡ 1 pins the second track at 1, so the
        // dual recurrence must equal the single filter exactly.
        let s_init = [0.7f32, 1.3];
        let y: Vec<f32> = (0..50)
            .map(|t| (80.0 + t as f32) * s_init[t % 2])
            .collect();
        let single = es_filter(&y, 0.3, 0.2, &s_init);
        let (lv, e1, e2) = es_dual_filter(&y, 0.3, 0.2, 0.0, &s_init, &[1.0]);
        for t in 0..y.len() {
            assert!((lv[t] - single.levels[t]).abs()
                    <= 1e-5 * single.levels[t].abs(),
                    "level[{t}]: {} vs {}", lv[t], single.levels[t]);
        }
        for t in 0..e1.len() {
            assert!((e1[t] - single.seas[t]).abs() <= 1e-5,
                    "seas[{t}]: {} vs {}", e1[t], single.seas[t]);
        }
        assert!(e2.iter().all(|v| (v - 1.0).abs() < 1e-7));
    }

    #[test]
    fn primer_jittered_dual_has_packed_width_and_jittered_gamma2() {
        let y: Vec<f32> = (0..80).map(|t| 50.0 + (t % 4) as f32).collect();
        let mut rng = Rng::new(7);
        let p = primer_jittered(&y, 4, 6, &mut rng);
        assert_eq!(p.log_s_init.len(), 10, "dual primer must pack [S1|S2]");
        assert!((p.gamma2_logit - logit(INIT_GAMMA)).abs() > 1e-6,
                "gamma2_logit must be jittered for dual configs");
        // Single configs keep the S1-only block and leave gamma2 at the
        // default (nothing reads it).
        let mut rng = Rng::new(7);
        let q = primer_jittered(&y, 4, 0, &mut rng);
        assert_eq!(q.log_s_init.len(), 4);
        assert_eq!(q.gamma2_logit, logit(INIT_GAMMA));
    }

    /// Transpose `n` per-series rows (each length `c`) into `[c][LANES]`
    /// SoA, padding missing lanes with 1.0 — test-local marshalling.
    fn to_soa(rows: &[Vec<f32>], c: usize) -> Vec<f32> {
        let mut soa = vec![1.0f32; c * LANES];
        for (l, row) in rows.iter().enumerate() {
            for t in 0..c {
                soa[t * LANES + l] = row[t];
            }
        }
        soa
    }

    #[test]
    fn es_filter_lanes_matches_scalar_per_lane() {
        let mut rng = Rng::new(31);
        let s = 4usize;
        let c = 40usize;
        let mut ys = Vec::new();
        let mut inits = Vec::new();
        let mut alpha = [0.0f32; LANES];
        let mut gamma = [0.0f32; LANES];
        for l in 0..LANES {
            ys.push((0..c)
                .map(|t| {
                    (50.0 + t as f32)
                        * (1.0 + 0.2 * ((t % s) as f32 - 1.5))
                        * rng.uniform(0.9, 1.1) as f32
                })
                .collect::<Vec<f32>>());
            inits.push((0..s)
                .map(|_| rng.uniform(0.7, 1.4) as f32)
                .collect::<Vec<f32>>());
            alpha[l] = rng.uniform(0.05, 0.9) as f32;
            gamma[l] = rng.uniform(0.0, 0.5) as f32;
        }
        let y_soa = to_soa(&ys, c);
        let s_soa = to_soa(&inits, s);
        let (levels, seas) = es_filter_lanes(&y_soa, c, Lanes(alpha),
                                             Lanes(gamma), &s_soa, s);
        for l in 0..LANES {
            let want = es_filter(&ys[l], alpha[l], gamma[l], &inits[l]);
            for t in 0..c {
                let got = levels[t * LANES + l];
                assert!((got - want.levels[t]).abs()
                        <= 1e-5 * want.levels[t].abs().max(1.0),
                        "lane {l} level[{t}]: {got} vs {}", want.levels[t]);
            }
            for t in 0..c + s {
                let got = seas[t * LANES + l];
                assert!((got - want.seas[t]).abs() <= 1e-5,
                        "lane {l} seas[{t}]: {got} vs {}", want.seas[t]);
            }
        }
    }

    #[test]
    fn es_dual_filter_lanes_matches_scalar_per_lane() {
        let mut rng = Rng::new(37);
        let (s1, s2) = (3usize, 5usize);
        let c = 45usize;
        let mut ys = Vec::new();
        let mut i1 = Vec::new();
        let mut i2 = Vec::new();
        let mut alpha = [0.0f32; LANES];
        let mut g1 = [0.0f32; LANES];
        let mut g2 = [0.0f32; LANES];
        for l in 0..LANES {
            ys.push((0..c)
                .map(|t| {
                    200.0
                        * (1.0 + 0.15 * ((t % s1) as f32 - 1.0))
                        * (1.0 + 0.1 * ((t % s2) as f32 - 2.0))
                        * rng.uniform(0.95, 1.05) as f32
                })
                .collect::<Vec<f32>>());
            i1.push((0..s1)
                .map(|_| rng.uniform(0.8, 1.2) as f32)
                .collect::<Vec<f32>>());
            i2.push((0..s2)
                .map(|_| rng.uniform(0.8, 1.2) as f32)
                .collect::<Vec<f32>>());
            alpha[l] = rng.uniform(0.05, 0.9) as f32;
            g1[l] = rng.uniform(0.0, 0.5) as f32;
            g2[l] = rng.uniform(0.0, 0.5) as f32;
        }
        let y_soa = to_soa(&ys, c);
        let s1_soa = to_soa(&i1, s1);
        let s2_soa = to_soa(&i2, s2);
        let (levels, e1, e2) = es_dual_filter_lanes(
            &y_soa, c, Lanes(alpha), Lanes(g1), Lanes(g2), &s1_soa, s1,
            &s2_soa, s2);
        for l in 0..LANES {
            let (wl, w1, w2) = es_dual_filter(&ys[l], alpha[l], g1[l],
                                              g2[l], &i1[l], &i2[l]);
            for t in 0..c {
                let got = levels[t * LANES + l];
                assert!((got - wl[t]).abs() <= 1e-5 * wl[t].abs().max(1.0),
                        "lane {l} level[{t}]: {got} vs {}", wl[t]);
            }
            for t in 0..c + s1 {
                let got = e1[t * LANES + l];
                assert!((got - w1[t]).abs() <= 1e-5,
                        "lane {l} seas1[{t}]: {got} vs {}", w1[t]);
            }
            for t in 0..c + s2 {
                let got = e2[t * LANES + l];
                assert!((got - w2[t]).abs() <= 1e-5,
                        "lane {l} seas2[{t}]: {got} vs {}", w2[t]);
            }
        }
    }

    #[test]
    fn es_filter_seasonal_recovery() {
        // Planted multiplicative seasonality; filter with the true s_init
        // keeps seasonality stable.
        let s_true = [0.7f32, 1.3];
        let y: Vec<f32> = (0..60).map(|t| 100.0 * s_true[t % 2]).collect();
        let out = es_filter(&y, 0.2, 0.2, &s_true);
        let c = y.len();
        // final seasonal states stay near truth
        assert!((out.seas[c] / out.seas[c + 1] - 0.7 / 1.3).abs() < 0.05);
        let fc = es_forecast(&out, 2, 4);
        assert!((fc[0] / fc[1] - 0.7 / 1.3).abs() < 0.05);
    }

    fn demo_series(n: usize, s1: usize, s2: usize) -> Vec<f32> {
        let mut rng = Rng::new(0x5eed);
        (0..n)
            .map(|t| {
                200.0
                    * (1.0 + 0.2 * ((t % s1.max(1)) as f32 - 1.0))
                    * (1.0 + if s2 > 0 {
                        0.1 * ((t % s2) as f32 - 2.0) / s2 as f32
                    } else {
                        0.0
                    })
                    * rng.uniform(0.95, 1.05) as f32
            })
            .collect()
    }

    #[test]
    fn es_state_advance_is_bit_identical_to_batch_filter() {
        let s = 12;
        let y = demo_series(90, s, 0);
        let (first, rest) = y.split_at(40);
        let mut st = es_state_seed(first, s, 0);
        // Feed the remainder in uneven chunks.
        for chunk in rest.chunks(7) {
            st.advance(chunk, INIT_ALPHA, INIT_GAMMA, INIT_GAMMA);
        }
        // Oracle: one batch filter over the full history with the seed
        // rings from the FIRST batch (the seeding contract).
        let s_init = seasonal_indices(first, s);
        let out = es_filter(&y, INIT_ALPHA, INIT_GAMMA, &s_init);
        let c = y.len();
        assert_eq!(st.level, out.levels[c - 1]);
        for p in 0..s {
            // ring[p] holds the most recent seasonal value for phase p,
            // which the batch filter leaves at seas[c + ((p + s - c % s) % s)].
            let j = (p + s - c % s) % s;
            assert_eq!(st.ring1[p], out.seas[c + j], "phase {p}");
        }
        assert_eq!(st.forecast(6), es_forecast(&out, s, 6));
    }

    #[test]
    fn es_state_dual_advance_matches_batch_dual_filter() {
        let (s1, s2) = (24, 168);
        let y = demo_series(400, s1, s2);
        let (first, rest) = y.split_at(336);
        let mut st = es_state_seed(first, s1, s2);
        st.advance(rest, INIT_ALPHA, INIT_GAMMA, INIT_GAMMA);
        // Oracle: re-derive the seed rings exactly as es_state_seed does,
        // then batch-filter the whole history.
        let idx1 = seasonal_indices(first, s1);
        let residual: Vec<f32> = first
            .iter()
            .enumerate()
            .map(|(t, v)| v / idx1[t % s1].max(1e-6))
            .collect();
        let idx2 = seasonal_indices(&residual, s2);
        let (levels, e1, e2) =
            es_dual_filter(&y, INIT_ALPHA, INIT_GAMMA, INIT_GAMMA, &idx1,
                           &idx2);
        let c = y.len();
        assert_eq!(st.level, levels[c - 1]);
        for p in 0..s1 {
            let j = (p + s1 - c % s1) % s1;
            assert_eq!(st.ring1[p], e1[c + j], "ring1 phase {p}");
        }
        for p in 0..s2 {
            let j = (p + s2 - c % s2) % s2;
            assert_eq!(st.ring2[p], e2[c + j], "ring2 phase {p}");
        }
        // Forecast oracle straight off the batch filter tails.
        let h = 48;
        let fc = st.forecast(h);
        for (i, got) in fc.iter().enumerate() {
            let want = levels[c - 1]
                * e1[c + i % s1]
                * e2[c + i % s2];
            assert_eq!(*got, want, "h={i}");
        }
    }

    #[test]
    fn es_state_seed_handles_short_and_flat_series() {
        // Too short for decomposition: rings fall back to 1.0 and the
        // level tracks the smoothed series.
        let st = es_state_seed(&[5.0, 5.0, 5.0], 12, 0);
        assert_eq!(st.observed, 3);
        assert!((st.level - 5.0).abs() < 1e-3);
        assert!(st.forecast(4).iter().all(|v| (v - 5.0).abs() < 1e-2));
        // Non-seasonal config (s1 = 1) keeps a single-slot ring.
        let st = es_state_seed(&[10.0, 12.0, 11.0, 13.0], 1, 0);
        assert_eq!(st.ring1.len(), 1);
    }
}
