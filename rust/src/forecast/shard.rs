//! Horizontal sharding: a [`ShardedStack`] owns N independent
//! [`ServingStack`] shards and routes every request by a consistent hash
//! of its series id.
//!
//! Why consistent hashing (a point ring with virtual nodes) instead of
//! `hash(id) % N`:
//!
//! * **stable assignment** — a series id maps to the same shard on every
//!   process restart and regardless of shard insertion order (the ring
//!   is a sorted set of hash points, not a history);
//! * **bounded movement** — adding or removing one shard moves only the
//!   keys adjacent to that shard's points, ≈1/N of the keyspace, and
//!   adding a shard moves keys *only onto the new shard* (never between
//!   survivors). `%-N` would reshuffle almost everything, defeating any
//!   per-shard warm state (and, once shards are remote, any cache).
//!
//! Shard lifecycle: [`add_shard`](ShardedStack::add_shard) splices a
//! running stack into the ring; [`remove_shard`](ShardedStack::remove_shard)
//! is the drain protocol — it atomically stops routing to the shard and
//! hands the caller the `Arc`, whose final drop shuts the shard's pools
//! down *after* their queues drain (`FreqPool` drains before its workers
//! exit), so removal never drops an accepted request.
//!
//! Today every shard lives in-process; the ring + drain protocol are the
//! routing layer a cross-machine deployment reuses unchanged (a remote
//! shard is a `ServingStack` behind a TCP transport — see ROADMAP).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::config::Frequency;
use crate::coordinator::{checkpoint, ModelState};
use crate::telemetry::registry::Registry;

use super::router::ServingStack;
use super::{ForecastRequest, ForecastResponse, ResponseReceiver,
            ServiceStats};

/// Virtual nodes per shard. More vnodes → smoother key distribution and
/// closer-to-1/N movement on membership change, at the cost of a larger
/// (still tiny) ring. 64 keeps the max/min shard load ratio near 1.3
/// for realistic shard counts.
const VNODES: usize = 64;

/// FNV-1a 64-bit with a MurmurHash3 `fmix64` avalanche finalizer —
/// tiny, dependency-free, and stable across platforms and releases
/// (unlike `DefaultHasher`, whose output may change between Rust
/// versions — assignment stability across restarts is the point).
///
/// The finalizer matters: ring placement orders raw 64-bit values, so
/// it is dominated by the *high* bits, and plain FNV-1a of short,
/// similar keys (`series-0`, `series-1`, …) clusters badly up there —
/// measured on 10k sequential ids over 4 shards, one shard owned 65%
/// of the keyspace. `fmix64` scatters every input bit across the word
/// (its whole design goal), bringing the same measurement to a
/// 23–28% per-shard spread.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    fmix64(h)
}

/// MurmurHash3's 64-bit finalizer: full avalanche (every input bit
/// flips each output bit with ~1/2 probability).
fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// A consistent-hash ring: each shard label contributes [`VNODES`]
/// points; a key routes to the first point clockwise from its own hash.
/// Pure data structure (no pools) so routing properties are unit-testable
/// without starting servers.
#[derive(Debug, Default, Clone)]
pub struct HashRing {
    /// Sorted by (point, label); the label tie-break makes point
    /// collisions deterministic.
    points: Vec<(u64, String)>,
}

impl HashRing {
    pub fn new() -> Self {
        Self::default()
    }

    fn point(label: &str, vnode: usize) -> u64 {
        fnv1a64(format!("{label}#{vnode}").as_bytes())
    }

    /// Add a shard's points. Errors if the label is already present.
    pub fn insert(&mut self, label: &str) -> Result<()> {
        if self.contains(label) {
            bail!("shard `{label}` is already on the ring");
        }
        for v in 0..VNODES {
            self.points.push((Self::point(label, v), label.to_string()));
        }
        self.points.sort();
        Ok(())
    }

    /// Remove a shard's points. Errors if the label is absent.
    pub fn remove(&mut self, label: &str) -> Result<()> {
        if !self.contains(label) {
            bail!("shard `{label}` is not on the ring");
        }
        self.points.retain(|(_, l)| l != label);
        Ok(())
    }

    pub fn contains(&self, label: &str) -> bool {
        self.points.iter().any(|(_, l)| l == label)
    }

    /// Number of shards (not points) on the ring.
    pub fn len(&self) -> usize {
        self.labels().len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Shard labels, sorted.
    pub fn labels(&self) -> Vec<String> {
        let mut ls: Vec<String> =
            self.points.iter().map(|(_, l)| l.clone()).collect();
        ls.sort();
        ls.dedup();
        ls
    }

    /// The shard owning `key`: the first point at or clockwise after
    /// `hash(key)`, wrapping to the ring's first point. `None` on an
    /// empty ring.
    pub fn route(&self, key: &str) -> Option<&str> {
        if self.points.is_empty() {
            return None;
        }
        let h = fnv1a64(key.as_bytes());
        let i = self.points.partition_point(|(p, _)| *p < h);
        let (_, label) = &self.points[i % self.points.len()];
        Some(label)
    }
}

struct Shards {
    ring: HashRing,
    stacks: BTreeMap<String, Arc<ServingStack>>,
}

/// N [`ServingStack`] shards behind a consistent-hash router. All
/// methods take `&self` (membership sits under one `RwLock`; request
/// dispatch takes the read side only, so routing scales with shards).
///
/// The router also owns the ring's metrics [`Registry`]: every shard's
/// pool instruments are bound into it (under `{shard, freq}` labels)
/// as the shard joins and unbound as it leaves, so `GET /v1/metrics`
/// always reflects the current membership.
pub struct ShardedStack {
    // lint:lock-name(shard.inner)
    inner: RwLock<Shards>,
    registry: Arc<Registry>,
}

impl Default for ShardedStack {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedStack {
    /// An empty router: [`add_shard`](Self::add_shard) before serving.
    pub fn new() -> Self {
        Self {
            inner: RwLock::new(Shards {
                ring: HashRing::new(),
                stacks: BTreeMap::new(),
            }),
            registry: Arc::new(Registry::new()),
        }
    }

    /// The metrics registry every shard's pool instruments are bound
    /// into; the HTTP front-end renders it at `GET /v1/metrics` and
    /// binds its own connection metrics here too.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Wrap one existing stack as a single-shard router (what the
    /// single-stack [`HttpServer::start`](super::http::HttpServer::start)
    /// entrypoint uses).
    pub fn single(stack: Arc<ServingStack>) -> Result<Self> {
        let sharded = Self::new();
        sharded.add_shard_arc("shard-0", stack)?;
        Ok(sharded)
    }

    /// Splice a running stack into the ring under `label`. New requests
    /// whose keys land on the new shard's points route there from the
    /// moment this returns; no key between surviving shards moves.
    pub fn add_shard(&self, label: &str, stack: ServingStack) -> Result<()> {
        self.add_shard_arc(label, Arc::new(stack))
    }

    /// [`add_shard`](Self::add_shard) for a stack the caller also holds.
    pub fn add_shard_arc(&self, label: &str, stack: Arc<ServingStack>)
                         -> Result<()> {
        if stack.is_empty() {
            bail!("shard `{label}` has no running pools");
        }
        {
            let mut inner = self.inner.write().unwrap();
            if let Some(first) = inner.stacks.values().next() {
                if first.frequencies() != stack.frequencies() {
                    bail!("shard `{label}` serves {:?} but the ring \
                           serves {:?} — every shard must serve the same \
                           frequencies",
                          stack.frequencies(), first.frequencies());
                }
            }
            inner.ring.insert(label)?;
            inner.stacks.insert(label.to_string(), Arc::clone(&stack));
        }
        // Bind after the membership lock is released: registration takes
        // the registry's own mutex, and no path may hold both locks.
        stack.bind_metrics(&self.registry, label);
        Ok(())
    }

    /// Drain protocol, step 1+2 in one atomic move: stop routing to
    /// `label` and return its stack. The shard keeps serving whatever it
    /// already accepted; when the caller drops the returned `Arc` (and
    /// in-flight requests release theirs), the pools shut down and
    /// *drain their queues before the workers exit* — an accepted
    /// request is never dropped by a removal.
    pub fn remove_shard(&self, label: &str) -> Result<Arc<ServingStack>> {
        let removed = {
            let mut inner = self.inner.write().unwrap();
            if inner.stacks.len() == 1 && inner.stacks.contains_key(label) {
                bail!("cannot remove `{label}` — it is the last shard");
            }
            inner.ring.remove(label)?;
            inner
                .stacks
                .remove(label)
                .ok_or_else(|| anyhow!("shard `{label}` not found"))?
        };
        // The departed shard's series leave the exposition with it
        // (unbind outside the membership lock, mirroring add_shard_arc).
        self.registry.unregister("shard", label);
        Ok(removed)
    }

    pub fn shard_count(&self) -> usize {
        self.inner.read().unwrap().stacks.len()
    }

    /// Shard labels, sorted.
    pub fn shard_labels(&self) -> Vec<String> {
        self.inner.read().unwrap().stacks.keys().cloned().collect()
    }

    /// Which shard `key` (a series id) routes to — exposed so operators
    /// and tests can audit placement.
    pub fn shard_for(&self, key: &str) -> Result<String> {
        let inner = self.inner.read().unwrap();
        inner
            .ring
            .route(key)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("no shards are running"))
    }

    /// Route `key` to its shard's stack, holding the read lock only for
    /// the lookup — the returned `Arc` keeps the shard alive even if it
    /// is removed from the ring mid-request.
    fn route(&self, key: &str) -> Result<Arc<ServingStack>> {
        let inner = self.inner.read().unwrap();
        let label = inner
            .ring
            .route(key)
            .ok_or_else(|| anyhow!("no shards are running"))?;
        Ok(Arc::clone(&inner.stacks[label]))
    }

    /// Every running stack, for operations that fan out (reload, stats).
    fn all(&self) -> Vec<(String, Arc<ServingStack>)> {
        let inner = self.inner.read().unwrap();
        inner
            .stacks
            .iter()
            .map(|(l, s)| (l.clone(), Arc::clone(s)))
            .collect()
    }

    fn first(&self) -> Result<Arc<ServingStack>> {
        let inner = self.inner.read().unwrap();
        inner
            .stacks
            .values()
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("no shards are running"))
    }

    /// Frequencies served (identical on every shard, by construction).
    pub fn frequencies(&self) -> Vec<Frequency> {
        self.first().map(|s| s.frequencies()).unwrap_or_default()
    }

    /// The ring's only frequency, when exactly one is served.
    pub fn single_frequency(&self) -> Option<Frequency> {
        self.first().ok()?.single_frequency()
    }

    /// The equalized history length required of requests for `freq`.
    pub fn required_length(&self, freq: Frequency) -> Result<usize> {
        self.first()?.required_length(freq)
    }

    /// Blocking forecast: consistent-hash route by `req.id`, then
    /// dispatch by frequency inside the shard.
    pub fn forecast(&self, freq: Frequency, req: ForecastRequest)
                    -> Result<ForecastResponse> {
        self.route(&req.id)?.forecast(freq, req)
    }

    /// Non-blocking submit, same routing as [`forecast`](Self::forecast).
    pub fn submit(&self, freq: Frequency, req: ForecastRequest)
                  -> Result<ResponseReceiver> {
        self.route(&req.id)?.submit(freq, req)
    }

    /// Hot-swap `freq`'s model on every shard. Returns the newest
    /// generation now serving (shards version independently; the fleet
    /// converges to the same weights even though tags may differ).
    /// Errs on an empty ring — "reloaded nowhere" must not look like
    /// success.
    pub fn reload(&self, freq: Frequency, state: ModelState) -> Result<u64> {
        let all = self.all();
        if all.is_empty() {
            bail!("no shards are running");
        }
        let mut newest = 0;
        for (_, stack) in all {
            newest = newest.max(stack.reload(freq, state.clone())?);
        }
        Ok(newest)
    }

    /// [`reload`](Self::reload) from a checkpoint file (JSON or binary,
    /// magic-sniffed); the checkpoint's recorded frequency must match.
    pub fn reload_checkpoint(&self, freq: Frequency, path: impl AsRef<Path>)
                             -> Result<u64> {
        let state = checkpoint::load_model_state_for(path, freq.name())?;
        self.reload(freq, state)
    }

    /// Newest generation serving `freq` on any shard; errs on an empty
    /// ring.
    pub fn generation(&self, freq: Frequency) -> Result<u64> {
        let all = self.all();
        if all.is_empty() {
            bail!("no shards are running");
        }
        let mut newest = 0;
        for (_, stack) in all {
            newest = newest.max(stack.generation(freq)?);
        }
        Ok(newest)
    }

    /// Aggregated stats for one frequency (see [`ServiceStats::absorb`]).
    pub fn stats(&self, freq: Frequency) -> Result<ServiceStats> {
        let mut agg = ServiceStats::default();
        for (_, stack) in self.all() {
            agg.absorb(&stack.stats(freq)?);
        }
        Ok(agg)
    }

    /// Aggregated stats for every frequency: counters sum over shards,
    /// generation takes the max, latencies take the worst shard.
    pub fn stats_all(&self) -> BTreeMap<Frequency, ServiceStats> {
        let mut out: BTreeMap<Frequency, ServiceStats> = BTreeMap::new();
        for (_, stack) in self.all() {
            for (freq, st) in stack.stats_all() {
                out.entry(freq).or_default().absorb(&st);
            }
        }
        out
    }

    /// Unaggregated per-shard stats, keyed by shard label.
    pub fn shard_stats(&self)
                       -> BTreeMap<String, BTreeMap<Frequency, ServiceStats>> {
        self.all()
            .into_iter()
            .map(|(label, stack)| (label, stack.stats_all()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("series-{i}")).collect()
    }

    fn assign(ring: &HashRing, keys: &[String]) -> Vec<String> {
        keys.iter().map(|k| ring.route(k).unwrap().to_string()).collect()
    }

    #[test]
    fn empty_ring_routes_nothing() {
        let ring = HashRing::new();
        assert!(ring.route("anything").is_none());
        assert!(ring.is_empty());
    }

    #[test]
    fn assignment_is_stable_across_restarts_and_insertion_order() {
        let ks = keys(2000);
        let mut a = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            a.insert(l).unwrap();
        }
        // A "restarted" ring built in a different order must agree on
        // every key — the ring is a set of points, not a history.
        let mut b = HashRing::new();
        for l in ["s3", "s1", "s0", "s2"] {
            b.insert(l).unwrap();
        }
        assert_eq!(assign(&a, &ks), assign(&b, &ks));
    }

    #[test]
    fn every_shard_takes_a_reasonable_share() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for a in assign(&ring, &ks) {
            *counts.entry(a).or_default() += 1;
        }
        assert_eq!(counts.len(), 4, "some shard got no keys: {counts:?}");
        for (label, c) in &counts {
            // Perfect balance is 2500; vnodes keep the skew moderate.
            assert!(*c > 1000 && *c < 5000,
                    "shard {label} owns {c}/10000 keys — ring is badly \
                     unbalanced: {counts:?}");
        }
    }

    #[test]
    fn adding_a_shard_moves_keys_only_onto_it_and_about_one_in_n() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        let before = assign(&ring, &ks);
        ring.insert("s4").unwrap();
        let after = assign(&ring, &ks);
        let mut moved = 0usize;
        for (old, new) in before.iter().zip(&after) {
            if old != new {
                // THE consistent-hashing property: growth never
                // reshuffles keys between surviving shards.
                assert_eq!(new, "s4",
                           "key moved from {old} to {new}, not to the \
                            new shard");
                moved += 1;
            }
        }
        // Ideal movement is 1/5 of keys; allow generous slack for vnode
        // placement luck but reject %-N-style full reshuffles.
        assert!(moved > 500, "new shard took only {moved}/10000 keys");
        assert!(moved < 4000,
                "{moved}/10000 keys moved — far beyond the ≈1/N contract");
    }

    #[test]
    fn removing_a_shard_strands_no_other_keys() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3", "s4"] {
            ring.insert(l).unwrap();
        }
        let before = assign(&ring, &ks);
        ring.remove("s2").unwrap();
        let after = assign(&ring, &ks);
        let mut moved = 0usize;
        for (old, new) in before.iter().zip(&after) {
            if old == "s2" {
                assert_ne!(new, "s2", "key still routed to removed shard");
                moved += 1;
            } else {
                // Keys on surviving shards must not move at all.
                assert_eq!(old, new,
                           "removal reshuffled a key between survivors");
            }
        }
        assert!(moved > 500 && moved < 4000,
                "s2 owned {moved}/10000 keys before removal");
    }

    #[test]
    fn insert_and_remove_validate_membership() {
        let mut ring = HashRing::new();
        ring.insert("s0").unwrap();
        assert!(ring.insert("s0").is_err(), "duplicate label must fail");
        assert!(ring.remove("nope").is_err(), "unknown label must fail");
        ring.remove("s0").unwrap();
        assert!(ring.is_empty());
        assert_eq!(ring.len(), 0);
    }

    #[test]
    fn labels_and_len_track_membership() {
        let mut ring = HashRing::new();
        for l in ["b", "a", "c"] {
            ring.insert(l).unwrap();
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.labels(), vec!["a", "b", "c"]);
        assert!(ring.contains("b"));
        ring.remove("b").unwrap();
        assert!(!ring.contains("b"));
        assert_eq!(ring.labels(), vec!["a", "c"]);
    }
}
