//! Horizontal sharding: a [`ShardedStack`] owns N independent
//! [`ServingStack`] shards and routes every request by a consistent hash
//! of its series id.
//!
//! Why consistent hashing (a point ring with virtual nodes) instead of
//! `hash(id) % N`:
//!
//! * **stable assignment** — a series id maps to the same shard on every
//!   process restart and regardless of shard insertion order (the ring
//!   is a sorted set of hash points, not a history);
//! * **bounded movement** — adding or removing one shard moves only the
//!   keys adjacent to that shard's points, ≈1/N of the keyspace, and
//!   adding a shard moves keys *only onto the new shard* (never between
//!   survivors). `%-N` would reshuffle almost everything, defeating any
//!   per-shard warm state (and, once shards are remote, any cache).
//!
//! Shard lifecycle: [`add_shard`](ShardedStack::add_shard) splices a
//! running stack into the ring; [`remove_shard`](ShardedStack::remove_shard)
//! is the drain protocol — it atomically stops routing to the shard and
//! hands the caller the `Arc`, whose final drop shuts the shard's pools
//! down *after* their queues drain (`FreqPool` drains before its workers
//! exit), so removal never drops an accepted request.
//!
//! The ring routes to [`ShardClient`]s, not concrete stacks: an
//! in-process [`ServingStack`] and a [`RemoteShard`](super::remote)
//! proxying another machine over TCP are interchangeable members. With
//! `--replicas R` each key maps to its R distinct ring successors
//! ([`HashRing::route_n`]) and reads are *hedged* (see
//! [`remote::hedged_forecast`](super::remote)): the primary gets the
//! rolling p95 to answer before the next replica is fired too, so one
//! slow replica is a near-miss instead of a p99 cliff. An ejected
//! remote (failed health probes) keeps its ring points but loses
//! routing *preference* — healthy replicas are tried first, and
//! readmission restores the exact pre-ejection placement.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::config::Frequency;
use crate::coordinator::ModelState;
use crate::telemetry::registry::{Counter, Registry};

use super::pool::ObserveOutcome;
use super::remote::{hedged_forecast, HedgeClock, RemoteShard, ShardClient,
                    ShardHealth};
use super::router::ServingStack;
use super::state::SeriesRecord;
use super::{ForecastRequest, ForecastResponse, ResponseReceiver,
            ServiceStats};

/// Virtual nodes per shard. More vnodes → smoother key distribution and
/// closer-to-1/N movement on membership change, at the cost of a larger
/// (still tiny) ring. 64 keeps the max/min shard load ratio near 1.3
/// for realistic shard counts.
const VNODES: usize = 64;

/// FNV-1a 64-bit with a MurmurHash3 `fmix64` avalanche finalizer —
/// tiny, dependency-free, and stable across platforms and releases
/// (unlike `DefaultHasher`, whose output may change between Rust
/// versions — assignment stability across restarts is the point).
///
/// The finalizer matters: ring placement orders raw 64-bit values, so
/// it is dominated by the *high* bits, and plain FNV-1a of short,
/// similar keys (`series-0`, `series-1`, …) clusters badly up there —
/// measured on 10k sequential ids over 4 shards, one shard owned 65%
/// of the keyspace. `fmix64` scatters every input bit across the word
/// (its whole design goal), bringing the same measurement to a
/// 23–28% per-shard spread.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    fmix64(h)
}

/// MurmurHash3's 64-bit finalizer: full avalanche (every input bit
/// flips each output bit with ~1/2 probability).
fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// A consistent-hash ring: each shard label contributes [`VNODES`]
/// points; a key routes to the first point clockwise from its own hash.
/// Pure data structure (no pools) so routing properties are unit-testable
/// without starting servers.
#[derive(Debug, Default, Clone)]
pub struct HashRing {
    /// Sorted by (point, label); the label tie-break makes point
    /// collisions deterministic.
    points: Vec<(u64, String)>,
}

impl HashRing {
    pub fn new() -> Self {
        Self::default()
    }

    fn point(label: &str, vnode: usize) -> u64 {
        fnv1a64(format!("{label}#{vnode}").as_bytes())
    }

    /// Add a shard's points. Errors if the label is already present.
    pub fn insert(&mut self, label: &str) -> Result<()> {
        if self.contains(label) {
            bail!("shard `{label}` is already on the ring");
        }
        for v in 0..VNODES {
            self.points.push((Self::point(label, v), label.to_string()));
        }
        self.points.sort();
        Ok(())
    }

    /// Remove a shard's points. Errors if the label is absent.
    pub fn remove(&mut self, label: &str) -> Result<()> {
        if !self.contains(label) {
            bail!("shard `{label}` is not on the ring");
        }
        self.points.retain(|(_, l)| l != label);
        Ok(())
    }

    pub fn contains(&self, label: &str) -> bool {
        self.points.iter().any(|(_, l)| l == label)
    }

    /// Number of shards (not points) on the ring.
    pub fn len(&self) -> usize {
        self.labels().len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Shard labels, sorted.
    pub fn labels(&self) -> Vec<String> {
        let mut ls: Vec<String> =
            self.points.iter().map(|(_, l)| l.clone()).collect();
        ls.sort();
        ls.dedup();
        ls
    }

    /// The shard owning `key`: the first point at or clockwise after
    /// `hash(key)`, wrapping to the ring's first point. `None` on an
    /// empty ring.
    pub fn route(&self, key: &str) -> Option<&str> {
        if self.points.is_empty() {
            return None;
        }
        let h = fnv1a64(key.as_bytes());
        let i = self.points.partition_point(|(p, _)| *p < h);
        let (_, label) = &self.points[i % self.points.len()];
        Some(label)
    }

    /// The `n` *distinct* shards owning `key`'s replica set: the first
    /// point clockwise from `hash(key)` and then the next points whose
    /// labels have not been seen yet, wrapping. Fewer than `n` shards on
    /// the ring returns them all. `route_n(key, 1)` agrees with
    /// [`route`](Self::route) on every key, and — same argument as for
    /// single routing — membership changes elsewhere on the ring cannot
    /// reorder a key's surviving successors (points never move, so the
    /// clockwise scan meets them in the same order).
    pub fn route_n(&self, key: &str, n: usize) -> Vec<&str> {
        if self.points.is_empty() || n == 0 {
            return Vec::new();
        }
        let h = fnv1a64(key.as_bytes());
        let start = self.points.partition_point(|(p, _)| *p < h);
        let mut out: Vec<&str> = Vec::new();
        for i in 0..self.points.len() {
            let (_, label) = &self.points[(start + i) % self.points.len()];
            if !out.iter().any(|l| l == label) {
                out.push(label.as_str());
                if out.len() == n {
                    break;
                }
            }
        }
        out
    }
}

struct Shards {
    ring: HashRing,
    clients: BTreeMap<String, Arc<dyn ShardClient>>,
}

/// N shards — in-process [`ServingStack`]s and/or
/// [`RemoteShard`] proxies — behind a consistent-hash router. All
/// methods take `&self` (membership sits under one `RwLock`; request
/// dispatch takes the read side only, so routing scales with shards).
///
/// The router also owns the ring's metrics [`Registry`]: every shard's
/// instruments are bound into it (under `{shard, freq}` / `{shard,
/// addr}` labels) as the shard joins and unbound as it leaves, so
/// `GET /v1/metrics` always reflects the current membership.
pub struct ShardedStack {
    // lint:lock-name(shard.inner)
    inner: RwLock<Shards>,
    registry: Arc<Registry>,
    /// Replicas per key (R-way): each key routes to its R distinct
    /// ring successors; reads are hedged across them.
    replicas: AtomicUsize,
    /// The rolling-p95 hedge timer + ring-level hedge counters.
    hedge: HedgeClock,
    /// Async observe fan-outs fired at non-primary replicas.
    observe_fanout: Counter,
    /// Fan-outs that failed (the replica re-converges on the next
    /// observe or checkpoint sidecar import — see DESIGN.md).
    observe_fanout_errors: Counter,
}

impl Default for ShardedStack {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedStack {
    /// An empty router: [`add_shard`](Self::add_shard) before serving.
    pub fn new() -> Self {
        let registry = Arc::new(Registry::new());
        let hedge = HedgeClock::new();
        // Ring-level (unlabeled) hedge counters: hedging is a property
        // of the replicated read path, not of any one shard.
        registry.register_counter(
            "fesrnn_remote_hedges_total",
            "Hedged (duplicate) reads fired after the primary replica \
             outlived the rolling-p95 hedge timer.",
            &[], &hedge.hedges);
        registry.register_counter(
            "fesrnn_remote_hedge_wins_total",
            "Hedged or failed-over reads answered first by a non-primary \
             replica.",
            &[], &hedge.hedge_wins);
        let observe_fanout = Counter::new();
        let observe_fanout_errors = Counter::new();
        registry.register_counter(
            "fesrnn_observe_fanout_total",
            "Asynchronous observe replications fired at non-primary \
             replicas of a series' replica set.",
            &[], &observe_fanout);
        registry.register_counter(
            "fesrnn_observe_fanout_errors_total",
            "Asynchronous observe replications that failed (the replica \
             re-converges on its next observe or sidecar import).",
            &[], &observe_fanout_errors);
        Self {
            inner: RwLock::new(Shards {
                ring: HashRing::new(),
                clients: BTreeMap::new(),
            }),
            registry,
            replicas: AtomicUsize::new(1),
            hedge,
            observe_fanout,
            observe_fanout_errors,
        }
    }

    /// The metrics registry every shard's pool instruments are bound
    /// into; the HTTP front-end renders it at `GET /v1/metrics` and
    /// binds its own connection metrics here too.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Wrap one existing stack as a single-shard router (what the
    /// single-stack [`HttpServer::start`](super::http::HttpServer::start)
    /// entrypoint uses).
    pub fn single(stack: Arc<ServingStack>) -> Result<Self> {
        let sharded = Self::new();
        sharded.add_shard_arc("shard-0", stack)?;
        Ok(sharded)
    }

    /// Splice a running stack into the ring under `label`. New requests
    /// whose keys land on the new shard's points route there from the
    /// moment this returns; no key between surviving shards moves.
    pub fn add_shard(&self, label: &str, stack: ServingStack) -> Result<()> {
        self.add_shard_arc(label, Arc::new(stack))
    }

    /// [`add_shard`](Self::add_shard) for a stack the caller also holds.
    pub fn add_shard_arc(&self, label: &str, stack: Arc<ServingStack>)
                         -> Result<()> {
        self.add_shard_client(label, stack)
    }

    /// Splice a [`RemoteShard`] — a shard living in another process —
    /// into the ring. The ring treats it exactly like a local stack.
    pub fn add_remote_shard(&self, label: &str, remote: RemoteShard)
                            -> Result<()> {
        self.add_shard_client(label, Arc::new(remote))
    }

    /// The general form both of the above lower to: any
    /// [`ShardClient`] joins the ring under `label`.
    pub fn add_shard_client(&self, label: &str, client: Arc<dyn ShardClient>)
                            -> Result<()> {
        if client.frequencies().is_empty() {
            bail!("shard `{label}` has no running pools");
        }
        {
            let mut inner = self.inner.write().unwrap();
            if let Some(first) = inner.clients.values().next() {
                if first.frequencies() != client.frequencies() {
                    bail!("shard `{label}` serves {:?} but the ring \
                           serves {:?} — every shard must serve the same \
                           frequencies",
                          client.frequencies(), first.frequencies());
                }
            }
            inner.ring.insert(label)?;
            inner.clients.insert(label.to_string(), Arc::clone(&client));
        }
        // Bind after the membership lock is released: registration takes
        // the registry's own mutex, and no path may hold both locks.
        client.bind_metrics(&self.registry, label);
        Ok(())
    }

    /// Drain protocol, step 1+2 in one atomic move: stop routing to
    /// `label` and return its client. A local shard keeps serving
    /// whatever it already accepted; when the caller drops the returned
    /// `Arc` (and in-flight requests release theirs), the pools shut
    /// down and *drain their queues before the workers exit* — an
    /// accepted request is never dropped by a removal. (A remote
    /// shard's process keeps running; removal only stops routing to it
    /// and stops its health prober.)
    pub fn remove_shard(&self, label: &str) -> Result<Arc<dyn ShardClient>> {
        let removed = {
            let mut inner = self.inner.write().unwrap();
            if inner.clients.len() == 1 && inner.clients.contains_key(label) {
                bail!("cannot remove `{label}` — it is the last shard");
            }
            inner.ring.remove(label)?;
            inner
                .clients
                .remove(label)
                .ok_or_else(|| anyhow!("shard `{label}` not found"))?
        };
        // The departed shard's series leave the exposition with it
        // (unbind outside the membership lock, mirroring
        // add_shard_client).
        self.registry.unregister("shard", label);
        Ok(removed)
    }

    pub fn shard_count(&self) -> usize {
        self.inner.read().unwrap().clients.len()
    }

    /// Shard labels, sorted.
    pub fn shard_labels(&self) -> Vec<String> {
        self.inner.read().unwrap().clients.keys().cloned().collect()
    }

    /// Set the replication factor R: every key maps to its R distinct
    /// ring successors and reads are hedged across them. Clamped to
    /// ≥ 1; values above the shard count degrade gracefully (a key
    /// simply gets every shard). Takes effect for the *next* request —
    /// no lock, no drain.
    pub fn set_replicas(&self, n: usize) {
        self.replicas.store(n.max(1), Ordering::Relaxed);
    }

    /// The configured replication factor.
    pub fn replicas(&self) -> usize {
        self.replicas.load(Ordering::Relaxed)
    }

    /// Hedged reads fired (rolling-p95 timer expiries).
    pub fn hedges(&self) -> u64 {
        self.hedge.hedges()
    }

    /// Hedged/failed-over reads a non-primary replica answered first.
    pub fn hedge_wins(&self) -> u64 {
        self.hedge.hedge_wins()
    }

    /// Per-shard health (kind, address, ejection state, probe
    /// counters), keyed by shard label — the `/v1/stats` `"remote"`
    /// section and `fast-esrnn top` read this.
    pub fn shard_health(&self) -> BTreeMap<String, ShardHealth> {
        self.all()
            .into_iter()
            .map(|(label, c)| (label, c.health()))
            .collect()
    }

    /// Which shard `key` (a series id) routes to — exposed so operators
    /// and tests can audit placement.
    pub fn shard_for(&self, key: &str) -> Result<String> {
        let inner = self.inner.read().unwrap();
        inner
            .ring
            .route(key)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("no shards are running"))
    }

    /// `key`'s replica set, ready to dispatch: up to R clients in
    /// routing-preference order, the read lock held only for the
    /// lookup — the returned `Arc`s keep the shards alive even if they
    /// are removed from the ring mid-request.
    ///
    /// Ejection is a *mask*, not a membership change: an unhealthy
    /// shard keeps its ring points but loses preference — the set is
    /// the healthy successors in ring order first, then (only when too
    /// few shards are healthy) the ejected ones as a last resort. With
    /// R = 1 this is automatic failover; readmission restores the
    /// exact pre-ejection placement because the points never moved.
    fn route_replicas(&self, key: &str)
                      -> Result<Vec<Arc<dyn ShardClient>>> {
        let want = self.replicas.load(Ordering::Relaxed).max(1);
        let inner = self.inner.read().unwrap();
        if inner.ring.is_empty() {
            bail!("no shards are running");
        }
        let quick = inner.ring.route_n(key, want);
        let clients: Vec<Arc<dyn ShardClient>> = quick
            .iter()
            .map(|l| Arc::clone(&inner.clients[*l]))
            .collect();
        // Fast path (the common, fully-healthy case): the first R
        // successors are the replica set, no full-ring walk.
        if clients.iter().all(|c| c.healthy()) {
            return Ok(clients);
        }
        let order = inner.ring.route_n(key, inner.ring.len());
        let mut picked: Vec<Arc<dyn ShardClient>> = Vec::new();
        let mut ejected: Vec<Arc<dyn ShardClient>> = Vec::new();
        for label in order {
            let c = Arc::clone(&inner.clients[label]);
            if c.healthy() {
                picked.push(c);
            } else {
                ejected.push(c);
            }
        }
        picked.extend(ejected);
        picked.truncate(want);
        Ok(picked)
    }

    /// Route `key` to its primary (first healthy) shard.
    fn route(&self, key: &str) -> Result<Arc<dyn ShardClient>> {
        self.route_replicas(key)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no shards are running"))
    }

    /// Every running shard client, for operations that fan out
    /// (reload, stats).
    fn all(&self) -> Vec<(String, Arc<dyn ShardClient>)> {
        let inner = self.inner.read().unwrap();
        inner
            .clients
            .iter()
            .map(|(l, s)| (l.clone(), Arc::clone(s)))
            .collect()
    }

    fn first(&self) -> Result<Arc<dyn ShardClient>> {
        let inner = self.inner.read().unwrap();
        inner
            .clients
            .values()
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("no shards are running"))
    }

    /// Frequencies served (identical on every shard, by construction).
    pub fn frequencies(&self) -> Vec<Frequency> {
        self.first().map(|s| s.frequencies()).unwrap_or_default()
    }

    /// The ring's only frequency, when exactly one is served.
    pub fn single_frequency(&self) -> Option<Frequency> {
        let freqs = self.first().ok()?.frequencies();
        if freqs.len() == 1 {
            Some(freqs[0])
        } else {
            None
        }
    }

    /// The equalized history length required of requests for `freq`.
    pub fn required_length(&self, freq: Frequency) -> Result<usize> {
        self.first()?.required_length(freq)
    }

    /// Blocking forecast: consistent-hash route by `req.id` to the
    /// key's replica set, hedge across it (primary first; next replica
    /// fired at the rolling p95 or on a fast failure), then dispatch by
    /// frequency inside the winning shard. With R = 1 (the default)
    /// this is a plain synchronous call to the key's shard.
    pub fn forecast(&self, freq: Frequency, req: ForecastRequest)
                    -> Result<ForecastResponse> {
        let replicas = self.route_replicas(&req.id)?;
        hedged_forecast(&self.hedge, &replicas, freq, req)
    }

    /// Non-blocking submit to the key's primary shard (hedging needs a
    /// blocking rendezvous; replicated dispatch is the
    /// [`forecast`](Self::forecast) path).
    pub fn submit(&self, freq: Frequency, req: ForecastRequest)
                  -> Result<ResponseReceiver> {
        self.route(&req.id)?.submit(freq, req)
    }

    /// Advance a series' ES state: consistent-hash route by `id` to the
    /// same replica set as [`forecast`](Self::forecast), apply on the
    /// primary *synchronously* (the caller's next forecast must see the
    /// new state), then replicate to the remaining replicas
    /// *asynchronously* — a slow replica must not sit on the observe
    /// hot path. The `t0` write guard applies on the primary only;
    /// fan-outs are best-effort (a replica that missed one batch would
    /// otherwise reject every later one). A failed fan-out bumps
    /// `fesrnn_observe_fanout_errors_total`; a lagging replica
    /// re-converges on a checkpoint state-sidecar import.
    pub fn observe(&self, freq: Frequency, id: &str, values: &[f32],
                   t0: Option<u64>) -> Result<ObserveOutcome> {
        let replicas = self.route_replicas(id)?;
        let (primary, rest) = replicas
            .split_first()
            .ok_or_else(|| anyhow!("no shards are running"))?;
        let outcome = primary.observe(freq, id, values, t0)?;
        for replica in rest {
            self.observe_fanout.inc();
            let client = Arc::clone(replica);
            let errors = self.observe_fanout_errors.clone();
            let (id, values) = (id.to_string(), values.to_vec());
            std::thread::spawn(move || {
                if client.observe(freq, &id, &values, None).is_err() {
                    errors.inc();
                }
            });
        }
        Ok(outcome)
    }

    /// Async observe replications fired at non-primary replicas.
    pub fn observe_fanouts(&self) -> u64 {
        self.observe_fanout.get()
    }

    /// Fan-outs that failed.
    pub fn observe_fanout_errors(&self) -> u64 {
        self.observe_fanout_errors.get()
    }

    /// Stateful forecast from a series' stored ES state, routed to the
    /// key's primary shard (the one synchronous observes land on — the
    /// replica states are eventually consistent).
    pub fn series_forecast(&self, freq: Frequency, id: &str)
                           -> Result<ForecastResponse> {
        self.route(id)?.series_forecast(freq, id)
    }

    /// The stored state record for one series, from the key's primary.
    pub fn series_record(&self, freq: Frequency, id: &str)
                         -> Result<SeriesRecord> {
        self.route(id)?.series_record(freq, id)
    }

    /// Hot-swap `freq`'s model on every shard. Returns the newest
    /// generation now serving (shards version independently; the fleet
    /// converges to the same weights even though tags may differ).
    /// Errs on an empty ring — "reloaded nowhere" must not look like
    /// success.
    /// Requires every shard to accept the state — a remote shard
    /// cannot (a `ModelState` is not wire-shippable) and will fail the
    /// whole reload; mixed rings use
    /// [`reload_checkpoint`](Self::reload_checkpoint), where each shard
    /// resolves the path on its own filesystem.
    pub fn reload(&self, freq: Frequency, state: ModelState) -> Result<u64> {
        let all = self.all();
        if all.is_empty() {
            bail!("no shards are running");
        }
        let mut newest = 0;
        for (_, client) in all {
            newest = newest.max(client.reload(freq, state.clone())?);
        }
        Ok(newest)
    }

    /// [`reload`](Self::reload) from a checkpoint file (JSON or binary,
    /// magic-sniffed); the checkpoint's recorded frequency must match.
    /// Fans the *path* out to every shard — a local stack loads it
    /// here, a remote shard resolves it on its own filesystem via
    /// `POST /v1/reload` — so every member of a mixed ring converges on
    /// the same weights.
    pub fn reload_checkpoint(&self, freq: Frequency, path: impl AsRef<Path>)
                             -> Result<u64> {
        let all = self.all();
        if all.is_empty() {
            bail!("no shards are running");
        }
        let mut newest = 0;
        for (_, client) in all {
            newest = newest.max(client.reload_checkpoint(freq,
                                                         path.as_ref())?);
        }
        Ok(newest)
    }

    /// Newest generation serving `freq` on any *reachable* shard; errs
    /// on an empty ring or when no shard answers (an ejected remote
    /// must not take `/v1/healthz` down with it).
    pub fn generation(&self, freq: Frequency) -> Result<u64> {
        let all = self.all();
        if all.is_empty() {
            bail!("no shards are running");
        }
        let mut newest: Option<u64> = None;
        let mut last_err = None;
        for (_, client) in all {
            match client.generation(freq) {
                Ok(g) => newest = Some(newest.unwrap_or(0).max(g)),
                Err(e) => last_err = Some(e),
            }
        }
        match (newest, last_err) {
            (Some(g), _) => Ok(g),
            (None, Some(e)) => Err(e),
            (None, None) => bail!("no shards are running"),
        }
    }

    /// Aggregated stats for one frequency (see [`ServiceStats::absorb`]).
    /// Unreachable shards are skipped — a dead remote must not turn
    /// `/v1/stats` into a 500.
    pub fn stats(&self, freq: Frequency) -> Result<ServiceStats> {
        let mut agg = ServiceStats::default();
        for (_, by_freq) in self.shard_stats() {
            if let Some(st) = by_freq.get(&freq) {
                agg.absorb(st);
            }
        }
        Ok(agg)
    }

    /// Aggregated stats for every frequency: counters sum over shards,
    /// generation takes the max, latencies take the worst shard.
    /// Unreachable shards are skipped.
    pub fn stats_all(&self) -> BTreeMap<Frequency, ServiceStats> {
        let mut out: BTreeMap<Frequency, ServiceStats> = BTreeMap::new();
        for (_, by_freq) in self.shard_stats() {
            for (freq, st) in by_freq {
                out.entry(freq).or_default().absorb(&st);
            }
        }
        out
    }

    /// Unaggregated per-shard stats, keyed by shard label. A shard
    /// whose snapshot fails (dead remote) is omitted — its absence
    /// from the breakdown plus its `"remote"` health row is the
    /// operator's signal, not a 500.
    pub fn shard_stats(&self)
                       -> BTreeMap<String, BTreeMap<Frequency, ServiceStats>> {
        self.all()
            .into_iter()
            .filter_map(|(label, client)| {
                client.stats_snapshot().ok().map(|s| (label, s))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("series-{i}")).collect()
    }

    fn assign(ring: &HashRing, keys: &[String]) -> Vec<String> {
        keys.iter().map(|k| ring.route(k).unwrap().to_string()).collect()
    }

    #[test]
    fn empty_ring_routes_nothing() {
        let ring = HashRing::new();
        assert!(ring.route("anything").is_none());
        assert!(ring.is_empty());
    }

    #[test]
    fn assignment_is_stable_across_restarts_and_insertion_order() {
        let ks = keys(2000);
        let mut a = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            a.insert(l).unwrap();
        }
        // A "restarted" ring built in a different order must agree on
        // every key — the ring is a set of points, not a history.
        let mut b = HashRing::new();
        for l in ["s3", "s1", "s0", "s2"] {
            b.insert(l).unwrap();
        }
        assert_eq!(assign(&a, &ks), assign(&b, &ks));
    }

    #[test]
    fn every_shard_takes_a_reasonable_share() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for a in assign(&ring, &ks) {
            *counts.entry(a).or_default() += 1;
        }
        assert_eq!(counts.len(), 4, "some shard got no keys: {counts:?}");
        for (label, c) in &counts {
            // Perfect balance is 2500; vnodes keep the skew moderate.
            assert!(*c > 1000 && *c < 5000,
                    "shard {label} owns {c}/10000 keys — ring is badly \
                     unbalanced: {counts:?}");
        }
    }

    #[test]
    fn adding_a_shard_moves_keys_only_onto_it_and_about_one_in_n() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        let before = assign(&ring, &ks);
        ring.insert("s4").unwrap();
        let after = assign(&ring, &ks);
        let mut moved = 0usize;
        for (old, new) in before.iter().zip(&after) {
            if old != new {
                // THE consistent-hashing property: growth never
                // reshuffles keys between surviving shards.
                assert_eq!(new, "s4",
                           "key moved from {old} to {new}, not to the \
                            new shard");
                moved += 1;
            }
        }
        // Ideal movement is 1/5 of keys; allow generous slack for vnode
        // placement luck but reject %-N-style full reshuffles.
        assert!(moved > 500, "new shard took only {moved}/10000 keys");
        assert!(moved < 4000,
                "{moved}/10000 keys moved — far beyond the ≈1/N contract");
    }

    #[test]
    fn removing_a_shard_strands_no_other_keys() {
        let ks = keys(10_000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3", "s4"] {
            ring.insert(l).unwrap();
        }
        let before = assign(&ring, &ks);
        ring.remove("s2").unwrap();
        let after = assign(&ring, &ks);
        let mut moved = 0usize;
        for (old, new) in before.iter().zip(&after) {
            if old == "s2" {
                assert_ne!(new, "s2", "key still routed to removed shard");
                moved += 1;
            } else {
                // Keys on surviving shards must not move at all.
                assert_eq!(old, new,
                           "removal reshuffled a key between survivors");
            }
        }
        assert!(moved > 500 && moved < 4000,
                "s2 owned {moved}/10000 keys before removal");
    }

    #[test]
    fn insert_and_remove_validate_membership() {
        let mut ring = HashRing::new();
        ring.insert("s0").unwrap();
        assert!(ring.insert("s0").is_err(), "duplicate label must fail");
        assert!(ring.remove("nope").is_err(), "unknown label must fail");
        ring.remove("s0").unwrap();
        assert!(ring.is_empty());
        assert_eq!(ring.len(), 0);
    }

    // ------------------------------------------------------- route_n

    #[test]
    fn route_n_of_one_agrees_with_route_on_every_key() {
        let ks = keys(2000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        for k in &ks {
            assert_eq!(ring.route_n(k, 1), vec![ring.route(k).unwrap()],
                       "route_n(_, 1) must be the single-route answer");
        }
    }

    #[test]
    fn route_n_returns_distinct_shards_capped_at_membership() {
        let ks = keys(1000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        for k in &ks {
            for n in 0..=6 {
                let reps = ring.route_n(k, n);
                assert_eq!(reps.len(), n.min(4),
                           "want min(n, shards) replicas for n={n}");
                let mut uniq: Vec<&str> = reps.clone();
                uniq.sort();
                uniq.dedup();
                assert_eq!(uniq.len(), reps.len(),
                           "replica set for {k} repeats a shard: {reps:?}");
            }
        }
        assert!(HashRing::new().route_n("anything", 2).is_empty());
    }

    #[test]
    fn route_n_is_stable_across_insertion_order() {
        let ks = keys(1000);
        let mut a = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            a.insert(l).unwrap();
        }
        let mut b = HashRing::new();
        for l in ["s3", "s1", "s0", "s2"] {
            b.insert(l).unwrap();
        }
        for k in &ks {
            assert_eq!(a.route_n(k, 2), b.route_n(k, 2),
                       "replica sets must not depend on build order");
        }
    }

    #[test]
    fn unrelated_insert_keeps_surviving_replica_order() {
        // Adding a shard may interpose itself into some keys' replica
        // chains, but the *relative order of the surviving shards*
        // must never change (points do not move), so replica sets
        // stay warm across unrelated membership churn.
        let ks = keys(2000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3"] {
            ring.insert(l).unwrap();
        }
        let before: Vec<Vec<String>> = ks
            .iter()
            .map(|k| {
                ring.route_n(k, 2).iter().map(|s| s.to_string()).collect()
            })
            .collect();
        ring.insert("s4").unwrap();
        for (k, old) in ks.iter().zip(&before) {
            let new = ring.route_n(k, 3);
            let survivors: Vec<&str> = new
                .iter()
                .copied()
                .filter(|l| *l != "s4")
                .take(2)
                .collect();
            assert_eq!(survivors, old.iter().map(String::as_str)
                                      .collect::<Vec<_>>(),
                       "key {k}: surviving replica order changed on an \
                        unrelated insert (old {old:?}, new {new:?})");
        }
    }

    #[test]
    fn unrelated_remove_keeps_other_replica_sets() {
        // Removing a shard must only splice it out of the chains it was
        // in; keys whose replica set never contained it are untouched.
        let ks = keys(2000);
        let mut ring = HashRing::new();
        for l in ["s0", "s1", "s2", "s3", "s4"] {
            ring.insert(l).unwrap();
        }
        let before: Vec<Vec<String>> = ks
            .iter()
            .map(|k| {
                ring.route_n(k, 2).iter().map(|s| s.to_string()).collect()
            })
            .collect();
        ring.remove("s4").unwrap();
        let mut untouched = 0usize;
        for (k, old) in ks.iter().zip(&before) {
            let new = ring.route_n(k, 2);
            if old.iter().all(|l| l != "s4") {
                assert_eq!(new, old.as_slice(),
                           "key {k}: replica set changed although s4 was \
                            not in it");
                untouched += 1;
            } else {
                assert!(new.iter().all(|l| *l != "s4"),
                        "key {k} still lists the removed shard");
            }
        }
        assert!(untouched > 500,
                "almost every replica set contained s4 — ring is \
                 degenerate ({untouched}/2000 untouched)");
    }

    #[test]
    fn labels_and_len_track_membership() {
        let mut ring = HashRing::new();
        for l in ["b", "a", "c"] {
            ring.insert(l).unwrap();
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.labels(), vec!["a", "b", "c"]);
        assert!(ring.contains("b"));
        ring.remove("b").unwrap();
        assert!(!ring.contains("b"));
        assert_eq!(ring.labels(), vec!["a", "c"]);
    }
}
