//! Per-frequency worker pool with a shared dynamic-batching queue and
//! generation-tagged model hot-swap.
//!
//! N worker threads serve one frequency. Each worker constructs its own
//! backend *on its thread* via the shared factory (backends may be
//! `!Send` — the PJRT client is), then loops: pull a drain-round from the
//! shared queue (collect-until-deadline dynamic batching), snapshot the
//! current model, execute, reply. Because every worker drains its own
//! round, executions overlap instead of serializing behind one thread.
//!
//! Hot-swap invariants:
//!
//! * the published model lives in a generation-tagged swap slot
//!   ([`reload`](FreqPool::reload) bumps the generation and replaces the
//!   `Arc` atomically under a mutex held for nanoseconds);
//! * a worker snapshots the slot once per drain-round, so every response
//!   in a round is computed from one coherent `ModelState` and tagged
//!   with its generation — a reload racing a round can never mix tensors
//!   from two checkpoints into one answer;
//! * the request queue is independent of the model slot: a reload drops
//!   no queued or in-flight request, and shutdown drains the queue before
//!   the workers exit.
//!
//! Backpressure: the queue is bounded by `ServiceOptions::queue_limit`.
//! A submit that would exceed it returns a typed [`QueueFull`] error
//! immediately (never blocks, never queues) so overload is shed at the
//! door — the HTTP layer maps it to `429` + `Retry-After`.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::{Frequency, NetworkConfig};
use crate::coordinator::ModelState;
use crate::hw;
use crate::runtime::{execute_with_maps, Backend, HostTensor, Manifest,
                     NativeBackend};
use crate::telemetry::registry::{Counter, Gauge, Histogram, Registry};
use crate::telemetry::Quantiles;

use super::api::{check_t0, StaleObservation, UnknownSeries};
use super::state::{SeriesRecord, StateStore};
use super::{pick_batch, plan_batches, ForecastRequest, ForecastResponse,
            ResponseReceiver, ServiceOptions, ServiceStats};

/// Backend constructor shared by all workers of a pool: called once per
/// worker, on the worker's own thread.
pub type BackendFactory =
    Arc<dyn Fn() -> Result<Box<dyn Backend>> + Send + Sync>;

/// Typed backpressure rejection: the pool's queue is at
/// `ServiceOptions::queue_limit`, so this submit was shed instead of
/// queued. Carried as the payload of the returned `anyhow::Error`
/// (`err.is::<QueueFull>()`), which the HTTP layer maps to
/// `429 Too Many Requests` + `Retry-After` — distinct from client
/// mistakes (400) and server faults (500).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull {
    /// The configured queue depth limit that was hit.
    pub limit: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "forecast queue is full ({} pending requests) — retry \
                   later", self.limit)
    }
}

impl std::error::Error for QueueFull {}

/// A model state published under one generation tag. Workers hold the
/// `Arc` for the duration of a drain-round; old generations are freed
/// when the last in-flight round using them completes.
struct VersionedModel {
    generation: u64,
    state: ModelState,
}

/// Result of one observe: where the series' state now stands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObserveOutcome {
    /// Total observations the series has consumed.
    pub observed: u64,
    /// Model generation the state was stamped with.
    pub generation: u64,
    /// True when this observe seeded the series.
    pub new_series: bool,
}

/// One cached stateful forecast. The key triple is
/// `(series, generation, observed)`: an observe bumps `observed`, a
/// reload bumps `generation` — either mismatch is a miss, so stale
/// forecasts can never be served.
struct CachedForecast {
    generation: u64,
    observed: u64,
    forecast: Vec<f32>,
}

struct Job {
    req: ForecastRequest,
    tx: mpsc::Sender<Result<ForecastResponse>>,
    enqueued: Instant,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

#[derive(Default)]
struct StatsInner {
    requests: u64,
    rejected: u64,
    rejected_overload: u64,
    batches: u64,
    padded_slots: u64,
    reloads: u64,
    queue_wait: Quantiles,
    execute: Quantiles,
    total: Quantiles,
    // Backend-global cumulative gauges (spawns / steady allocs /
    // scratch bytes). Workers overwrite these with the backend's latest
    // snapshot after each round — the backend is shared, so summing
    // per-worker deltas would double count.
    backend_spawns: u64,
    backend_steady_allocs: u64,
    backend_scratch_bytes: u64,
    // Observe lane.
    observes: u64,
    observe_new: u64,
    observe_stale: u64,
    cache_hits: u64,
    cache_misses: u64,
    cache_invalidations: u64,
}

/// Registry-facing instruments for one pool, updated on the same code
/// paths as `StatsInner` but with single relaxed atomics — no extra
/// lock traffic on the hot paths. Created unbound at pool start; the
/// sharding layer binds clones into its [`Registry`] under
/// `{shard, freq}` labels when the pool's stack joins the ring.
#[derive(Default)]
struct PoolMetrics {
    submitted: Counter,
    accepted: Counter,
    shed: Counter,
    rejected: Counter,
    batches: Counter,
    padded_slots: Counter,
    reloads: Counter,
    queue_depth: Gauge,
    queue_limit: Gauge,
    workers: Gauge,
    generation: Gauge,
    backend_spawns: Gauge,
    backend_steady_allocs: Gauge,
    backend_scratch_bytes: Gauge,
    queue_wait: Histogram,
    execute: Histogram,
    total: Histogram,
    observes: Counter,
    observe_new: Counter,
    observe_stale: Counter,
    state_series: Gauge,
    state_bytes: Gauge,
    cache_hits: Counter,
    cache_misses: Counter,
    cache_invalidations: Counter,
}

impl PoolMetrics {
    /// Bind every instrument under `{shard, freq}`. Idempotent:
    /// re-binding the same pool replaces its series in place.
    fn bind(&self, reg: &Registry, shard: &str, freq: &str) {
        let l = [("freq", freq), ("shard", shard)];
        reg.register_counter(
            "fesrnn_queue_submitted_total",
            "Validated submits that reached the queue gate (accepted \
             plus shed).",
            &l, &self.submitted);
        reg.register_counter(
            "fesrnn_queue_accepted_total",
            "Requests accepted into the pool queue.",
            &l, &self.accepted);
        reg.register_counter(
            "fesrnn_queue_shed_total",
            "Requests shed at the queue gate with QueueFull (HTTP 429).",
            &l, &self.shed);
        reg.register_counter(
            "fesrnn_queue_rejected_total",
            "Requests rejected before the queue gate (e.g. history \
             shorter than the input window).",
            &l, &self.rejected);
        reg.register_counter(
            "fesrnn_batches_total",
            "Backend executions (one per padded chunk of a drain-round).",
            &l, &self.batches);
        reg.register_counter(
            "fesrnn_padded_slots_total",
            "Batch slots padded to reach a compiled batch size.",
            &l, &self.padded_slots);
        reg.register_counter(
            "fesrnn_reloads_total",
            "Completed model hot-swaps.",
            &l, &self.reloads);
        reg.register_gauge(
            "fesrnn_queue_depth",
            "Accepted-but-undrained requests in the pool queue.",
            &l, &self.queue_depth);
        reg.register_gauge(
            "fesrnn_queue_limit",
            "Configured backpressure limit (0 = unbounded).",
            &l, &self.queue_limit);
        reg.register_gauge(
            "fesrnn_pool_workers",
            "Worker threads serving the pool.",
            &l, &self.workers);
        reg.register_gauge(
            "fesrnn_model_generation",
            "Generation tag of the model currently served.",
            &l, &self.generation);
        reg.register_gauge(
            "fesrnn_backend_spawns",
            "OS threads the backend has spawned since start.",
            &l, &self.backend_spawns);
        reg.register_gauge(
            "fesrnn_backend_steady_allocs",
            "Post-warmup steady-state heap allocations charged to the \
             backend.",
            &l, &self.backend_steady_allocs);
        reg.register_gauge(
            "fesrnn_backend_scratch_bytes",
            "Bytes pinned by the backend's reusable compute arenas.",
            &l, &self.backend_scratch_bytes);
        reg.register_histogram(
            "fesrnn_queue_wait_seconds",
            "Enqueue to drain-round pickup.",
            &l, &self.queue_wait);
        reg.register_histogram(
            "fesrnn_execute_seconds",
            "Backend execution time attributed to each request.",
            &l, &self.execute);
        reg.register_histogram(
            "fesrnn_request_total_seconds",
            "Enqueue to response sent.",
            &l, &self.total);
        reg.register_counter(
            "fesrnn_observe_requests_total",
            "Observe requests processed (accepted + rejected).",
            &l, &self.observes);
        reg.register_counter(
            "fesrnn_observe_new_series_total",
            "Observes that seeded a brand-new series state.",
            &l, &self.observe_new);
        reg.register_counter(
            "fesrnn_observe_stale_total",
            "Observes rejected because the batch rewound time (HTTP 409).",
            &l, &self.observe_stale);
        reg.register_gauge(
            "fesrnn_state_series",
            "Series with live ES state in the store.",
            &l, &self.state_series);
        reg.register_gauge(
            "fesrnn_state_bytes",
            "State-store slab footprint in bytes.",
            &l, &self.state_bytes);
        reg.register_counter(
            "fesrnn_state_cache_hits_total",
            "Stateful forecasts served from the per-series cache.",
            &l, &self.cache_hits);
        reg.register_counter(
            "fesrnn_state_cache_misses_total",
            "Stateful forecasts recomputed (cold or invalidated key).",
            &l, &self.cache_misses);
        reg.register_counter(
            "fesrnn_state_cache_invalidations_total",
            "Forecast cache entries dropped by an observe.",
            &l, &self.cache_invalidations);
    }
}

/// State shared between the pool handle(s) and the worker threads.
///
/// Lock discipline: `queue`, `model` and `stats` are three independent
/// mutexes and no code path holds two at once (the queue lock is released
/// before stats are recorded; the model lock only guards the `Arc` swap).
pub(crate) struct PoolShared {
    net: NetworkConfig,
    opts: ServiceOptions,
    // lint:lock-name(fcpool.queue)
    queue: Mutex<QueueInner>,
    cond: Condvar,
    // lint:lock-name(fcpool.model)
    model: Mutex<Arc<VersionedModel>>,
    // lint:lock-name(fcpool.stats)
    stats: Mutex<StatsInner>,
    metrics: PoolMetrics,
    /// Per-series ES state (its own internal lock, `state.slab`).
    state: Arc<StateStore>,
    /// Stateful forecast cache, keyed by series id; entries carry the
    /// `(generation, observed)` half of the invalidation key.
    // lint:lock-name(fcpool.fcache)
    fcache: Mutex<HashMap<String, CachedForecast>>,
}

impl PoolShared {
    fn submit(&self, req: ForecastRequest) -> Result<ResponseReceiver> {
        let (tx, rx) = mpsc::channel();
        let c = self.net.length;
        if req.values.len() < c {
            // Reject at the door: a short request must not poison the
            // batch it would have ridden in with its error.
            self.stats.lock().unwrap().rejected += 1;
            self.metrics.rejected.inc();
            let _ = tx.send(Err(anyhow!(
                "request `{}`: need ≥ {c} values, got {}", req.id,
                req.values.len())));
            return Ok(rx);
        }
        {
            let mut q = self.queue.lock().unwrap();
            if q.shutdown {
                bail!("forecast service is down");
            }
            let limit = self.opts.queue_limit;
            if limit > 0 && q.jobs.len() >= limit {
                // Backpressure: shed this request instead of queueing it
                // behind work we cannot keep up with — the caller gets a
                // typed QueueFull (HTTP 429) immediately, and the
                // requests already queued keep their latency budget.
                drop(q);
                self.stats.lock().unwrap().rejected_overload += 1;
                self.metrics.submitted.inc();
                self.metrics.shed.inc();
                return Err(QueueFull { limit }.into());
            }
            q.jobs.push_back(Job { req, tx, enqueued: Instant::now() });
            self.metrics.queue_depth.set(q.jobs.len() as u64);
        }
        self.stats.lock().unwrap().requests += 1;
        self.metrics.submitted.inc();
        self.metrics.accepted.inc();
        self.cond.notify_one();
        Ok(rx)
    }

    /// Block until a drain-round is available (dynamic batching: hold the
    /// first request up to `batch_window` while more arrive, capped at
    /// `max_batch`). Returns `None` only at shutdown *with an empty
    /// queue* — pending requests are always served first.
    fn next_round(&self) -> Option<(Vec<Job>, Instant)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if !q.jobs.is_empty() {
                break;
            }
            if q.shutdown {
                return None;
            }
            q = self.cond.wait(q).unwrap();
        }
        let deadline = Instant::now() + self.opts.batch_window;
        while q.jobs.len() < self.opts.max_batch && !q.shutdown {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) =
                self.cond.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = q.jobs.len().min(self.opts.max_batch);
        let jobs: Vec<Job> = q.jobs.drain(..take).collect();
        let more = !q.jobs.is_empty();
        self.metrics.queue_depth.set(q.jobs.len() as u64);
        drop(q);
        if more {
            // Work conservation: the submit-side notifications that
            // accumulated while we collected this round may all have
            // landed on us — wake a sibling for the remainder.
            self.cond.notify_one();
        }
        Some((jobs, Instant::now()))
    }

    fn current_model(&self) -> Arc<VersionedModel> {
        self.model.lock().unwrap().clone()
    }

    /// Advance one series' ES recurrence over a batch of new
    /// observations — synchronous and µs-scale (a handful of FLOPs per
    /// point), so it bypasses the batching queue entirely. A first
    /// observe seeds the state from the batch
    /// ([`hw::es_state_seed`]); later observes continue the recurrence
    /// bit-identically to re-filtering the full history. On success the
    /// series' cached forecast is invalidated.
    fn observe(&self, id: &str, values: &[f32], t0: Option<u64>)
               -> Result<ObserveOutcome> {
        self.stats.lock().unwrap().observes += 1;
        self.metrics.observes.inc();
        if values.is_empty() {
            bail!("observe for `{id}` carries no values");
        }
        let generation = self.current_model().generation;
        let (s1, s2) = (self.net.seasonality, self.net.seasonality2);
        let result = self.state.update(id, |cur| match cur {
            None => {
                check_t0(t0, 0)?;
                Ok(SeriesRecord {
                    state: hw::es_state_seed(values, s1, s2),
                    generation,
                })
            }
            Some(mut rec) => {
                check_t0(t0, rec.state.observed)?;
                rec.state.advance(values, hw::INIT_ALPHA, hw::INIT_GAMMA,
                                  hw::INIT_GAMMA);
                rec.generation = generation;
                Ok(rec)
            }
        });
        match result {
            Ok((rec, new_series)) => {
                let invalidated =
                    self.fcache.lock().unwrap().remove(id).is_some();
                {
                    let mut s = self.stats.lock().unwrap();
                    if new_series {
                        s.observe_new += 1;
                    }
                    if invalidated {
                        s.cache_invalidations += 1;
                    }
                }
                if new_series {
                    self.metrics.observe_new.inc();
                }
                if invalidated {
                    self.metrics.cache_invalidations.inc();
                }
                self.metrics.state_series.set(self.state.series() as u64);
                self.metrics.state_bytes.set(self.state.bytes());
                Ok(ObserveOutcome {
                    observed: rec.state.observed,
                    generation: rec.generation,
                    new_series,
                })
            }
            Err(e) => {
                if e.is::<StaleObservation>() {
                    self.stats.lock().unwrap().observe_stale += 1;
                    self.metrics.observe_stale.inc();
                }
                Err(e)
            }
        }
    }

    /// The stored state for one series, or a typed [`UnknownSeries`].
    fn series_record(&self, id: &str) -> Result<SeriesRecord> {
        self.state.get(id)?.ok_or_else(|| {
            anyhow::Error::new(UnknownSeries { id: id.to_string() })
        })
    }

    /// Stateful forecast: the Holt-Winters h-step forecast off the
    /// series' live state — no queue, no RNN pass, no history replay.
    /// Cached per series under the `(generation, observed)` key.
    fn series_forecast(&self, id: &str) -> Result<ForecastResponse> {
        let generation = self.current_model().generation;
        let rec = self.series_record(id)?;
        let observed = rec.state.observed;
        {
            let cache = self.fcache.lock().unwrap();
            if let Some(hit) = cache.get(id) {
                if hit.generation == generation && hit.observed == observed {
                    let forecast = hit.forecast.clone();
                    drop(cache);
                    self.stats.lock().unwrap().cache_hits += 1;
                    self.metrics.cache_hits.inc();
                    return Ok(ForecastResponse {
                        id: id.to_string(),
                        forecast,
                        generation,
                    });
                }
            }
        }
        let forecast = rec.state.forecast(self.net.horizon);
        self.fcache.lock().unwrap().insert(
            id.to_string(),
            CachedForecast {
                generation,
                observed,
                forecast: forecast.clone(),
            },
        );
        self.stats.lock().unwrap().cache_misses += 1;
        self.metrics.cache_misses.inc();
        Ok(ForecastResponse { id: id.to_string(), forecast, generation })
    }

    fn reload(&self, state: ModelState) -> u64 {
        let mut slot = self.model.lock().unwrap();
        let generation = slot.generation + 1;
        *slot = Arc::new(VersionedModel { generation, state });
        drop(slot);
        self.stats.lock().unwrap().reloads += 1;
        self.metrics.reloads.inc();
        self.metrics.generation.set(generation);
        generation
    }

    fn begin_shutdown(&self) {
        self.queue.lock().unwrap().shutdown = true;
        self.cond.notify_all();
    }

    fn stats_snapshot(&self) -> ServiceStats {
        let generation = self.current_model().generation;
        // Sequential acquisitions — the lock discipline (never two locks
        // at once) holds; the depth gauge and the counters may be one
        // submit apart, which is fine for monitoring.
        let queue_depth = self.queue.lock().unwrap().jobs.len();
        let state_series = self.state.series() as u64;
        let state_bytes = self.state.bytes();
        let s = self.stats.lock().unwrap();
        ServiceStats {
            requests: s.requests,
            rejected: s.rejected,
            rejected_overload: s.rejected_overload,
            batches: s.batches,
            padded_slots: s.padded_slots,
            reloads: s.reloads,
            generation,
            workers: self.opts.workers,
            queue_depth,
            queue_limit: self.opts.queue_limit,
            queue_wait: s.queue_wait.summary(),
            execute: s.execute.summary(),
            total: s.total.summary(),
            backend_spawns: s.backend_spawns,
            backend_steady_allocs: s.backend_steady_allocs,
            backend_scratch_bytes: s.backend_scratch_bytes,
            observe_requests: s.observes,
            observe_new_series: s.observe_new,
            observe_stale: s.observe_stale,
            state_series,
            state_bytes,
            state_cache_hits: s.cache_hits,
            state_cache_misses: s.cache_misses,
            state_cache_invalidations: s.cache_invalidations,
        }
    }
}

/// Clonable client handle to a running pool, usable from any thread.
#[derive(Clone)]
pub struct ForecastHandle {
    shared: Arc<PoolShared>,
}

impl ForecastHandle {
    /// Blocking single forecast.
    pub fn forecast(&self, req: ForecastRequest) -> Result<ForecastResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("forecast service dropped reply"))?
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit(&self, req: ForecastRequest) -> Result<ResponseReceiver> {
        self.shared.submit(req)
    }

    /// Advance a series' ES state on new observations (synchronous; no
    /// queue — see [`PoolShared::observe`]).
    pub fn observe(&self, id: &str, values: &[f32], t0: Option<u64>)
                   -> Result<ObserveOutcome> {
        self.shared.observe(id, values, t0)
    }

    /// Stateful Holt-Winters forecast from the series' stored state.
    pub fn series_forecast(&self, id: &str) -> Result<ForecastResponse> {
        self.shared.series_forecast(id)
    }

    /// The stored state record for a series.
    pub fn series_record(&self, id: &str) -> Result<SeriesRecord> {
        self.shared.series_record(id)
    }

    pub fn stats(&self) -> Result<ServiceStats> {
        Ok(self.shared.stats_snapshot())
    }

    /// Publish a new model; workers adopt it at their next drain-round.
    /// Returns the new generation tag.
    pub fn reload(&self, state: ModelState) -> u64 {
        self.shared.reload(state)
    }

    /// Generation currently being served.
    pub fn generation(&self) -> u64 {
        self.shared.current_model().generation
    }

    pub fn freq(&self) -> Frequency {
        self.shared.net.freq
    }

    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }
}

/// N worker threads serving one frequency from a shared dynamic-batching
/// queue, with generation-tagged model hot-swap.
pub struct FreqPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl FreqPool {
    /// Start `opts.workers` threads, each constructing its own backend
    /// via `factory` on its thread. Fails (and tears the pool down) if
    /// any worker's backend fails to construct.
    pub fn start(factory: BackendFactory, freq: Frequency, state: ModelState,
                 opts: ServiceOptions) -> Result<Self> {
        let net = NetworkConfig::for_freq(freq)?;
        let n_workers = opts.workers.max(1);
        // Durable state slab under <state_dir>/<freq>/ when configured;
        // otherwise in-memory (observes work, state dies with the
        // process).
        let series_state = match &opts.state_dir {
            Some(dir) => Arc::new(StateStore::open(
                &dir.join(freq.name()), net.seasonality,
                net.seasonality2)?),
            None => Arc::new(StateStore::in_memory(net.seasonality,
                                                   net.seasonality2)),
        };
        let shared = Arc::new(PoolShared {
            net,
            opts: ServiceOptions { workers: n_workers, ..opts },
            queue: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cond: Condvar::new(),
            model: Mutex::new(Arc::new(VersionedModel {
                generation: 1,
                state,
            })),
            stats: Mutex::new(StatsInner::default()),
            metrics: PoolMetrics::default(),
            state: series_state,
            fcache: Mutex::new(HashMap::new()),
        });
        shared.metrics.queue_limit.set(shared.opts.queue_limit as u64);
        shared.metrics.workers.set(n_workers as u64);
        shared.metrics.generation.set(1);
        shared.metrics.state_series.set(shared.state.series() as u64);
        shared.metrics.state_bytes.set(shared.state.bytes());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let shared_w = Arc::clone(&shared);
            let factory_w = Arc::clone(&factory);
            let ready_w = ready_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("forecast-{}-{w}", freq.name()))
                .spawn(move || match (factory_w.as_ref())() {
                    Ok(backend) => {
                        let _ = ready_w.send(Ok(()));
                        // Release the readiness channel before serving:
                        // if a *sibling* worker's factory panics (sends
                        // nothing), start() must see the channel
                        // disconnect instead of blocking on a sender
                        // parked here for the pool's whole lifetime.
                        drop(ready_w);
                        worker_loop(&shared_w, backend.as_ref());
                    }
                    Err(e) => {
                        let _ = ready_w.send(Err(e));
                    }
                })?;
            workers.push(join);
        }
        drop(ready_tx);
        for _ in 0..n_workers {
            let up = ready_rx
                .recv()
                .map_err(|_| anyhow!("worker thread died during startup"))
                .and_then(|r| r);
            if let Err(e) = up {
                shared.begin_shutdown();
                for j in workers {
                    let _ = j.join();
                }
                return Err(e);
            }
        }
        Ok(Self { shared, workers })
    }

    /// Start on the pure-Rust native backend (no artifacts needed).
    pub fn start_native(freq: Frequency, state: ModelState,
                        opts: ServiceOptions) -> Result<Self> {
        Self::start(
            Arc::new(|| Ok(Box::new(NativeBackend::new()) as Box<dyn Backend>)),
            freq, state, opts,
        )
    }

    pub fn handle(&self) -> ForecastHandle {
        ForecastHandle { shared: Arc::clone(&self.shared) }
    }

    pub fn freq(&self) -> Frequency {
        self.shared.net.freq
    }

    pub fn net(&self) -> &NetworkConfig {
        &self.shared.net
    }

    /// Publish a new model; returns the new generation tag.
    pub fn reload(&self, state: ModelState) -> u64 {
        self.shared.reload(state)
    }

    pub fn generation(&self) -> u64 {
        self.shared.current_model().generation
    }

    pub fn stats(&self) -> ServiceStats {
        self.shared.stats_snapshot()
    }

    /// Advance a series' ES state on new observations.
    pub fn observe(&self, id: &str, values: &[f32], t0: Option<u64>)
                   -> Result<ObserveOutcome> {
        self.shared.observe(id, values, t0)
    }

    /// Stateful forecast from the series' stored ES state.
    pub fn series_forecast(&self, id: &str) -> Result<ForecastResponse> {
        self.shared.series_forecast(id)
    }

    /// The stored state record for one series.
    pub fn series_record(&self, id: &str) -> Result<SeriesRecord> {
        self.shared.series_record(id)
    }

    /// The pool's per-series state store (checkpoint sidecars, tests).
    pub fn state_store(&self) -> &Arc<StateStore> {
        &self.shared.state
    }

    /// Bind this pool's registry instruments under `{shard, freq}`
    /// labels — called by the sharding layer when the pool's stack
    /// joins a ring (and again, idempotently, if it rejoins).
    pub fn bind_metrics(&self, reg: &Registry, shard: &str) {
        self.shared.metrics.bind(reg, shard, self.shared.net.freq.name());
    }
}

impl Drop for FreqPool {
    fn drop(&mut self) {
        self.shared.begin_shutdown();
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

/// One worker: pull drain-rounds until shutdown+empty, snapshot the model
/// once per round, split the round into executions, reply per chunk.
fn worker_loop(shared: &PoolShared, backend: &dyn Backend) {
    let freq = shared.net.freq.name().to_string();
    let available = backend.manifest().available_batches(&freq, "predict");
    while let Some((jobs, drained_at)) = shared.next_round() {
        let model = shared.current_model();
        let mut round_batches = 0u64;
        let mut round_padded = 0u64;
        // (chunk length, execute secs, chunk completion) — stats are
        // flushed under one lock after the round so the reply hot path
        // never contends on the stats mutex.
        let mut chunks: Vec<(usize, f64, Instant)> = Vec::new();
        let mut start = 0usize;
        for real in plan_batches(&available, jobs.len()) {
            let chunk = &jobs[start..start + real];
            round_batches += 1;
            let t0 = Instant::now();
            match execute_chunk(backend, &shared.net, &model.state,
                                &available, chunk) {
                Ok((forecasts, padded)) => {
                    round_padded += padded as u64;
                    for (job, fc) in chunk.iter().zip(forecasts) {
                        let _ = job.tx.send(Ok(ForecastResponse {
                            id: job.req.id.clone(),
                            forecast: fc,
                            generation: model.generation,
                        }));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for job in chunk {
                        let _ = job.tx.send(Err(anyhow!("{msg}")));
                    }
                }
            }
            chunks.push((real, t0.elapsed().as_secs_f64(), Instant::now()));
            start += real;
        }
        // Snapshot the backend's steady-state gauges before taking the
        // pool stats lock (the snapshot touches the backend's own locks).
        let bstats = backend.stats();
        let m = &shared.metrics;
        m.backend_spawns.set(bstats.spawns);
        m.backend_steady_allocs.set(bstats.steady_allocs);
        m.backend_scratch_bytes.set(bstats.scratch_bytes);
        m.batches.add(round_batches);
        m.padded_slots.add(round_padded);
        let mut s = shared.stats.lock().unwrap();
        s.backend_spawns = bstats.spawns;
        s.backend_steady_allocs = bstats.steady_allocs;
        s.backend_scratch_bytes = bstats.scratch_bytes;
        s.batches += round_batches;
        s.padded_slots += round_padded;
        let mut job_i = 0usize;
        for (len, exec_secs, done) in chunks {
            for _ in 0..len {
                let job = &jobs[job_i];
                job_i += 1;
                let wait =
                    drained_at.duration_since(job.enqueued).as_secs_f64();
                let total =
                    done.duration_since(job.enqueued).as_secs_f64();
                s.queue_wait.record(wait);
                s.execute.record(exec_secs);
                s.total.record(total);
                m.queue_wait.observe(wait);
                m.execute.observe(exec_secs);
                m.total.observe(total);
            }
        }
    }
}

/// Execute one chunk of a drain-round: pad up to the smallest fitting
/// predict program, assemble `data.*` plus per-request primer parameters,
/// run the backend, slice the forecasts back out. Returns the forecasts
/// and the number of padded slots.
fn execute_chunk(backend: &dyn Backend, net: &NetworkConfig,
                 state: &ModelState, available: &[usize], jobs: &[Job])
                 -> Result<(Vec<Vec<f32>>, usize)> {
    let n = jobs.len();
    let b = pick_batch(available, n);
    let c = net.length;
    let h = net.horizon;
    let padded = b - n.min(b);

    // Assemble y/cat plus per-request primer parameters.
    let mut y = Vec::with_capacity(b * c);
    let mut cat = vec![0.0f32; b * 6];
    let mut inputs: HashMap<String, HostTensor> = HashMap::new();
    let s_width = net.total_seasonality();
    let mut alpha = Vec::with_capacity(b);
    let mut gamma = Vec::with_capacity(b);
    let mut gamma2 = Vec::with_capacity(b);
    let mut s_init = Vec::with_capacity(b * s_width);
    for slot in 0..b {
        let req = &jobs[slot.min(n - 1)].req;
        if req.values.len() < c {
            // Defensive: submit() already rejects short histories.
            bail!("request `{}`: need ≥ {c} values, got {}", req.id,
                  req.values.len());
        }
        let window = &req.values[req.values.len() - c..];
        y.extend_from_slice(window);
        cat[slot * 6 + req.category.index()] = 1.0;
        let p = hw::primer_for(window, net.seasonality, net.seasonality2);
        alpha.push(p.alpha_logit);
        gamma.push(p.gamma_logit);
        gamma2.push(p.gamma2_logit);
        s_init.extend_from_slice(&p.log_s_init);
    }
    inputs.insert("data.y".into(), HostTensor::new(vec![b, c], y)?);
    inputs.insert("data.cat".into(), HostTensor::new(vec![b, 6], cat)?);
    inputs.insert("params.series.alpha_logit".into(),
                  HostTensor::new(vec![b], alpha)?);
    inputs.insert("params.series.gamma_logit".into(),
                  HostTensor::new(vec![b], gamma)?);
    inputs.insert("params.series.gamma2_logit".into(),
                  HostTensor::new(vec![b], gamma2)?);
    inputs.insert("params.series.log_s_init".into(),
                  HostTensor::new(vec![b, s_width], s_init)?);

    let name = Manifest::program_name(net.freq.name(), b, "predict");
    let outs = execute_with_maps(backend, &name, &inputs, &state.tensors)?;
    let fc = &outs[0].1;
    let forecasts =
        (0..n).map(|i| fc.data[i * h..(i + 1) * h].to_vec()).collect();
    Ok((forecasts, padded))
}
