//! Typed wire DTOs for the `/v1` serving API.
//!
//! One definition per request/response shape, shared by the HTTP server
//! handlers, `RemoteShard` (the internal client), the CLI demo, benches
//! and integration tests — replacing the hand-rolled `Json::obj` /
//! `doc.get(..)` sites that had drifted apart since PR 4. Each DTO owns
//! both directions (`to_json` / `from_json`), so a shape change is one
//! edit and every producer/consumer moves together.
//!
//! Field names here ARE the wire contract: `util::json` serializes
//! objects in sorted key order, so `to_json(..).to_string()` is
//! byte-deterministic — which the PR-8 alias conformance checks
//! (byte-identical legacy vs `/v1` payloads) rely on.

use std::fmt;

use anyhow::{Context, Result};

use crate::config::{Category, Frequency};
use crate::util::json::Json;

/// `POST /v1/series/{id}/forecast` (and the deprecated `/v1/forecast`
/// alias) request body. `id` is optional only on the alias — the
/// resource route carries it in the path.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastRequest {
    /// Omitted when the server serves a single frequency.
    pub freq: Option<Frequency>,
    pub id: Option<String>,
    pub category: Option<Category>,
    pub values: Vec<f32>,
}

impl ForecastRequest {
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(f) = self.freq {
            fields.push(("freq", Json::str(f.name())));
        }
        if let Some(id) = &self.id {
            fields.push(("id", Json::str(id.as_str())));
        }
        if let Some(c) = self.category {
            fields.push(("category", Json::str(c.name())));
        }
        fields.push(("values", Json::arr_f32(&self.values)));
        Json::obj(fields)
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(ForecastRequest {
            freq: match doc.opt("freq") {
                Some(j) => Some(Frequency::parse(j.as_str()?)?),
                None => None,
            },
            id: match doc.opt("id") {
                Some(j) => Some(j.as_str()?.to_string()),
                None => None,
            },
            category: match doc.opt("category") {
                Some(j) => Some(Category::parse(j.as_str()?)?),
                None => None,
            },
            values: doc.get("values")?.as_f32_vec()?,
        })
    }
}

/// Forecast response body: `{id, freq, generation, forecast}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastResponse {
    pub id: String,
    pub freq: Frequency,
    pub generation: u64,
    pub forecast: Vec<f32>,
}

impl ForecastResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.as_str())),
            ("freq", Json::str(self.freq.name())),
            ("generation", Json::num(self.generation as f64)),
            ("forecast", Json::arr_f32(&self.forecast)),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(ForecastResponse {
            id: doc.get("id")?.as_str()?.to_string(),
            freq: Frequency::parse(doc.get("freq")?.as_str()?)?,
            generation: doc.get("generation")?.as_f64()? as u64,
            forecast: doc.get("forecast")?.as_f32_vec()?,
        })
    }
}

/// `POST /v1/series/{id}/observe` request body. `t0`, when present, is
/// the absolute time index of `values[0]` — the server rejects
/// observations that would rewind (`stale_observation`) or skip ahead
/// of the stored state.
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveRequest {
    pub freq: Option<Frequency>,
    pub values: Vec<f32>,
    pub t0: Option<u64>,
}

impl ObserveRequest {
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(f) = self.freq {
            fields.push(("freq", Json::str(f.name())));
        }
        if let Some(t0) = self.t0 {
            fields.push(("t0", Json::num(t0 as f64)));
        }
        fields.push(("values", Json::arr_f32(&self.values)));
        Json::obj(fields)
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(ObserveRequest {
            freq: match doc.opt("freq") {
                Some(j) => Some(Frequency::parse(j.as_str()?)?),
                None => None,
            },
            values: doc.get("values")?.as_f32_vec()?,
            t0: match doc.opt("t0") {
                Some(j) => Some(j.as_f64()? as u64),
                None => None,
            },
        })
    }
}

/// Observe response body:
/// `{id, freq, observed, generation, new_series}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveResponse {
    pub id: String,
    pub freq: Frequency,
    /// Total observations consumed for this series so far.
    pub observed: u64,
    pub generation: u64,
    /// True when this observe seeded the series' state.
    pub new_series: bool,
}

impl ObserveResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.as_str())),
            ("freq", Json::str(self.freq.name())),
            ("observed", Json::num(self.observed as f64)),
            ("generation", Json::num(self.generation as f64)),
            ("new_series", Json::Bool(self.new_series)),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(ObserveResponse {
            id: doc.get("id")?.as_str()?.to_string(),
            freq: Frequency::parse(doc.get("freq")?.as_str()?)?,
            observed: doc.get("observed")?.as_f64()? as u64,
            generation: doc.get("generation")?.as_f64()? as u64,
            new_series: doc.get("new_series")?.as_bool()?,
        })
    }
}

/// `GET /v1/series/{id}/state` response body — the live ES state, with
/// the seasonal rings in *phase order* (`seasonality[p]` is the value
/// for time indices `t ≡ p (mod S)`).
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesState {
    pub id: String,
    pub freq: Frequency,
    pub observed: u64,
    pub generation: u64,
    pub level: f32,
    pub seasonality: Vec<f32>,
    /// Empty unless the frequency is dual-seasonal (hourly).
    pub seasonality2: Vec<f32>,
}

impl SeriesState {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.as_str())),
            ("freq", Json::str(self.freq.name())),
            ("observed", Json::num(self.observed as f64)),
            ("generation", Json::num(self.generation as f64)),
            ("level", Json::num(self.level as f64)),
            ("seasonality", Json::arr_f32(&self.seasonality)),
            ("seasonality2", Json::arr_f32(&self.seasonality2)),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(SeriesState {
            id: doc.get("id")?.as_str()?.to_string(),
            freq: Frequency::parse(doc.get("freq")?.as_str()?)?,
            observed: doc.get("observed")?.as_f64()? as u64,
            generation: doc.get("generation")?.as_f64()? as u64,
            level: doc.get("level")?.as_f32()?,
            seasonality: doc.get("seasonality")?.as_f32_vec()?,
            seasonality2: doc.get("seasonality2")?.as_f32_vec()?,
        })
    }
}

/// `POST /v1/reload` request body.
#[derive(Debug, Clone, PartialEq)]
pub struct ReloadRequest {
    pub freq: Option<Frequency>,
    pub checkpoint: String,
}

impl ReloadRequest {
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(f) = self.freq {
            fields.push(("freq", Json::str(f.name())));
        }
        fields.push(("checkpoint", Json::str(self.checkpoint.as_str())));
        Json::obj(fields)
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        Ok(ReloadRequest {
            freq: match doc.opt("freq") {
                Some(j) => Some(Frequency::parse(j.as_str()?)?),
                None => None,
            },
            checkpoint: doc.get("checkpoint")?.as_str()?.to_string(),
        })
    }
}

/// The unified `/v1` error envelope:
/// `{"error": {"code", "message", "retry_after_ms"?}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorEnvelope {
    pub code: String,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl ErrorEnvelope {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::str(self.code.as_str())),
            ("message", Json::str(self.message.as_str())),
        ];
        if let Some(ms) = self.retry_after_ms {
            fields.push(("retry_after_ms", Json::num(ms as f64)));
        }
        Json::obj(vec![("error", Json::obj(fields))])
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        let err = doc.get("error").context("error envelope")?;
        Ok(ErrorEnvelope {
            code: err.get("code")?.as_str()?.to_string(),
            message: err.get("message")?.as_str()?.to_string(),
            retry_after_ms: match err.opt("retry_after_ms") {
                Some(j) => Some(j.as_f64()? as u64),
                None => None,
            },
        })
    }
}

/// Typed service error: the requested series has no stored state.
/// Surfaces as HTTP 404 with envelope code `unknown_series`.
#[derive(Debug, Clone, PartialEq)]
pub struct UnknownSeries {
    pub id: String,
}

impl fmt::Display for UnknownSeries {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "series '{}' has no stored state — POST an observe \
                   first", self.id)
    }
}

impl std::error::Error for UnknownSeries {}

/// Typed service error: the observation batch starts at or before a
/// time index the series has already consumed. Surfaces as HTTP 409
/// with envelope code `stale_observation`.
#[derive(Debug, Clone, PartialEq)]
pub struct StaleObservation {
    /// Observations already consumed (the next accepted `t0`).
    pub observed: u64,
    /// The rejected batch's start index.
    pub t0: u64,
}

impl fmt::Display for StaleObservation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "observation batch starts at t0={} but the series has \
                   already consumed {} observations", self.t0, self.observed)
    }
}

impl std::error::Error for StaleObservation {}

/// Typed service error: the observation batch starts *past* the stored
/// progress — accepting it would silently skip the gap. Surfaces as
/// HTTP 400 (`bad_request`): unlike a stale replay, a gap is a client
/// bug, not a retryable race.
#[derive(Debug, Clone, PartialEq)]
pub struct ObservationGap {
    /// Observations already consumed (the next accepted `t0`).
    pub observed: u64,
    /// The rejected batch's start index.
    pub t0: u64,
}

impl fmt::Display for ObservationGap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "observation batch starts at t0={} but only {} \
                   observations are stored — refusing to skip the gap",
               self.t0, self.observed)
    }
}

impl std::error::Error for ObservationGap {}

/// Validate an observe batch's `t0` against the stored progress.
/// `Ok(())` means the batch appends cleanly at `observed`.
pub fn check_t0(t0: Option<u64>, observed: u64) -> Result<()> {
    match t0 {
        None => Ok(()),
        Some(t) if t == observed => Ok(()),
        Some(t) if t < observed => {
            Err(anyhow::Error::new(StaleObservation { observed, t0: t }))
        }
        Some(t) => {
            Err(anyhow::Error::new(ObservationGap { observed, t0: t }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forecast_request_round_trips() {
        let req = ForecastRequest {
            freq: Some(Frequency::Quarterly),
            id: Some("q-1".into()),
            category: Some(Category::Macro),
            values: vec![1.0, 2.5, 3.0],
        };
        let back =
            ForecastRequest::from_json(&req.to_json()).expect("round trip");
        assert_eq!(req, back);
        // Optional fields really are optional on the wire.
        let min = ForecastRequest {
            freq: None,
            id: None,
            category: None,
            values: vec![9.0],
        };
        let j = min.to_json();
        assert!(j.opt("freq").is_none() && j.opt("id").is_none());
        assert_eq!(ForecastRequest::from_json(&j).expect("min"), min);
    }

    #[test]
    fn observe_and_state_round_trip() {
        let obs = ObserveRequest {
            freq: Some(Frequency::Monthly),
            values: vec![5.0; 4],
            t0: Some(120),
        };
        assert_eq!(ObserveRequest::from_json(&obs.to_json()).expect("obs"),
                   obs);
        let st = SeriesState {
            id: "m1".into(),
            freq: Frequency::Monthly,
            observed: 124,
            generation: 3,
            level: 101.5,
            seasonality: vec![0.9; 12],
            seasonality2: vec![],
        };
        assert_eq!(SeriesState::from_json(&st.to_json()).expect("state"),
                   st);
    }

    #[test]
    fn error_envelope_round_trips() {
        let env = ErrorEnvelope {
            code: "queue_full".into(),
            message: "busy".into(),
            retry_after_ms: Some(1000),
        };
        assert_eq!(ErrorEnvelope::from_json(&env.to_json()).expect("env"),
                   env);
        assert_eq!(
            env.to_json().to_string(),
            r#"{"error":{"code":"queue_full","message":"busy","retry_after_ms":1000}}"#
        );
    }

    #[test]
    fn t0_contract() {
        assert!(check_t0(None, 7).is_ok());
        assert!(check_t0(Some(7), 7).is_ok());
        let stale = check_t0(Some(3), 7).expect_err("stale");
        assert!(stale.is::<StaleObservation>());
        let gap = check_t0(Some(9), 7).expect_err("gap");
        assert!(!gap.is::<StaleObservation>());
        assert!(gap.is::<ObservationGap>());
    }
}
