//! Multi-frequency router: one [`ServingStack`] owns a [`FreqPool`] per
//! trained frequency, dispatches requests by frequency, and exposes the
//! generation-tagged hot-swap API (including checkpoint reloads in either
//! persistence format — see `coordinator::checkpoint`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::config::Frequency;
use crate::coordinator::{checkpoint, ModelState};
use crate::telemetry::registry::Registry;

use super::pool::{BackendFactory, ForecastHandle, FreqPool, ObserveOutcome};
use super::state::SeriesRecord;
use super::{ForecastRequest, ForecastResponse, ResponseReceiver,
            ServiceOptions, ServiceStats};

/// The serving router: pools for all trained frequencies. Construct
/// empty, [`start_pool`](Self::start_pool) each frequency, then share
/// behind an `Arc` (all methods take `&self`; the pools' own locks do the
/// synchronization).
#[derive(Default)]
pub struct ServingStack {
    pools: BTreeMap<Frequency, FreqPool>,
}

impl ServingStack {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a pool for `freq` serving `state`. One pool per frequency;
    /// starting a second is an error (reload instead).
    pub fn start_pool(&mut self, factory: BackendFactory, freq: Frequency,
                      state: ModelState, opts: ServiceOptions) -> Result<()> {
        if self.pools.contains_key(&freq) {
            bail!("a {} pool is already running — use reload to swap its \
                   model", freq.name());
        }
        let pool = FreqPool::start(factory, freq, state, opts)?;
        self.pools.insert(freq, pool);
        Ok(())
    }

    /// Start a native-backend pool (no artifacts needed).
    pub fn start_pool_native(&mut self, freq: Frequency, state: ModelState,
                             opts: ServiceOptions) -> Result<()> {
        use crate::runtime::{Backend, NativeBackend};
        self.start_pool(
            std::sync::Arc::new(|| {
                Ok(Box::new(NativeBackend::new()) as Box<dyn Backend>)
            }),
            freq, state, opts,
        )
    }

    pub fn frequencies(&self) -> Vec<Frequency> {
        self.pools.keys().copied().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }

    /// The stack's only frequency, when exactly one pool is running —
    /// lets single-model deployments omit `freq` on the wire.
    pub fn single_frequency(&self) -> Option<Frequency> {
        if self.pools.len() == 1 {
            self.pools.keys().next().copied()
        } else {
            None
        }
    }

    fn pool(&self, freq: Frequency) -> Result<&FreqPool> {
        self.pools.get(&freq).ok_or_else(|| {
            anyhow!("no {} pool is running (serving: {})", freq.name(),
                    self.pools
                        .keys()
                        .map(|f| f.name())
                        .collect::<Vec<_>>()
                        .join(", "))
        })
    }

    /// Clonable handle to one frequency's pool.
    pub fn handle(&self, freq: Frequency) -> Result<ForecastHandle> {
        Ok(self.pool(freq)?.handle())
    }

    /// Blocking forecast, routed by frequency.
    pub fn forecast(&self, freq: Frequency, req: ForecastRequest)
                    -> Result<ForecastResponse> {
        self.pool(freq)?.handle().forecast(req)
    }

    /// Non-blocking submit, routed by frequency.
    pub fn submit(&self, freq: Frequency, req: ForecastRequest)
                  -> Result<ResponseReceiver> {
        self.pool(freq)?.handle().submit(req)
    }

    /// Advance one series' ES state on new observations, routed by
    /// frequency. Synchronous — no batching queue (see
    /// [`FreqPool::observe`]).
    pub fn observe(&self, freq: Frequency, id: &str, values: &[f32],
                   t0: Option<u64>) -> Result<ObserveOutcome> {
        self.pool(freq)?.observe(id, values, t0)
    }

    /// Stateful forecast from a series' stored ES state.
    pub fn series_forecast(&self, freq: Frequency, id: &str)
                           -> Result<ForecastResponse> {
        self.pool(freq)?.series_forecast(id)
    }

    /// The stored state record for one series.
    pub fn series_record(&self, freq: Frequency, id: &str)
                         -> Result<SeriesRecord> {
        self.pool(freq)?.series_record(id)
    }

    /// Hot-swap one frequency's model; workers adopt it at their next
    /// batch boundary. Returns the new generation tag.
    pub fn reload(&self, freq: Frequency, state: ModelState) -> Result<u64> {
        Ok(self.pool(freq)?.reload(state))
    }

    /// Hot-swap from a checkpoint file (JSON or the compact binary
    /// format — sniffed by magic). The checkpoint's recorded frequency
    /// must match the pool it is being loaded into. When a
    /// `<checkpoint>.state` sidecar (written by
    /// [`export_state_sidecar`](Self::export_state_sidecar)) sits next
    /// to the file, its per-series ES states are merged into the pool's
    /// live store after the swap — newly published models arrive
    /// together with the series states they were trained against.
    pub fn reload_checkpoint(&self, freq: Frequency, path: impl AsRef<Path>)
                             -> Result<u64> {
        let path = path.as_ref();
        let state = checkpoint::load_model_state_for(path, freq.name())?;
        let generation = self.reload(freq, state)?;
        let sidecar = checkpoint::state_sidecar_path(path);
        if sidecar.exists() {
            self.pool(freq)?.state_store().import_from(&sidecar)?;
        }
        Ok(generation)
    }

    /// Write the pool's per-series ES state as a `<checkpoint>.state`
    /// sidecar next to `path`, for [`reload_checkpoint`]
    /// (Self::reload_checkpoint) on another host to merge. Returns the
    /// number of series exported.
    pub fn export_state_sidecar(&self, freq: Frequency,
                                path: impl AsRef<Path>) -> Result<usize> {
        let store = self.pool(freq)?.state_store();
        store.export_to(&checkpoint::state_sidecar_path(path.as_ref()))?;
        Ok(store.series())
    }

    pub fn generation(&self, freq: Frequency) -> Result<u64> {
        Ok(self.pool(freq)?.generation())
    }

    pub fn stats(&self, freq: Frequency) -> Result<ServiceStats> {
        Ok(self.pool(freq)?.stats())
    }

    /// Stats for every pool, keyed by frequency.
    pub fn stats_all(&self) -> BTreeMap<Frequency, ServiceStats> {
        self.pools.iter().map(|(f, p)| (*f, p.stats())).collect()
    }

    /// The equalized history length required of requests for `freq`.
    pub fn required_length(&self, freq: Frequency) -> Result<usize> {
        Ok(self.pool(freq)?.net().length)
    }

    /// Bind every pool's registry instruments under `{shard, freq}`
    /// labels — called by the sharding layer when this stack joins a
    /// ring as `shard`. Idempotent per pool.
    pub fn bind_metrics(&self, reg: &Registry, shard: &str) {
        for pool in self.pools.values() {
            pool.bind_metrics(reg, shard);
        }
    }
}
