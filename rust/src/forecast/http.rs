//! Zero-dependency HTTP/1.1 front-end over `std::net::TcpListener`,
//! serving a [`ServingStack`] with `util::json` as the wire format (no
//! async runtime, no frameworks — the offline build vendors nothing).
//!
//! Routes (all request/response bodies are JSON):
//!
//! * `POST /forecast` — `{"freq"?, "id"?, "category"?, "values": [..]}`
//!   → `{"id", "freq", "generation", "forecast": [..]}`. `freq` may be
//!   omitted when exactly one frequency is being served.
//! * `GET /stats` — per-frequency [`ServiceStats`](super::ServiceStats)
//!   (counters + p50/p95/p99 phase latencies in ms).
//! * `GET /healthz` — `{"status": "ok", "frequencies": [..],
//!   "generations": {..}}`.
//! * `POST /reload` — `{"freq"?, "checkpoint": "<server-local path>"}`
//!   → `{"freq", "generation"}`. Hot-swaps the model from a checkpoint
//!   (JSON or compact binary, sniffed by magic) without dropping queued
//!   requests. Operator-facing: the path is resolved on the server.
//!
//! Client errors → `400 {"error": ...}`; unknown routes → 404; wrong
//! method → 405; faults while serving a valid forecast request (backend
//! error, pool shut down) → 500. One thread per connection (requests are
//! short-lived and
//! the heavy lifting is already pooled behind the dynamic-batching
//! queue); `Connection: close` semantics keep the loop simple.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Category, Frequency};
use crate::util::json::Json;

use super::router::ServingStack;
use super::ForecastRequest;

const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// A running HTTP front-end: an accept-loop thread dispatching each
/// connection to a short-lived handler thread.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free port —
    /// read it back from [`Self::addr`]) and start serving `stack`.
    pub fn start(stack: Arc<ServingStack>, addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let stack = Arc::clone(&stack);
                    let _ = std::thread::Builder::new()
                        .name("http-conn".into())
                        .spawn(move || handle_connection(&stack, stream));
                }
            })?;
        Ok(Self { addr: local, shutdown, accept: Some(accept) })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections. In-flight handlers finish on their
    /// own threads (bounded by the per-connection read timeout).
    pub fn shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::SeqCst) {
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
    }
}

struct ParsedRequest {
    method: String,
    path: String,
    body: String,
}

fn handle_connection(stack: &ServingStack, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    let (code, body) = match read_request(&mut stream) {
        Ok(req) => route(stack, &req),
        Err(e) => (400, err_json(&format!("{e:#}"))),
    };
    let _ = write_response(&mut stream, code, &body.to_string());
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn read_request(stream: &mut TcpStream) -> Result<ParsedRequest> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subsequence(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request headers too large");
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("empty request line"))?
        .to_ascii_uppercase();
    let raw_path = parts.next().unwrap_or("/");
    let path = raw_path.split('?').next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad Content-Length `{}`", v.trim()))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("request body too large ({content_length} bytes)");
    }
    let body_start = (header_end + 4).min(buf.len());
    let mut body = buf[body_start..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(ParsedRequest {
        method,
        path,
        body: String::from_utf8(body).context("request body is not UTF-8")?,
    })
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

fn route(stack: &ServingStack, req: &ParsedRequest) -> (u16, Json) {
    let reply = |r: Result<Json>| match r {
        Ok(j) => (200, j),
        Err(e) => (400, err_json(&format!("{e:#}"))),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/forecast") => match handle_forecast(stack, &req.body) {
            Ok(j) => (200, j),
            Err(code_body) => code_body,
        },
        ("POST", "/reload") => reply(handle_reload(stack, &req.body)),
        ("GET", "/stats") => (200, handle_stats(stack)),
        ("GET", "/healthz") => (200, handle_healthz(stack)),
        (_, "/forecast" | "/reload" | "/stats" | "/healthz") => {
            (405, err_json(&format!("method {} not allowed for {}",
                                    req.method, req.path)))
        }
        _ => (404, err_json(&format!("no route for {} {}", req.method,
                                     req.path))),
    }
}

fn resolve_freq(stack: &ServingStack, doc: &Json) -> Result<Frequency> {
    match doc.opt("freq") {
        Some(j) => Frequency::parse(j.as_str()?),
        None => stack.single_frequency().ok_or_else(|| {
            anyhow!("`freq` is required when serving multiple frequencies \
                     ({})",
                    stack
                        .frequencies()
                        .iter()
                        .map(|f| f.name())
                        .collect::<Vec<_>>()
                        .join(", "))
        }),
    }
}

/// `Ok(json)` on success; `Err((status, body))` otherwise — malformed /
/// unroutable / too-short requests are 400, faults *while serving* a
/// valid request (backend error, pool shut down) are 500 so monitoring
/// and load balancers see a server outage, not a client mistake.
fn handle_forecast(stack: &ServingStack, body: &str)
                   -> Result<Json, (u16, Json)> {
    let (freq, req) = parse_forecast_request(stack, body)
        .map_err(|e| (400, err_json(&format!("{e:#}"))))?;
    let resp = stack
        .forecast(freq, req)
        .map_err(|e| (500, err_json(&format!("{e:#}"))))?;
    Ok(Json::obj(vec![
        ("id", Json::str(resp.id)),
        ("freq", Json::str(freq.name())),
        ("generation", Json::num(resp.generation as f64)),
        ("forecast", Json::arr_f32(&resp.forecast)),
    ]))
}

/// Validate everything client-controlled up front, including the history
/// length (mirroring the pool's own submit-time check) so a short
/// request is a clean 400 before it ever reaches the queue.
fn parse_forecast_request(stack: &ServingStack, body: &str)
                          -> Result<(Frequency, ForecastRequest)> {
    let doc = Json::parse(body).context("request body")?;
    let freq = resolve_freq(stack, &doc)?;
    let values = doc.get("values")?.as_f32_vec()?;
    let id = match doc.opt("id") {
        Some(j) => j.as_str()?.to_string(),
        None => "http".to_string(),
    };
    let category = match doc.opt("category") {
        Some(j) => Category::parse(j.as_str()?)?,
        None => Category::Other,
    };
    let need = stack.required_length(freq)?;
    if values.len() < need {
        bail!("request needs ≥ {need} history values for {}, got {}",
              freq.name(), values.len());
    }
    Ok((freq, ForecastRequest { id, values, category }))
}

fn handle_reload(stack: &ServingStack, body: &str) -> Result<Json> {
    let doc = Json::parse(body).context("request body")?;
    let freq = resolve_freq(stack, &doc)?;
    let path = doc.get("checkpoint")?.as_str()?;
    let generation = stack.reload_checkpoint(freq, path)?;
    Ok(Json::obj(vec![
        ("freq", Json::str(freq.name())),
        ("generation", Json::num(generation as f64)),
    ]))
}

fn handle_stats(stack: &ServingStack) -> Json {
    Json::Obj(
        stack
            .stats_all()
            .iter()
            .map(|(f, s)| (f.name().to_string(), s.to_json()))
            .collect(),
    )
}

fn handle_healthz(stack: &ServingStack) -> Json {
    let freqs = stack.frequencies();
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("frequencies",
         Json::Arr(freqs.iter().map(|f| Json::str(f.name())).collect())),
        ("generations",
         Json::Obj(
             freqs
                 .iter()
                 .map(|f| {
                     (f.name().to_string(),
                      Json::num(stack.generation(*f).unwrap_or(0) as f64))
                 })
                 .collect(),
         )),
    ])
}

fn write_response(stream: &mut TcpStream, code: u16, body: &str)
                  -> std::io::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len());
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Minimal blocking HTTP client for the CLI demo and integration tests:
/// one request per connection (`Connection: close`), returns
/// `(status code, body)`.
pub fn http_request(addr: &str, method: &str, path: &str, body: Option<&str>)
                    -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    parse_http_response(std::str::from_utf8(&buf).context("response UTF-8")?)
}

/// Split a raw HTTP/1.1 response into (status code, body).
fn parse_http_response(text: &str) -> Result<(u16, String)> {
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response (no header end)"))?;
    let code = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| anyhow!("malformed HTTP status line"))?;
    Ok((code, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_parsing() {
        let (code, body) = parse_http_response(
            "HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\n{}").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "{}");
        assert!(parse_http_response("garbage").is_err());
        assert!(parse_http_response("HTTP/1.1 x\r\n\r\n").is_err());
    }

    #[test]
    fn subsequence_search() {
        assert_eq!(find_subsequence(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subsequence(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn error_body_shape() {
        let j = err_json("boom");
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
    }
}
