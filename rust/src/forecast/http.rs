//! Zero-dependency HTTP/1.1 front-end over `std::net::TcpListener`,
//! serving a [`ShardedStack`] with `util::json` as the wire format (no
//! async runtime, no frameworks — the offline build vendors nothing).
//!
//! Routes live under the versioned `/v1/` prefix; the original
//! unversioned paths remain as **deprecated aliases** serving
//! byte-identical payloads plus a `Deprecation: true` header and a
//! `Link: </v1/...>; rel="successor-version"` pointer. New clients
//! (including [`HttpClient`] callers in this repo) speak `/v1`.
//!
//! * `POST /v1/series/{id}/observe` — `{"freq"?, "values": [..],
//!   "t0"?}` → `{"id", "freq", "observed", "generation",
//!   "new_series"}`. Advances the series' ES recurrence online (no RNN
//!   retrain) and invalidates its cached forecast. `t0`, when present,
//!   is the absolute index of `values[0]`: a replayed batch is `409`
//!   (`stale_observation`), a batch that would skip ahead is `400`.
//! * `GET /v1/series/{id}/forecast` — stateful forecast from the
//!   series' stored ES state (`?freq=` required only when serving
//!   multiple frequencies) → the same `{"id", "freq", "generation",
//!   "forecast"}` shape as the POST route. Unknown series → `404`
//!   (`unknown_series`).
//! * `POST /v1/series/{id}/forecast` — stateless forecast from history
//!   carried in the body (same body as the deprecated `/v1/forecast`,
//!   with `id` taken from the path).
//! * `GET /v1/series/{id}/state` — the stored ES state:
//!   `{"id", "freq", "observed", "generation", "level", "seasonality",
//!   "seasonality2"}`.
//! * `POST /v1/forecast` — **deprecated** alias of
//!   `POST /v1/series/{id}/forecast` with `id` in the body:
//!   `{"freq"?, "id"?, "category"?, "values": [..]}` → `{"id", "freq",
//!   "generation", "forecast": [..]}`. `freq` may be omitted when
//!   exactly one frequency is being served; `id` is also the
//!   consistent-hash shard key. Served byte-identically, plus the
//!   `Deprecation` + `Link` successor headers.
//! * `GET /v1/stats` — `{"schema_version": 1, "serving": {...},
//!   "http": {...}, "backend": {...}, "shards": [...]}` — see
//!   [`ServiceStats::to_json`](super::ServiceStats::to_json). Field
//!   names match the `/v1/metrics` metric names one-for-one so
//!   dashboards can join the two.
//! * `GET /v1/metrics` — the stack's
//!   [`Registry`](crate::telemetry::registry::Registry) in Prometheus
//!   text exposition format 0.0.4 (`Content-Type: text/plain;
//!   version=0.0.4`): per-`{shard, freq}` queue depth, accepted/shed
//!   counters, latency histograms, backend gauges, plus the front-end's
//!   own connection metrics.
//! * `GET /v1/healthz` — `{"status", "frequencies", "generations",
//!   "shards"}`.
//! * `POST /v1/reload` — `{"freq"?, "checkpoint": "<server-local
//!   path>"}` → `{"freq", "generation"}`. Hot-swaps every shard's model
//!   from a checkpoint (JSON or compact binary, sniffed by magic)
//!   without dropping queued requests. Operator-facing: the path is
//!   resolved on the server.
//!
//! Every non-2xx response carries the unified error envelope
//! `{"error": {"code": "<machine-readable>", "message": "...",
//! "retry_after_ms": <only with Retry-After>}}`; see [`error_code`] for
//! the status → code table.
//!
//! Connection model — built to survive overload and hostile clients:
//!
//! * **HTTP/1.1 keep-alive**: a connection serves many requests
//!   (pipelined bytes are buffered and served in order). `Connection:
//!   close` — or HTTP/1.0 without `Connection: keep-alive` — closes
//!   after the response.
//! * **Bounded workers**: a fixed pool of `conn_workers` handler
//!   threads serves connections from an accept backlog of at most
//!   `accept_backlog`; when the backlog is full the accept loop sheds
//!   the connection with `503` + `Retry-After` instead of queueing or
//!   spawning without bound.
//! * **Request-size limits**: headers over `max_header_bytes` → `431`;
//!   a `Content-Length` over `max_body_bytes` → `413` *before* any body
//!   byte is buffered, so a hostile declared length cannot balloon
//!   memory. Reads poll in short ticks, so an idle keep-alive
//!   connection times out (`keep_alive`), a stalled mid-request client
//!   gets `408` (`request_timeout`), and shutdown is observed promptly.
//!
//! Status contract: client mistakes → `400`, unknown route or unknown
//! series → `404`, wrong method → `405`, stalled request → `408`,
//! replayed observation batch → `409`, oversized body → `413`, pool
//! queue full (backpressure, [`QueueFull`](super::QueueFull)) → `429` +
//! `Retry-After`, oversized headers → `431`, chunked transfer → `501`,
//! faults while serving a valid forecast → `500`, accept backlog full →
//! `503` + `Retry-After` — each with the error envelope as its body.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Category, Frequency};
use crate::telemetry::registry::{Counter, Gauge, Registry};
use crate::util::json::Json;

use super::api;
use super::api::{ObservationGap, StaleObservation, UnknownSeries};
use super::pool::QueueFull;
use super::router::ServingStack;
use super::shard::ShardedStack;
use super::{ForecastRequest, ServiceStats};

/// How often blocking reads wake to re-check deadlines and shutdown.
const POLL_TICK: Duration = Duration::from_millis(100);

/// `Content-Type` for JSON bodies (every route except `/v1/metrics`).
const CT_JSON: &str = "application/json";

/// `Content-Type` for the Prometheus text exposition format served at
/// `/v1/metrics`.
const CT_PROM: &str = "text/plain; version=0.0.4";

/// Connection-handling knobs. The defaults suit tests and single-node
/// deployments; production front-ends size `conn_workers` ≈ expected
/// concurrent connections and `accept_backlog` to the burst they are
/// willing to absorb before shedding.
#[derive(Debug, Clone)]
pub struct HttpOptions {
    /// Connection-handler threads (each owns one connection at a time).
    pub conn_workers: usize,
    /// Accepted connections waiting for a worker before `503` shedding.
    pub accept_backlog: usize,
    /// Hard cap on one request's header section → `431`.
    pub max_header_bytes: usize,
    /// Hard cap on one request's `Content-Length` → `413`.
    pub max_body_bytes: usize,
    /// Idle time allowed between keep-alive requests before close.
    pub keep_alive: Duration,
    /// Time allowed to finish reading one request once started → `408`.
    pub request_timeout: Duration,
    /// Fairness rotation: after this many responses a keep-alive
    /// connection is closed (`Connection: close` on the last one) so a
    /// persistent client cannot pin a handler worker forever while
    /// backlogged connections starve. [`HttpClient`] reconnects
    /// transparently when rotated.
    pub max_requests_per_conn: usize,
}

impl Default for HttpOptions {
    fn default() -> Self {
        Self {
            conn_workers: 8,
            accept_backlog: 64,
            max_header_bytes: 16 * 1024,
            max_body_bytes: 16 * 1024 * 1024,
            keep_alive: Duration::from_secs(5),
            request_timeout: Duration::from_secs(10),
            max_requests_per_conn: 128,
        }
    }
}

/// State shared by the accept loop and the connection workers.
struct ServerShared {
    stack: Arc<ShardedStack>,
    opts: HttpOptions,
    shutdown: AtomicBool,
    /// Accepted connections waiting for a worker, with enqueue time so
    /// stale waiters can be shed instead of hanging answerless.
    // lint:lock-name(http.conns)
    conns: Mutex<VecDeque<(TcpStream, Instant)>>,
    cond: Condvar,
    /// Front-end connection metrics, bound into the stack's registry
    /// (also the source for the [`HttpServer::sheds`] /
    /// [`HttpServer::stale_sheds`] accessors).
    metrics: HttpMetrics,
}

/// Statuses an error response can carry, pre-registered under
/// `fesrnn_http_responses_total{code=...}` so every code's series
/// exists (at zero) from the very first scrape.
const ERROR_STATUSES: [u16; 11] =
    [400, 404, 405, 408, 409, 413, 429, 431, 500, 501, 503];

/// The HTTP front-end's own instruments, registered into the stack's
/// [`Registry`] at server start (idempotent: a second server on the
/// same stack rebinds the same names).
struct HttpMetrics {
    /// Error responses by status code, in [`ERROR_STATUSES`] order.
    by_code: Vec<(u16, Counter)>,
    /// Shed at accept: backlog full. Remedy: bigger backlog / more
    /// capacity.
    sheds_backlog: Counter,
    /// Shed at dequeue: waited ≥ request_timeout for a worker. Remedy:
    /// more conn workers / faster handlers.
    sheds_stale: Counter,
    /// Keep-alive connections closed by the fairness rotation cap.
    rotations: Counter,
    /// Connections accepted into the worker backlog.
    connections: Counter,
    /// Requests served via a legacy unversioned path alias.
    deprecated: Counter,
}

impl HttpMetrics {
    fn register(reg: &Registry, opts: &HttpOptions) -> Self {
        let mut by_code = Vec::with_capacity(ERROR_STATUSES.len());
        for code in ERROR_STATUSES {
            let c = Counter::new();
            let code_str = code.to_string();
            reg.register_counter(
                "fesrnn_http_responses_total",
                "Error responses sent, by status code. 2xx responses \
                 ride the request hot path and are deliberately \
                 unmetered here — count successes via \
                 fesrnn_queue_accepted_total.",
                &[("code", code_str.as_str())],
                &c,
            );
            by_code.push((code, c));
        }
        let shed_help =
            "Connections shed with 503, by cause: backlog_full wants a \
             bigger accept backlog or more capacity; stale_in_backlog \
             wants more or faster connection workers.";
        let sheds_backlog = Counter::new();
        reg.register_counter("fesrnn_http_sheds_total", shed_help,
                             &[("kind", "backlog_full")], &sheds_backlog);
        let sheds_stale = Counter::new();
        reg.register_counter("fesrnn_http_sheds_total", shed_help,
                             &[("kind", "stale_in_backlog")], &sheds_stale);
        let rotations = Counter::new();
        reg.register_counter(
            "fesrnn_http_keepalive_rotations_total",
            "Keep-alive connections closed by the per-connection \
             request cap (fairness rotation).",
            &[], &rotations);
        let connections = Counter::new();
        reg.register_counter(
            "fesrnn_http_connections_total",
            "Connections accepted into the worker backlog.",
            &[], &connections);
        let deprecated = Counter::new();
        reg.register_counter(
            "fesrnn_http_deprecated_requests_total",
            "Requests that arrived via a legacy unversioned path alias \
             — migrate callers to the /v1 routes.",
            &[], &deprecated);
        let workers = Gauge::new();
        workers.set(opts.conn_workers as u64);
        reg.register_gauge("fesrnn_http_conn_workers",
                           "Configured connection-handler workers.",
                           &[], &workers);
        let backlog = Gauge::new();
        backlog.set(opts.accept_backlog as u64);
        reg.register_gauge("fesrnn_http_accept_backlog",
                           "Configured accept-backlog capacity.",
                           &[], &backlog);
        Self {
            by_code,
            sheds_backlog,
            sheds_stale,
            rotations,
            connections,
            deprecated,
        }
    }

    /// Count one error response; 2xx are unmetered by design.
    fn response(&self, code: u16) {
        for (c, counter) in &self.by_code {
            if *c == code {
                counter.inc();
                return;
            }
        }
    }
}

/// A running HTTP front-end: one accept thread feeding a bounded pool
/// of connection-handler workers.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Serve a single [`ServingStack`] (wrapped as a one-shard ring)
    /// with default [`HttpOptions`]. Bind `addr` (e.g. `127.0.0.1:8080`;
    /// port 0 picks a free port — read it back from [`Self::addr`]).
    pub fn start(stack: Arc<ServingStack>, addr: &str) -> Result<Self> {
        Self::start_with(Arc::new(ShardedStack::single(stack)?), addr,
                         HttpOptions::default())
    }

    /// Serve a sharded stack with default [`HttpOptions`].
    pub fn start_sharded(stack: Arc<ShardedStack>, addr: &str)
                         -> Result<Self> {
        Self::start_with(stack, addr, HttpOptions::default())
    }

    /// Serve a sharded stack with explicit connection-handling knobs.
    pub fn start_with(stack: Arc<ShardedStack>, addr: &str,
                      opts: HttpOptions) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let opts = HttpOptions {
            conn_workers: opts.conn_workers.max(1),
            accept_backlog: opts.accept_backlog.max(1),
            max_requests_per_conn: opts.max_requests_per_conn.max(1),
            ..opts
        };
        // Bind the front-end's instruments into the same registry the
        // shards' pool metrics live in, so one /v1/metrics scrape covers
        // the whole serving path.
        let metrics = HttpMetrics::register(stack.registry(), &opts);
        let shared = Arc::new(ServerShared {
            stack,
            opts,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            metrics,
        });
        // Any spawn failure below must not leak the threads already
        // started (they'd block on the condvar forever with shutdown
        // unset and no owner to join them).
        let teardown = |workers: Vec<JoinHandle<()>>| {
            shared.shutdown.store(true, Ordering::SeqCst);
            let guard = shared.conns.lock().unwrap();
            shared.cond.notify_all();
            drop(guard);
            for j in workers {
                let _ = j.join();
            }
        };
        let mut workers = Vec::with_capacity(shared.opts.conn_workers);
        for w in 0..shared.opts.conn_workers {
            let sh = Arc::clone(&shared);
            match std::thread::Builder::new()
                .name(format!("http-conn-{w}"))
                .spawn(move || worker_loop(&sh))
            {
                Ok(j) => workers.push(j),
                Err(e) => {
                    teardown(workers);
                    return Err(e.into());
                }
            }
        }
        let sh = Arc::clone(&shared);
        let accept = match std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || accept_loop(&sh, listener))
        {
            Ok(j) => j,
            Err(e) => {
                teardown(workers);
                return Err(e.into());
            }
        };
        Ok(Self { addr: local, shared, accept: Some(accept), workers })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections shed with `503` because the accept backlog was full
    /// (undersized backlog / too much traffic — distinct from
    /// [`stale_sheds`](Self::stale_sheds)). Same counter as
    /// `fesrnn_http_sheds_total{kind="backlog_full"}`.
    pub fn sheds(&self) -> u64 {
        self.shared.metrics.sheds_backlog.get()
    }

    /// Connections shed with `503` after waiting ≥ `request_timeout` in
    /// the backlog for a worker (workers too few/slow for the accepted
    /// load — distinct from [`sheds`](Self::sheds)). Same counter as
    /// `fesrnn_http_sheds_total{kind="stale_in_backlog"}`.
    pub fn stale_sheds(&self) -> u64 {
        self.shared.metrics.sheds_stale.get()
    }

    /// Stop accepting connections and wake the workers. Connections
    /// already in the backlog are still picked up (workers drain the
    /// queue before exiting) but get at most one response each — the
    /// shutdown flag forces `Connection: close` — and idle keep-alive
    /// connections close within [`POLL_TICK`]. Teardown is therefore
    /// bounded by one in-flight request per backlogged connection;
    /// shutdown is for teardown, not rolling restart.
    pub fn shutdown(&self) {
        if !self.shared.shutdown.swap(true, Ordering::SeqCst) {
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
        }
        // Notify while holding the queue mutex: a worker between its
        // shutdown check and its wait would otherwise miss the wakeup
        // and sleep forever (the flag is atomic, not mutex-guarded).
        let _guard = self.shared.conns.lock().unwrap();
        self.shared.cond.notify_all();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

fn accept_loop(sh: &ServerShared, listener: TcpListener) {
    for conn in listener.incoming() {
        if sh.shutdown.load(Ordering::SeqCst) {
            // Give whatever connection accept() just handed us (the
            // shutdown self-connect, or a real client that raced it) a
            // definite 503 instead of a silent drop — consistent with
            // the under-lock shutdown path below.
            if let Ok(stream) = conn {
                sh.metrics.response(503);
                shed_connection(stream);
            }
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                // accept() can fail persistently without blocking (e.g.
                // EMFILE under fd exhaustion — exactly the overload this
                // server sheds). Back off briefly instead of spinning a
                // core on the error.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        let mut q = sh.conns.lock().unwrap();
        // Re-check shutdown under the queue lock: if it is still false
        // here, shutdown() has not yet taken this lock to notify, so
        // the workers' wakeup will see this connection (next_conn pops
        // before it checks the flag). Without this, a connection pushed
        // after idle workers already exited would hang answerless.
        if sh.shutdown.load(Ordering::SeqCst) {
            drop(q);
            sh.metrics.response(503);
            shed_connection(stream);
            break;
        }
        if q.len() >= sh.opts.accept_backlog {
            // Load shedding: tell the client to back off instead of
            // queueing unboundedly (which would degrade everyone).
            drop(q);
            sh.metrics.sheds_backlog.inc();
            sh.metrics.response(503);
            shed_connection(stream);
            continue;
        }
        q.push_back((stream, Instant::now()));
        drop(q);
        sh.metrics.connections.inc();
        sh.cond.notify_one();
    }
}

/// Best-effort `503` on a connection we will not serve. Runs on the
/// accept thread, so it must stay O(microseconds): the ~150-byte
/// response always fits a fresh socket's empty send buffer (write_all
/// returns without blocking; the timeout is a belt-and-suspenders cap),
/// and we deliberately do NOT drain the client's unread bytes here —
/// the close may RST the 503 away for a client mid-upload, but pinning
/// the accept loop on hostile streamers would starve every future
/// accept, which is strictly worse than a lost courtesy response.
fn shed_connection(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let body = err_json(503, "server is at capacity — retry later", Some(1))
        .to_string();
    let _ = write_response(&mut stream, 503, &body, CT_JSON, false, Some(1),
                           None);
}

/// Closing a socket with unread bytes in its receive buffer makes the
/// kernel send RST and discard any queued response — the client would
/// see a connection reset instead of the `413`/`431`/`503` we just
/// wrote. Discard what the client already sent (bounded in bytes and
/// time, so a hostile streamer cannot pin us) before the drop, giving
/// the error response a chance to be delivered.
fn drain_before_close(stream: &mut TcpStream) {
    const MAX_DRAIN_BYTES: usize = 256 * 1024;
    let deadline = Instant::now() + Duration::from_millis(500);
    let mut tmp = [0u8; 4096];
    let mut drained = 0usize;
    while drained < MAX_DRAIN_BYTES && Instant::now() < deadline {
        match read_tick(stream, &mut tmp) {
            Tick::Data(n) => drained += n,
            // Timeout: the client paused — likely reading our response;
            // one quiet tick is enough grace.
            Tick::Eof | Tick::Broken | Tick::Timeout => break,
        }
    }
}

/// Worker-thread variant of [`shed_connection`]: same `503`, plus the
/// bounded drain a worker can afford — a stale backlogged client has
/// usually already sent its request, and closing without reading those
/// bytes would RST the `503` away.
fn shed_connection_draining(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let body = err_json(503, "server is at capacity — retry later", Some(1))
        .to_string();
    if write_response(&mut stream, 503, &body, CT_JSON, false, Some(1), None)
        .is_ok()
    {
        let _ = stream.set_read_timeout(Some(POLL_TICK));
        drain_before_close(&mut stream);
    }
}

fn worker_loop(sh: &ServerShared) {
    while let Some(stream) = next_conn(sh) {
        serve_connection(sh, stream);
    }
}

fn next_conn(sh: &ServerShared) -> Option<TcpStream> {
    let mut q = sh.conns.lock().unwrap();
    loop {
        if let Some((stream, queued_at)) = q.pop_front() {
            if queued_at.elapsed() >= sh.opts.request_timeout {
                // The client already waited a whole request budget for
                // a worker; a definite "come back later" now beats a
                // stale answer after its own timeout has likely fired.
                drop(q);
                sh.metrics.sheds_stale.inc();
                sh.metrics.response(503);
                shed_connection_draining(stream);
                q = sh.conns.lock().unwrap();
                continue;
            }
            return Some(stream);
        }
        if sh.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        q = sh.cond.wait(q).unwrap();
    }
}

struct ParsedRequest {
    method: String,
    path: String,
    /// Raw query string (after `?`, without it), empty when absent.
    query: String,
    body: String,
    keep_alive: bool,
}

/// One attempt to read a request off a (possibly keep-alive) connection.
enum RequestOutcome {
    /// A complete request; leftover (pipelined) bytes stay in the buffer.
    Ready(ParsedRequest),
    /// Clean end of the connection (EOF / idle timeout / shutdown).
    Closed,
    /// Protocol or limit violation: respond with this status and close.
    Fatal(u16, String),
}

/// Serve requests on one connection until it closes, errs, times out
/// idle, asks to close, or the server shuts down.
fn serve_connection(sh: &ServerShared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(POLL_TICK));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut served = 0usize;
    loop {
        match read_request(&mut stream, &mut buf, &sh.opts, &sh.shutdown) {
            RequestOutcome::Closed => break,
            RequestOutcome::Fatal(code, msg) => {
                sh.metrics.response(code);
                if write_response(&mut stream, code,
                                  &err_json(code, &msg, None).to_string(),
                                  CT_JSON, false, None, None)
                    .is_ok()
                {
                    // The client may still be streaming the request we
                    // refused (oversized body, etc.) — discard it
                    // (bounded) so the close doesn't RST the error
                    // response out from under it.
                    drain_before_close(&mut stream);
                }
                break;
            }
            RequestOutcome::Ready(req) => {
                let reply = route(sh, &req);
                if reply.code >= 400 {
                    sh.metrics.response(reply.code);
                }
                served += 1;
                // Rotation fairness: close after the per-connection
                // request cap so one persistent client cannot pin this
                // worker while backlogged connections wait.
                let rotated = served >= sh.opts.max_requests_per_conn;
                if req.keep_alive && rotated {
                    sh.metrics.rotations.inc();
                }
                let keep = req.keep_alive && !rotated
                    && !sh.shutdown.load(Ordering::SeqCst);
                if write_response(&mut stream, reply.code, &reply.body,
                                  reply.content_type, keep,
                                  reply.retry_after, reply.successor)
                    .is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
        }
    }
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// One 100ms-bounded read step.
enum Tick {
    Data(usize),
    Eof,
    Timeout,
    Broken,
}

fn read_tick(stream: &mut TcpStream, tmp: &mut [u8]) -> Tick {
    match stream.read(tmp) {
        Ok(0) => Tick::Eof,
        Ok(n) => Tick::Data(n),
        Err(e) if matches!(e.kind(),
                           std::io::ErrorKind::WouldBlock
                           | std::io::ErrorKind::TimedOut
                           | std::io::ErrorKind::Interrupted) => Tick::Timeout,
        Err(_) => Tick::Broken,
    }
}

/// Read one request, leaving any pipelined surplus in `buf` for the
/// next call. Limits are enforced incrementally: headers may never
/// exceed `max_header_bytes` (431), a declared `Content-Length` beyond
/// `max_body_bytes` is refused (413) before one body byte is buffered.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>,
                opts: &HttpOptions, shutdown: &AtomicBool)
                -> RequestOutcome {
    let mut started = Instant::now();
    // Pipelined surplus counts as the request having started: a client
    // that pre-sends one byte of the next request must not earn a
    // deadline reset on its second byte (that would stretch each
    // request's read budget to ~2× request_timeout).
    let mut saw_data = !buf.is_empty();
    let mut tmp = [0u8; 4096];

    // Phase 1: headers.
    let header_end = loop {
        // RFC 9112 §2.2: ignore CRLF arriving before the request line.
        // Stripped inside the loop (not just on entry) so a blank line
        // counts no matter which read delivers it; once a non-CRLF byte
        // leads the buffer this is a no-op.
        let skip =
            buf.iter().take_while(|&&b| b == b'\r' || b == b'\n').count();
        buf.drain(..skip);
        if let Some(pos) = find_subsequence(buf, b"\r\n\r\n") {
            if pos > opts.max_header_bytes {
                return RequestOutcome::Fatal(
                    431,
                    format!("request headers exceed {} bytes",
                            opts.max_header_bytes));
            }
            break pos;
        }
        // `+ 4`: a header section of exactly the cap plus a partial
        // terminator may be in flight — without the slack, the verdict
        // on a cap-sized request would depend on TCP chunk boundaries.
        if buf.len() > opts.max_header_bytes + 4 {
            return RequestOutcome::Fatal(
                431,
                format!("request headers exceed {} bytes",
                        opts.max_header_bytes));
        }
        // Deadlines are checked every iteration — not just on quiet
        // ticks — so a slow-drip client feeding one byte per tick still
        // hits the 408 wall and cannot pin a bounded worker.
        if buf.is_empty() {
            // Idle between keep-alive requests.
            if started.elapsed() >= opts.keep_alive {
                return RequestOutcome::Closed;
            }
        } else if started.elapsed() >= opts.request_timeout {
            return RequestOutcome::Fatal(
                408, "timed out reading request headers".into());
        }
        match read_tick(stream, &mut tmp) {
            Tick::Data(n) => {
                if !saw_data {
                    // First byte of a new request: the deadline budget
                    // starts here — keep-alive idle time before it must
                    // not be charged against the 408 clock. Reset at
                    // most ONCE per request: a client dripping bare
                    // CRLFs (stripped above, so `buf` stays empty)
                    // must not keep rewinding the clock, or it could
                    // pin this worker forever; with one reset, such a
                    // connection dies at the keep_alive deadline.
                    saw_data = true;
                    started = Instant::now();
                }
                buf.extend_from_slice(&tmp[..n]);
            }
            Tick::Eof | Tick::Broken => {
                return if buf.is_empty() {
                    RequestOutcome::Closed
                } else {
                    RequestOutcome::Fatal(
                        400, "connection closed mid-request".into())
                };
            }
            Tick::Timeout => {
                if shutdown.load(Ordering::SeqCst) {
                    return RequestOutcome::Closed;
                }
            }
        }
    };

    let head = match parse_head(&buf[..header_end], opts.max_body_bytes) {
        Ok(h) => h,
        Err((code, msg)) => return RequestOutcome::Fatal(code, msg),
    };

    // Phase 2: exactly `content_length` body bytes (the cap was already
    // enforced on the declared length, so this buffers at most
    // `max_body_bytes`).
    let body_start = header_end + 4;
    let needed = body_start + head.content_length;
    while buf.len() < needed {
        // Same per-iteration deadline as phase 1: progress does not
        // reset the clock, so drip-feeding a body cannot hold a worker
        // past request_timeout.
        if started.elapsed() >= opts.request_timeout {
            return RequestOutcome::Fatal(
                408, "timed out reading request body".into());
        }
        match read_tick(stream, &mut tmp) {
            Tick::Data(n) => buf.extend_from_slice(&tmp[..n]),
            Tick::Eof | Tick::Broken => {
                return RequestOutcome::Fatal(
                    400, "connection closed mid-body".into());
            }
            Tick::Timeout => {
                if shutdown.load(Ordering::SeqCst) {
                    return RequestOutcome::Closed;
                }
            }
        }
    }
    let body = match std::str::from_utf8(&buf[body_start..needed]) {
        Ok(s) => s.to_string(),
        Err(_) => {
            return RequestOutcome::Fatal(
                400, "request body is not UTF-8".into());
        }
    };
    // Keep pipelined surplus for the next request on this connection —
    // but not the capacity a large body grew: without the shrink, one
    // max-sized POST would pin that allocation on this worker for the
    // connection's whole remaining lifetime.
    buf.drain(..needed);
    if buf.capacity() > 64 * 1024 {
        buf.shrink_to(4096.max(buf.len()));
    }
    RequestOutcome::Ready(ParsedRequest {
        method: head.method,
        path: head.path,
        query: head.query,
        body,
        keep_alive: head.keep_alive,
    })
}

struct Head {
    method: String,
    path: String,
    query: String,
    content_length: usize,
    keep_alive: bool,
}

/// Parse the request line + headers. Errors carry the HTTP status that
/// should reject them.
fn parse_head(raw: &[u8], max_body: usize) -> Result<Head, (u16, String)> {
    let text = std::str::from_utf8(raw)
        .map_err(|_| (400, "request head is not UTF-8".to_string()))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| (400, "empty request line".to_string()))?
        .to_ascii_uppercase();
    let raw_path = parts.next().unwrap_or("/");
    let (path, query) = match raw_path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (raw_path.to_string(), String::new()),
    };
    let version = parts.next().unwrap_or("HTTP/1.1");
    // HTTP/1.1 defaults to keep-alive; 1.0 defaults to close.
    let mut keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0");
    // RFC 9110 §7.6.1: once any Connection header says close, the
    // connection closes — a later `keep-alive` token cannot revive it.
    let mut saw_close = false;
    let mut content_length: Option<u64> = None;
    for line in lines {
        let Some((k, v)) = line.split_once(':') else { continue };
        let k = k.trim();
        let v = v.trim();
        if k.eq_ignore_ascii_case("content-length") {
            // Digits only (RFC 9110 §8.6): Rust's u64 parser would also
            // accept `+123`, which a stricter front proxy may reject or
            // frame differently — the same desync vector as conflicting
            // Content-Length values.
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err((400, format!("bad Content-Length `{v}`")));
            }
            let n: u64 = v.parse().map_err(|_| {
                (400, format!("bad Content-Length `{v}`"))
            })?;
            // RFC 9112 §6.3: conflicting Content-Length values are a
            // framing ambiguity (request-smuggling vector on keep-alive
            // connections) — reject, never pick one.
            if content_length.is_some_and(|prev| prev != n) {
                return Err((400,
                            "conflicting Content-Length headers".to_string()));
            }
            if n > max_body as u64 {
                return Err((413,
                            format!("request body of {n} bytes exceeds the \
                                     {max_body}-byte limit")));
            }
            content_length = Some(n);
        } else if k.eq_ignore_ascii_case("connection") {
            let v = v.to_ascii_lowercase();
            if v.split(',').any(|t| t.trim() == "close") {
                saw_close = true;
            } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                keep_alive = true;
            }
        } else if k.eq_ignore_ascii_case("transfer-encoding") {
            return Err((501,
                        "transfer encodings are not supported — send a \
                         Content-Length body"
                            .to_string()));
        }
    }
    Ok(Head {
        method,
        path,
        query,
        content_length: content_length.unwrap_or(0) as usize,
        keep_alive: keep_alive && !saw_close,
    })
}

/// The machine-readable `code` carried in the error envelope for each
/// status this server emits:
///
/// | status | code |
/// |--------|------|
/// | 400 | `bad_request` |
/// | 404 | `not_found` |
/// | 405 | `method_not_allowed` |
/// | 408 | `request_timeout` |
/// | 409 | `conflict` |
/// | 413 | `body_too_large` |
/// | 429 | `queue_full` |
/// | 431 | `headers_too_large` |
/// | 500 | `internal` |
/// | 501 | `not_implemented` |
/// | 503 | `overloaded` |
///
/// Any other status maps to `error`. Clients should branch on these
/// strings, never on `message` text. Two routes refine their default:
/// a missing series state is `404` with code `unknown_series`, and a
/// replayed observation batch is `409` with code `stale_observation`
/// (see [`Reply::error_coded`]).
pub fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        408 => "request_timeout",
        409 => "conflict",
        413 => "body_too_large",
        429 => "queue_full",
        431 => "headers_too_large",
        500 => "internal",
        501 => "not_implemented",
        503 => "overloaded",
        _ => "error",
    }
}

/// The unified error envelope every non-2xx body carries:
/// `{"error": {"code", "message", "retry_after_ms"?}}`. The
/// `retry_after_ms` field appears exactly when the response also
/// carries a `Retry-After` header (same duration, in milliseconds).
fn err_json(code: u16, msg: &str, retry_after: Option<u32>) -> Json {
    err_json_coded(error_code(code), msg, retry_after)
}

/// [`err_json`] with an explicit envelope code, for the statuses whose
/// default code is refined per-route (`unknown_series`,
/// `stale_observation`).
fn err_json_coded(code: &str, msg: &str, retry_after: Option<u32>) -> Json {
    let mut fields = vec![
        ("code", Json::str(code)),
        ("message", Json::str(msg)),
    ];
    if let Some(secs) = retry_after {
        fields.push(("retry_after_ms", Json::num(secs as f64 * 1000.0)));
    }
    Json::obj(vec![("error", Json::obj(fields))])
}

/// One routed response: status, serialized body, content type, and the
/// optional backpressure / deprecation response headers.
struct Reply {
    code: u16,
    body: String,
    content_type: &'static str,
    retry_after: Option<u32>,
    successor: Option<&'static str>,
}

impl Reply {
    fn json(code: u16, body: Json, retry_after: Option<u32>) -> Self {
        Self {
            code,
            body: body.to_string(),
            content_type: CT_JSON,
            retry_after,
            successor: None,
        }
    }

    fn error(code: u16, msg: &str, retry_after: Option<u32>) -> Self {
        Self::json(code, err_json(code, msg, retry_after), retry_after)
    }

    /// An error reply whose envelope code is route-refined rather than
    /// the status default — e.g. `404`/`unknown_series`,
    /// `409`/`stale_observation`.
    fn error_coded(code: u16, envelope_code: &str, msg: &str) -> Self {
        Self::json(code, err_json_coded(envelope_code, msg, None), None)
    }
}

/// Map a request path to its normalized route. Legacy unversioned
/// paths resolve to the same handlers but report their `/v1` successor
/// so the response can carry `Deprecation` + `Link` headers; `/v1/...`
/// paths are served natively.
fn split_alias(path: &str) -> (&str, Option<&'static str>) {
    match path {
        "/forecast" => ("/forecast", Some("/v1/forecast")),
        "/reload" => ("/reload", Some("/v1/reload")),
        "/stats" => ("/stats", Some("/v1/stats")),
        "/healthz" => ("/healthz", Some("/v1/healthz")),
        "/metrics" => ("/metrics", Some("/v1/metrics")),
        p => (p.strip_prefix("/v1").unwrap_or(p), None),
    }
}

/// Dispatch one parsed request. The legacy-alias counter is bumped
/// *before* the handler runs so an alias `/metrics` scrape already
/// includes its own deprecation hit — a legacy scrape followed by a
/// `/v1` scrape therefore returns byte-identical payloads (modulo live
/// traffic), which the conformance test relies on.
fn route(sh: &ServerShared, req: &ParsedRequest) -> Reply {
    let stack = &*sh.stack;
    let (path, successor) = split_alias(&req.path);
    if successor.is_some() {
        sh.metrics.deprecated.inc();
    }
    // Resource-oriented series routes. They postdate the unversioned
    // prefix, so they are served under /v1 only — `split_alias`'s
    // strip-prefix fallthrough must not grant an unversioned
    // `/series/...` spelling that never existed.
    if let Some(rest) = path.strip_prefix("/series/") {
        if req.path.starts_with("/v1/series/") {
            return route_series(sh, rest, req);
        }
        return Reply::error(
            404,
            &format!("no route for {} {} — series routes are served \
                      under /v1 only", req.method, req.path),
            None);
    }
    let mut reply = match (req.method.as_str(), path) {
        ("POST", "/forecast") => handle_forecast(stack, &req.body),
        ("POST", "/reload") => match handle_reload(stack, &req.body) {
            Ok(j) => Reply::json(200, j, None),
            Err(e) => Reply::error(400, &format!("{e:#}"), None),
        },
        ("GET", "/stats") => Reply::json(200, handle_stats(sh), None),
        ("GET", "/healthz") => Reply::json(200, handle_healthz(stack), None),
        ("GET", "/metrics") => Reply {
            code: 200,
            body: stack.registry().render(),
            content_type: CT_PROM,
            retry_after: None,
            successor: None,
        },
        (_, "/forecast" | "/reload" | "/stats" | "/healthz" | "/metrics") => {
            Reply::error(405,
                         &format!("method {} not allowed for {}", req.method,
                                  req.path),
                         None)
        }
        _ => Reply::error(404,
                          &format!("no route for {} {}", req.method,
                                   req.path),
                          None),
    };
    reply.successor = successor;
    // `POST /v1/forecast` is itself deprecated now that the resource
    // route exists: same handler, byte-identical payload, plus the
    // successor headers — exactly the alias contract the legacy
    // unversioned paths follow.
    if req.method == "POST" && path == "/forecast" && successor.is_none() {
        sh.metrics.deprecated.inc();
        reply.successor = Some("/v1/series/{id}/forecast");
    }
    reply
}

/// Dispatch `/v1/series/{id}/{action}`. `rest` is everything after the
/// `/series/` prefix; the id may itself contain `/` (split from the
/// right), and percent-escapes are passed through opaquely — the id on
/// the wire is the id in the store.
fn route_series(sh: &ServerShared, rest: &str, req: &ParsedRequest)
                -> Reply {
    let stack = &*sh.stack;
    let usage = "series routes are /v1/series/{id}/{observe|forecast|state}";
    let Some((id, action)) = rest.rsplit_once('/') else {
        return Reply::error(
            404, &format!("no route for {} {} — {usage}", req.method,
                          req.path),
            None);
    };
    if id.is_empty() {
        return Reply::error(
            404, &format!("empty series id in {} — {usage}", req.path),
            None);
    }
    match (req.method.as_str(), action) {
        ("POST", "observe") => handle_observe(stack, id, &req.body),
        ("GET", "forecast") => handle_series_forecast(stack, id, &req.query),
        ("POST", "forecast") => handle_forecast_for(stack, id, &req.body),
        ("GET", "state") => handle_series_state(stack, id, &req.query),
        (_, "observe" | "forecast" | "state") => Reply::error(
            405,
            &format!("method {} not allowed for {}", req.method, req.path),
            None),
        _ => Reply::error(
            404, &format!("no route for {} {} — {usage}", req.method,
                          req.path),
            None),
    }
}

/// Fill in an omitted `freq` from the stack's single frequency, or
/// explain which ones must be named.
fn resolve_parsed_freq(stack: &ShardedStack, freq: Option<Frequency>)
                       -> Result<Frequency> {
    match freq {
        Some(f) => Ok(f),
        None => stack.single_frequency().ok_or_else(|| {
            anyhow!("`freq` is required when serving multiple frequencies \
                     ({})",
                    stack
                        .frequencies()
                        .iter()
                        .map(|f| f.name())
                        .collect::<Vec<_>>()
                        .join(", "))
        }),
    }
}

/// Resolve `freq` for the GET series routes from the `?freq=` query
/// parameter (body-less requests), falling back to the stack's single
/// frequency.
fn resolve_freq_query(stack: &ShardedStack, query: &str)
                      -> Result<Frequency> {
    for pair in query.split('&') {
        let Some((k, v)) = pair.split_once('=') else { continue };
        if k == "freq" {
            return Frequency::parse(v);
        }
    }
    resolve_parsed_freq(stack, None)
}

/// Status mapping: malformed / unroutable / too-short requests are 400;
/// a queue-full backpressure rejection is 429 + `Retry-After` (the
/// request was valid — the server is asking the client to slow down);
/// faults *while serving* a valid request (backend error, pool shut
/// down) are 500 so monitoring and load balancers see a server outage,
/// not a client mistake.
fn handle_forecast(stack: &ShardedStack, body: &str) -> Reply {
    let (freq, req) = match parse_forecast_request(stack, body, None) {
        Ok(x) => x,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    run_forecast(stack, freq, req)
}

/// `POST /v1/series/{id}/forecast`: the same stateless forecast as the
/// deprecated `/v1/forecast` alias, with the series id taken from the
/// resource path (a body `id`, if present, is ignored).
fn handle_forecast_for(stack: &ShardedStack, id: &str, body: &str) -> Reply {
    let (freq, req) = match parse_forecast_request(stack, body, Some(id)) {
        Ok(x) => x,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    run_forecast(stack, freq, req)
}

fn run_forecast(stack: &ShardedStack, freq: Frequency, req: ForecastRequest)
                -> Reply {
    match stack.forecast(freq, req) {
        Ok(resp) => Reply::json(
            200,
            api::ForecastResponse {
                id: resp.id,
                freq,
                generation: resp.generation,
                forecast: resp.forecast,
            }
            .to_json(),
            None),
        Err(e) if e.is::<QueueFull>() => {
            Reply::error(429, &format!("{e:#}"), Some(1))
        }
        Err(e) => Reply::error(500, &format!("{e:#}"), None),
    }
}

/// `POST /v1/series/{id}/observe`: advance the series' ES recurrence.
/// Typed faults map per the status contract: a replayed batch → 409
/// `stale_observation`, a batch that skips ahead → 400, queue
/// backpressure → 429; anything else while applying a valid batch is a
/// server fault (500).
fn handle_observe(stack: &ShardedStack, id: &str, body: &str) -> Reply {
    let parsed = Json::parse(body)
        .context("request body")
        .and_then(|doc| api::ObserveRequest::from_json(&doc));
    let req = match parsed {
        Ok(r) => r,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    let freq = match resolve_parsed_freq(stack, req.freq) {
        Ok(f) => f,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    if req.values.is_empty() {
        return Reply::error(
            400, "an observe batch needs at least one value", None);
    }
    match stack.observe(freq, id, &req.values, req.t0) {
        Ok(out) => Reply::json(
            200,
            api::ObserveResponse {
                id: id.to_string(),
                freq,
                observed: out.observed,
                generation: out.generation,
                new_series: out.new_series,
            }
            .to_json(),
            None),
        Err(e) if e.is::<StaleObservation>() => {
            Reply::error_coded(409, "stale_observation", &format!("{e:#}"))
        }
        Err(e) if e.is::<ObservationGap>() => {
            Reply::error(400, &format!("{e:#}"), None)
        }
        Err(e) if e.is::<QueueFull>() => {
            Reply::error(429, &format!("{e:#}"), Some(1))
        }
        Err(e) => Reply::error(500, &format!("{e:#}"), None),
    }
}

/// `GET /v1/series/{id}/forecast`: stateful forecast from the stored
/// ES state — no history values on the wire.
fn handle_series_forecast(stack: &ShardedStack, id: &str, query: &str)
                          -> Reply {
    let freq = match resolve_freq_query(stack, query) {
        Ok(f) => f,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    match stack.series_forecast(freq, id) {
        Ok(resp) => Reply::json(
            200,
            api::ForecastResponse {
                id: resp.id,
                freq,
                generation: resp.generation,
                forecast: resp.forecast,
            }
            .to_json(),
            None),
        Err(e) if e.is::<UnknownSeries>() => {
            Reply::error_coded(404, "unknown_series", &format!("{e:#}"))
        }
        Err(e) => Reply::error(500, &format!("{e:#}"), None),
    }
}

/// `GET /v1/series/{id}/state`: the stored ES state, seasonal rings in
/// phase order.
fn handle_series_state(stack: &ShardedStack, id: &str, query: &str)
                       -> Reply {
    let freq = match resolve_freq_query(stack, query) {
        Ok(f) => f,
        Err(e) => return Reply::error(400, &format!("{e:#}"), None),
    };
    match stack.series_record(freq, id) {
        Ok(rec) => Reply::json(
            200,
            api::SeriesState {
                id: id.to_string(),
                freq,
                observed: rec.state.observed,
                generation: rec.generation,
                level: rec.state.level,
                seasonality: rec.state.ring1,
                seasonality2: rec.state.ring2,
            }
            .to_json(),
            None),
        Err(e) if e.is::<UnknownSeries>() => {
            Reply::error_coded(404, "unknown_series", &format!("{e:#}"))
        }
        Err(e) => Reply::error(500, &format!("{e:#}"), None),
    }
}

/// Round-robin discriminator for requests that omit `id`. A constant
/// fallback would consistent-hash every anonymous request onto one
/// shard (one fixed ring point), idling the rest of the fleet; a
/// rotating synthetic id spreads them evenly, and placement stability
/// only matters for *named* series anyway.
static ANON_SEQ: AtomicU64 = AtomicU64::new(0);

/// Validate everything client-controlled up front, including the history
/// length (mirroring the pool's own submit-time check) so a short
/// request is a clean 400 before it ever reaches the queue. `path_id`,
/// when present (the resource route), wins over any body `id`.
fn parse_forecast_request(stack: &ShardedStack, body: &str,
                          path_id: Option<&str>)
                          -> Result<(Frequency, ForecastRequest)> {
    let doc = Json::parse(body).context("request body")?;
    let wire = api::ForecastRequest::from_json(&doc)?;
    let freq = resolve_parsed_freq(stack, wire.freq)?;
    let id = match path_id {
        Some(p) => p.to_string(),
        None => wire.id.unwrap_or_else(|| {
            format!("http-{}", ANON_SEQ.fetch_add(1, Ordering::Relaxed))
        }),
    };
    let category = wire.category.unwrap_or(Category::Other);
    let need = stack.required_length(freq)?;
    if wire.values.len() < need {
        bail!("request needs ≥ {need} history values for {}, got {}",
              freq.name(), wire.values.len());
    }
    Ok((freq, ForecastRequest { id, values: wire.values, category }))
}

fn handle_reload(stack: &ShardedStack, body: &str) -> Result<Json> {
    let doc = Json::parse(body).context("request body")?;
    let req = api::ReloadRequest::from_json(&doc)?;
    let freq = resolve_parsed_freq(stack, req.freq)?;
    let generation = stack.reload_checkpoint(freq, &req.checkpoint)?;
    Ok(Json::obj(vec![
        ("freq", Json::str(freq.name())),
        ("generation", Json::num(generation as f64)),
    ]))
}

/// `GET /v1/stats`: schema version 1 — `{"schema_version", "serving",
/// "http", "backend", "shards"}` with field names matching the
/// `/v1/metrics` metric names one-for-one (minus the `fesrnn_` prefix)
/// so the two surfaces join without a translation table.
fn handle_stats(sh: &ServerShared) -> Json {
    // One snapshot, folded twice: the aggregate is computed from the
    // same per-shard view it is reported next to, so the top-level
    // numbers always equal the sum of the `"shards"` breakdown (two
    // separate snapshots could disagree under live traffic), and every
    // pool's stats mutexes are taken once per /stats, not twice.
    let per_shard = sh.stack.shard_stats();
    let mut agg: BTreeMap<Frequency, ServiceStats> = BTreeMap::new();
    for by_freq in per_shard.values() {
        for (f, s) in by_freq {
            agg.entry(*f).or_default().absorb(s);
        }
    }
    let serving_json = |by_freq: &BTreeMap<Frequency, ServiceStats>| {
        Json::Obj(by_freq
            .iter()
            .map(|(f, s)| (f.name().to_string(), s.to_json()))
            .collect())
    };
    let serving = serving_json(&agg);
    // Backend gauges summed over frequencies (shards already summed by
    // absorb above).
    let (mut spawns, mut steady, mut scratch) = (0u64, 0u64, 0u64);
    for s in agg.values() {
        spawns += s.backend_spawns;
        steady += s.backend_steady_allocs;
        scratch += s.backend_scratch_bytes;
    }
    let backend = Json::obj(vec![
        ("backend_spawns", Json::num(spawns as f64)),
        ("backend_steady_allocs", Json::num(steady as f64)),
        ("backend_scratch_bytes", Json::num(scratch as f64)),
    ]);
    let shards = Json::Arr(
        per_shard
            .iter()
            .map(|(label, by_freq)| {
                Json::obj(vec![
                    ("shard", Json::str(label.as_str())),
                    ("serving", serving_json(by_freq)),
                ])
            })
            .collect(),
    );
    // Front-end connection health: which knob to turn when clients see
    // 503s — `backlog_full` wants a bigger backlog / more capacity,
    // `stale_in_backlog` wants more / faster connection workers.
    let m = &sh.metrics;
    let responses = Json::Obj(
        m.by_code
            .iter()
            .map(|(c, counter)| {
                (c.to_string(), Json::num(counter.get() as f64))
            })
            .collect(),
    );
    let http = Json::obj(vec![
        ("http_accept_backlog", Json::num(sh.opts.accept_backlog as f64)),
        ("http_conn_workers", Json::num(sh.opts.conn_workers as f64)),
        ("http_connections_total", Json::num(m.connections.get() as f64)),
        ("http_deprecated_requests_total",
         Json::num(m.deprecated.get() as f64)),
        ("http_keepalive_rotations_total",
         Json::num(m.rotations.get() as f64)),
        ("http_responses_total", responses),
        ("http_sheds_total",
         Json::obj(vec![
             ("backlog_full", Json::num(m.sheds_backlog.get() as f64)),
             ("stale_in_backlog", Json::num(m.sheds_stale.get() as f64)),
         ])),
    ]);
    // Distributed-serving view: replication factor, hedge counters,
    // and per-shard health (local shards are trivially healthy; remote
    // ones carry their prober's verdict).
    let shard_health = Json::Obj(
        sh.stack
            .shard_health()
            .into_iter()
            .map(|(label, h)| {
                let mut fields = vec![
                    ("kind", Json::str(h.kind)),
                    ("healthy", Json::Bool(h.healthy)),
                    ("probe_failures_total",
                     Json::num(h.probe_failures as f64)),
                    ("ejections_total", Json::num(h.ejections as f64)),
                ];
                if let Some(addr) = &h.addr {
                    fields.push(("addr", Json::str(addr.as_str())));
                }
                (label, Json::obj(fields))
            })
            .collect(),
    );
    let remote = Json::obj(vec![
        ("replicas", Json::num(sh.stack.replicas() as f64)),
        ("hedges_total", Json::num(sh.stack.hedges() as f64)),
        ("hedge_wins_total", Json::num(sh.stack.hedge_wins() as f64)),
        ("shards", shard_health),
    ]);
    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("serving", serving),
        ("http", http),
        ("backend", backend),
        ("remote", remote),
        ("shards", shards),
    ])
}

fn handle_healthz(stack: &ShardedStack) -> Json {
    let freqs = stack.frequencies();
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("frequencies",
         Json::Arr(freqs.iter().map(|f| Json::str(f.name())).collect())),
        ("shards",
         Json::Arr(stack
             .shard_labels()
             .into_iter()
             .map(Json::Str)
             .collect())),
        ("generations",
         Json::Obj(
             freqs
                 .iter()
                 .map(|f| {
                     (f.name().to_string(),
                      Json::num(stack.generation(*f).unwrap_or(0) as f64))
                 })
                 .collect(),
         )),
        // Input-window lengths per frequency, so a RemoteShard joining
        // this server can validate request lengths client-side without
        // a round-trip per forecast.
        ("required_lengths",
         Json::Obj(
             freqs
                 .iter()
                 .filter_map(|f| {
                     stack.required_length(*f).ok().map(|n| {
                         (f.name().to_string(), Json::num(n as f64))
                     })
                 })
                 .collect(),
         )),
    ])
}

fn write_response(stream: &mut TcpStream, code: u16, body: &str,
                  content_type: &str, keep_alive: bool,
                  retry_after: Option<u32>, successor: Option<&str>)
                  -> std::io::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let mut head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\n",
        body.len());
    if let Some(secs) = retry_after {
        head.push_str(&format!("Retry-After: {secs}\r\n"));
    }
    if let Some(succ) = successor {
        // Deprecation signal on legacy path aliases (RFC 9745 style):
        // the request worked, and here is where it should go instead.
        head.push_str("Deprecation: true\r\n");
        head.push_str(
            &format!("Link: <{succ}>; rel=\"successor-version\"\r\n"));
    }
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

// ---------------------------------------------------------------------
// Clients
// ---------------------------------------------------------------------

/// Whether an I/O error means the peer tore the connection down (vs a
/// timeout or a local fault).
fn is_conn_dead(e: &std::io::Error) -> bool {
    matches!(e.kind(),
             std::io::ErrorKind::ConnectionReset
             | std::io::ErrorKind::ConnectionAborted
             | std::io::ErrorKind::BrokenPipe)
}

/// Typed marker for "the keep-alive socket was already dead": EOF
/// before a single response byte. The server cannot have sent anything,
/// and with it almost certainly never processed the request (an idle
/// close RSTs in-flight data) — the one failure [`HttpClient`] may
/// safely retry without risking double execution. Read timeouts and
/// mid-response EOFs are deliberately NOT this error: there the request
/// may have executed server-side.
#[derive(Debug)]
struct StaleConnection;

impl std::fmt::Display for StaleConnection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection closed before any response byte (stale \
                   keep-alive socket)")
    }
}

impl std::error::Error for StaleConnection {}

/// One parsed HTTP response.
#[derive(Debug, Clone)]
pub struct HttpReply {
    pub code: u16,
    /// Header (name, value) pairs; names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpReply {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Deadlines for [`HttpClient`] connections. A dead peer must cost a
/// bounded timeout, never a hang: `connect_timeout` caps the TCP dial
/// (the default `TcpStream::connect` can block for minutes on a
/// blackholed address) and `read_timeout` caps each socket read while
/// waiting for a reply.
#[derive(Debug, Clone, Copy)]
pub struct ClientOptions {
    pub connect_timeout: Duration,
    pub read_timeout: Duration,
}

impl Default for ClientOptions {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// Minimal blocking keep-alive HTTP/1.1 client: one persistent
/// connection serving many sequential requests — the cheap path the
/// serving benches measure against connection-per-request
/// [`http_request`]. Content-Length framed (which this server always
/// emits).
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
    addr: String,
    opts: ClientOptions,
    /// The server advertised `Connection: close` on the last reply;
    /// reconnect lazily before the next request (eager reconnection
    /// could fail — e.g. server shutting down — and would throw away a
    /// reply that was already successfully received).
    dead: bool,
    /// A request is in flight or died mid-flight. Set on entry to
    /// [`request`](Self::request), cleared only when a reply was fully
    /// parsed — so after a timeout or mid-response error the connection
    /// admits its read buffer may hold a partial reply. A poisoned
    /// client must not be returned to a [`ClientPool`]: the next
    /// request would misparse the leftover bytes as its own reply.
    poisoned: bool,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with(addr, ClientOptions::default())
    }

    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<Self> {
        let stream = Self::open(addr, &opts)?;
        Ok(Self {
            stream,
            buf: Vec::with_capacity(4096),
            addr: addr.into(),
            opts,
            dead: false,
            poisoned: false,
        })
    }

    fn open(addr: &str, opts: &ClientOptions) -> Result<TcpStream> {
        // `TcpStream::connect(&str)` has no timeout variant, so resolve
        // first and dial each candidate address under the deadline.
        let resolved = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?;
        let mut last_err: Option<std::io::Error> = None;
        let mut stream = None;
        for sock in resolved {
            match TcpStream::connect_timeout(&sock, opts.connect_timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => {
                let cause = last_err
                    .map(|e| e.to_string())
                    .unwrap_or_else(|| "no addresses resolved".into());
                bail!("connecting {addr}: {cause}");
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(opts.read_timeout))?;
        Ok(stream)
    }

    /// `false` once a request failed mid-flight: the read buffer may
    /// hold a partial reply, so the connection must be discarded rather
    /// than reused. (`dead` is not unhealthy — an advertised
    /// `Connection: close` reconnects lazily and cleanly.)
    pub fn healthy(&self) -> bool {
        !self.poisoned
    }

    /// Send one request on the persistent connection and read its
    /// reply. Server-initiated closes are handled transparently:
    /// advertised ones (`Connection: close` — worker rotation at
    /// `max_requests_per_conn`, shutdown) reconnect eagerly for the
    /// next request, and a silent idle close (the server's `keep_alive`
    /// timeout firing between calls) is recovered by one retry on a
    /// fresh connection.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&str>)
                   -> Result<HttpReply> {
        let body = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n\
             {body}",
            self.addr,
            body.len());
        if self.dead {
            self.reconnect()?;
        }
        self.poisoned = true;
        let reply = match self.try_request(&req) {
            Ok(reply) => reply,
            // Only the provably-unprocessed failure is retried: a
            // timeout or mid-response EOF may mean the server already
            // executed the (possibly non-idempotent) request.
            Err(e) if e.is::<StaleConnection>() => {
                self.reconnect()?;
                self.try_request(&req)?
            }
            Err(e) => return Err(e),
        };
        self.poisoned = false;
        // An advertised close (worker rotation, shutdown) marks the
        // connection for lazy reconnection — the reply in hand is still
        // returned even if the server is gone by now.
        self.dead = reply.header("connection") == Some("close");
        Ok(reply)
    }

    fn reconnect(&mut self) -> Result<()> {
        self.stream = Self::open(&self.addr, &self.opts)?;
        self.buf.clear();
        self.dead = false;
        Ok(())
    }

    fn try_request(&mut self, req: &str) -> Result<HttpReply> {
        if let Err(e) = self
            .stream
            .write_all(req.as_bytes())
            .and_then(|()| self.stream.flush())
        {
            // A request whose write failed was never processed — if the
            // failure smells like a dead socket, mark it retryable.
            return Err(if is_conn_dead(&e) {
                anyhow::Error::new(StaleConnection)
            } else {
                e.into()
            });
        }
        self.read_reply()
    }

    fn read_reply(&mut self) -> Result<HttpReply> {
        let mut tmp = [0u8; 4096];
        let header_end = loop {
            if let Some(pos) = find_subsequence(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            let n = match self.stream.read(&mut tmp) {
                Ok(n) => n,
                // On a low-RTT link a server idle-close usually shows
                // up as ECONNRESET (our write drew an RST), not a clean
                // EOF — with zero response bytes it is the same
                // provably-unprocessed case, so equally retryable.
                Err(e) if self.buf.is_empty() && is_conn_dead(&e) => {
                    return Err(anyhow::Error::new(StaleConnection));
                }
                Err(e) => return Err(e.into()),
            };
            if n == 0 {
                if self.buf.is_empty() {
                    // Zero response bytes: the socket was dead before
                    // we used it (server idle-close) — retryable.
                    return Err(anyhow::Error::new(StaleConnection));
                }
                bail!("server closed the connection mid-response");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        };
        let head = std::str::from_utf8(&self.buf[..header_end])
            .context("response head is not UTF-8")?;
        let mut lines = head.split("\r\n");
        let code = lines
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| anyhow!("malformed HTTP status line"))?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        for line in lines {
            if let Some((k, v)) = line.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_length = v
                        .parse()
                        .map_err(|_| anyhow!("bad Content-Length `{v}`"))?;
                }
                headers.push((k, v));
            }
        }
        let body_start = header_end + 4;
        let needed = body_start + content_length;
        while self.buf.len() < needed {
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                bail!("server closed the connection mid-response body");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
        let body = std::str::from_utf8(&self.buf[body_start..needed])
            .context("response body is not UTF-8")?
            .to_string();
        self.buf.drain(..needed);
        Ok(HttpReply { code, headers, body })
    }
}

/// A small pool of idle keep-alive connections to one address, shared
/// across threads (hedged reads hit the same remote from concurrent
/// threads). [`get`](Self::get) pops an idle connection or dials a
/// fresh one; the [`PooledClient`] guard returns it on drop — but only
/// if [`HttpClient::healthy`] still holds, so a connection whose
/// request died mid-response is discarded instead of poisoning the
/// next caller with its partial read buffer.
pub struct ClientPool {
    addr: String,
    opts: ClientOptions,
    max_idle: usize,
    // lint:lock-name(http.client_pool)
    idle: Mutex<Vec<HttpClient>>,
}

impl ClientPool {
    pub fn new(addr: &str, opts: ClientOptions, max_idle: usize) -> Self {
        Self {
            addr: addr.into(),
            opts,
            max_idle,
            idle: Mutex::new(Vec::new()),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Check out a connection: reuse an idle one when available,
    /// otherwise dial fresh (bounded by `opts.connect_timeout`). The
    /// pool never blocks waiting for a checkout to come back — a burst
    /// beyond `max_idle` simply dials extra connections that won't all
    /// be retained.
    pub fn get(&self) -> Result<PooledClient<'_>> {
        let reused = self.idle.lock().unwrap().pop();
        let client = match reused {
            Some(c) => c,
            None => HttpClient::connect_with(&self.addr, self.opts)?,
        };
        Ok(PooledClient { pool: self, client: Some(client) })
    }

    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().len()
    }

    fn put_back(&self, client: HttpClient) {
        if !client.healthy() {
            return;
        }
        let mut idle = self.idle.lock().unwrap();
        if idle.len() < self.max_idle {
            idle.push(client);
        }
    }
}

/// RAII checkout from a [`ClientPool`]: derefs to [`HttpClient`], and
/// on drop hands the connection back (or discards it if unhealthy).
pub struct PooledClient<'a> {
    pool: &'a ClientPool,
    client: Option<HttpClient>,
}

impl std::ops::Deref for PooledClient<'_> {
    type Target = HttpClient;

    fn deref(&self) -> &HttpClient {
        match &self.client {
            Some(c) => c,
            // Only `drop` takes the client, and it runs last.
            None => unreachable!("pooled client used after drop"),
        }
    }
}

impl std::ops::DerefMut for PooledClient<'_> {
    fn deref_mut(&mut self) -> &mut HttpClient {
        match &mut self.client {
            Some(c) => c,
            None => unreachable!("pooled client used after drop"),
        }
    }
}

impl Drop for PooledClient<'_> {
    fn drop(&mut self) {
        if let Some(client) = self.client.take() {
            self.pool.put_back(client);
        }
    }
}

/// Minimal blocking one-shot HTTP client: one request per connection
/// (`Connection: close`), returns `(status code, body)`. The expensive
/// path — kept for one-off operator calls and as the bench's
/// connection-per-request contender.
pub fn http_request(addr: &str, method: &str, path: &str, body: Option<&str>)
                    -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf)?;
    parse_http_response(std::str::from_utf8(&buf).context("response UTF-8")?)
}

/// Split a raw HTTP/1.1 response into (status code, body).
fn parse_http_response(text: &str) -> Result<(u16, String)> {
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response (no header end)"))?;
    let code = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| anyhow!("malformed HTTP status line"))?;
    Ok((code, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_parsing() {
        let (code, body) = parse_http_response(
            "HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\n{}").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "{}");
        assert!(parse_http_response("garbage").is_err());
        assert!(parse_http_response("HTTP/1.1 x\r\n\r\n").is_err());
    }

    #[test]
    fn subsequence_search() {
        assert_eq!(find_subsequence(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subsequence(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn error_envelope_shape() {
        let j = err_json(429, "boom", Some(2));
        let e = j.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "queue_full");
        assert_eq!(e.get("message").unwrap().as_str().unwrap(), "boom");
        assert_eq!(e.get("retry_after_ms").unwrap().as_f64().unwrap(),
                   2000.0);
        // No Retry-After header → no retry_after_ms field.
        let plain = err_json(400, "nope", None);
        let e = plain.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "bad_request");
        assert!(e.opt("retry_after_ms").is_none());
        // Route-refined codes override the status default …
        let coded = err_json_coded("stale_observation", "old batch", None);
        let e = coded.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(),
                   "stale_observation");
        // … and the refined replies still parse as the shared envelope.
        let reply = Reply::error_coded(404, "unknown_series", "who?");
        let env = api::ErrorEnvelope::from_json(
            &Json::parse(&reply.body).unwrap()).unwrap();
        assert_eq!(env.code, "unknown_series");
        assert_eq!(reply.code, 404);
    }

    #[test]
    fn every_emitted_status_has_a_machine_readable_code() {
        for (status, code) in [
            (400, "bad_request"),
            (404, "not_found"),
            (405, "method_not_allowed"),
            (408, "request_timeout"),
            (409, "conflict"),
            (413, "body_too_large"),
            (429, "queue_full"),
            (431, "headers_too_large"),
            (500, "internal"),
            (501, "not_implemented"),
            (503, "overloaded"),
        ] {
            assert_eq!(error_code(status), code, "status {status}");
        }
        assert_eq!(error_code(418), "error");
    }

    #[test]
    fn alias_normalization_maps_legacy_paths_onto_v1_routes() {
        assert_eq!(split_alias("/forecast"),
                   ("/forecast", Some("/v1/forecast")));
        assert_eq!(split_alias("/reload"), ("/reload", Some("/v1/reload")));
        assert_eq!(split_alias("/stats"), ("/stats", Some("/v1/stats")));
        assert_eq!(split_alias("/healthz"),
                   ("/healthz", Some("/v1/healthz")));
        assert_eq!(split_alias("/metrics"),
                   ("/metrics", Some("/v1/metrics")));
        // Native /v1 paths normalize without a deprecation successor …
        assert_eq!(split_alias("/v1/forecast"), ("/forecast", None));
        assert_eq!(split_alias("/v1/metrics"), ("/metrics", None));
        // … and unknown paths pass through untouched (→ 404).
        assert_eq!(split_alias("/nope"), ("/nope", None));
        assert_eq!(split_alias("/v2/forecast"), ("/v2/forecast", None));
        // Series routes normalize with no legacy successor: they are
        // /v1-native (route() additionally rejects the unversioned
        // spelling, which split_alias alone cannot distinguish).
        assert_eq!(split_alias("/v1/series/m1/observe"),
                   ("/series/m1/observe", None));
    }

    #[test]
    fn head_parsing_keep_alive_defaults() {
        // HTTP/1.1 defaults to keep-alive …
        let h = parse_head(b"GET /x HTTP/1.1\r\nHost: a", 100).unwrap();
        assert!(h.keep_alive);
        assert_eq!(h.method, "GET");
        assert_eq!(h.path, "/x");
        assert_eq!(h.query, "");
        // The query string is captured, not discarded.
        let h = parse_head(
            b"GET /v1/series/m1/state?freq=monthly HTTP/1.1\r\nHost: a",
            100)
            .unwrap();
        assert_eq!(h.path, "/v1/series/m1/state");
        assert_eq!(h.query, "freq=monthly");
        // … unless Connection: close; 1.0 defaults to close …
        let h = parse_head(b"GET / HTTP/1.1\r\nConnection: close", 100)
            .unwrap();
        assert!(!h.keep_alive);
        let h = parse_head(b"GET / HTTP/1.0\r\nHost: a", 100).unwrap();
        assert!(!h.keep_alive);
        // … unless it opts back in.
        let h = parse_head(b"GET / HTTP/1.0\r\nConnection: keep-alive", 100)
            .unwrap();
        assert!(h.keep_alive);
        // RFC 9110: close is sticky — a later keep-alive cannot revive
        // a connection an earlier header already asked to close.
        let h = parse_head(
            b"GET / HTTP/1.1\r\nConnection: close\r\n\
              Connection: keep-alive", 100)
            .unwrap();
        assert!(!h.keep_alive);
    }

    #[test]
    fn head_parsing_enforces_body_cap_before_buffering() {
        let h = parse_head(b"POST / HTTP/1.1\r\nContent-Length: 99", 100)
            .unwrap();
        assert_eq!(h.content_length, 99);
        // One byte over the cap → 413, even though no body was sent.
        let e = parse_head(b"POST / HTTP/1.1\r\nContent-Length: 101", 100)
            .unwrap_err();
        assert_eq!(e.0, 413);
        // A hostile declared length cannot trigger a huge allocation.
        let e = parse_head(
            b"POST / HTTP/1.1\r\nContent-Length: 999999999999999", 100)
            .unwrap_err();
        assert_eq!(e.0, 413);
        let e = parse_head(b"POST / HTTP/1.1\r\nContent-Length: nope", 100)
            .unwrap_err();
        assert_eq!(e.0, 400);
    }

    #[test]
    fn head_parsing_rejects_conflicting_content_lengths() {
        // RFC 9112 §6.3: conflicting values are a request-smuggling
        // vector on keep-alive connections — refuse to pick one.
        let e = parse_head(
            b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 44",
            100)
            .unwrap_err();
        assert_eq!(e.0, 400);
        // Duplicated-but-agreeing values are fine (some proxies do this).
        let h = parse_head(
            b"POST / HTTP/1.1\r\nContent-Length: 7\r\nContent-Length: 7",
            100)
            .unwrap();
        assert_eq!(h.content_length, 7);
    }

    #[test]
    fn head_parsing_rejects_chunked() {
        let e = parse_head(
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked", 100)
            .unwrap_err();
        assert_eq!(e.0, 501);
    }

    /// Raw single-connection server: accepts exactly one connection and
    /// answers `replies` keep-alive requests on it with `200 ok`, then
    /// holds the socket open. Because it never accepts a second
    /// connection, any request that succeeds after the first *must*
    /// have reused the pooled connection.
    fn serve_one_connection(listener: TcpListener, replies: usize)
                            -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            let mut tmp = [0u8; 1024];
            for _ in 0..replies {
                while find_subsequence(&buf, b"\r\n\r\n").is_none() {
                    let n = s.read(&mut tmp).unwrap();
                    if n == 0 {
                        return;
                    }
                    buf.extend_from_slice(&tmp[..n]);
                }
                let end = find_subsequence(&buf, b"\r\n\r\n").unwrap();
                buf.drain(..end + 4);
                s.write_all(
                    b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok")
                    .unwrap();
            }
            // Hold the connection until the client side is done.
            let _ = s.read(&mut tmp);
        })
    }

    #[test]
    fn connect_timeout_bounds_the_dial_to_a_dead_address() {
        // 192.0.2.0/24 is TEST-NET-1 (RFC 5737): never routable. The
        // default TcpStream::connect can block for minutes here; the
        // configured deadline must cap it (an instant network-unreachable
        // error also passes — the invariant is the bound, not the path).
        let opts = ClientOptions {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(200),
        };
        let t0 = Instant::now();
        let got = HttpClient::connect_with("192.0.2.1:9", opts);
        assert!(got.is_err(), "TEST-NET dial cannot succeed");
        assert!(t0.elapsed() < Duration::from_secs(3),
                "connect_timeout did not bound the dial: {:?}",
                t0.elapsed());
    }

    #[test]
    fn read_timeout_bounds_a_silent_server_and_poisons_the_client() {
        // The listener completes the TCP handshake (kernel backlog) but
        // never writes a byte: the request must fail within the read
        // deadline, and the connection must come back unhealthy — its
        // socket may still receive a late reply that would corrupt the
        // next request's framing.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ClientOptions {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_millis(300),
        };
        let mut client = HttpClient::connect_with(&addr, opts).unwrap();
        assert!(client.healthy());
        let t0 = Instant::now();
        assert!(client.request("GET", "/v1/healthz", None).is_err());
        assert!(t0.elapsed() < Duration::from_secs(3),
                "read_timeout did not bound the wait: {:?}", t0.elapsed());
        assert!(!client.healthy(),
                "a timed-out request must poison the connection");
        drop(listener);
    }

    #[test]
    fn pool_returns_clean_connections_and_reuses_them() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = serve_one_connection(listener, 2);
        let opts = ClientOptions {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(2),
        };
        let pool = ClientPool::new(&addr, opts, 4);
        assert_eq!(pool.idle_count(), 0);
        {
            let mut client = pool.get().unwrap();
            let reply = client.request("GET", "/x", None).unwrap();
            assert_eq!(reply.code, 200);
            assert_eq!(reply.body, "ok");
            assert!(client.healthy());
            assert_eq!(pool.idle_count(), 0, "still checked out");
        }
        assert_eq!(pool.idle_count(), 1,
                   "a healthy connection returns to the pool on drop");
        {
            // The server accepts exactly one connection, so this request
            // can only succeed over the pooled socket.
            let mut client = pool.get().unwrap();
            assert_eq!(pool.idle_count(), 0, "idle connection was reused");
            let reply = client.request("GET", "/x", None).unwrap();
            assert_eq!(reply.code, 200);
        }
        assert_eq!(pool.idle_count(), 1);
        drop(pool);
        server.join().unwrap();
    }

    #[test]
    fn pool_discards_a_connection_poisoned_mid_response() {
        // The server advertises a 10-byte body, sends 2 bytes, and
        // slams the connection: the request errs mid-response, and the
        // guard's Drop must discard the connection instead of handing
        // its half-read buffer to the next caller.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut tmp = [0u8; 1024];
            let _ = s.read(&mut tmp);
            s.write_all(
                b"HTTP/1.1 200 OK\r\nContent-Length: 10\r\n\r\nab")
                .unwrap();
            // Drop closes the socket mid-body.
        });
        let opts = ClientOptions {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(2),
        };
        let pool = ClientPool::new(&addr, opts, 4);
        {
            let mut client = pool.get().unwrap();
            assert!(client.request("GET", "/x", None).is_err());
            assert!(!client.healthy());
        }
        assert_eq!(pool.idle_count(), 0,
                   "a poisoned connection must not re-enter the pool");
        server.join().unwrap();
    }
}
