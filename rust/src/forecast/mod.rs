//! The serving subsystem: dynamic-batching forecast pools with
//! backpressure, a multi-frequency router with generation-tagged model
//! hot-swap, consistent-hash sharding, and a zero-dependency HTTP
//! front-end.
//!
//! Serving layers (one file each):
//!
//! * [`state`] — [`StateStore`]: the memory-mapped per-series ES state
//!   slab behind the stateful observe → forecast path (one instance per
//!   pool; see DESIGN.md §Stateful serving).
//! * [`api`] — typed wire DTOs for the `/v1` surface, shared by the
//!   server handlers, [`RemoteShard`], the CLI and tests.
//! * [`pool`] — [`FreqPool`]: N worker threads for one frequency, each
//!   owning its own backend (backends may be `!Send`), pulling
//!   drain-rounds from one shared dynamic-batching queue so executions
//!   overlap instead of serializing. The pool holds the current model in
//!   a generation-tagged swap slot: a reload publishes a new
//!   [`coordinator::ModelState`](crate::coordinator::ModelState) which
//!   workers adopt at batch boundaries — every response is produced from
//!   exactly one generation and tagged with it, and the request queue is
//!   never dropped.
//! * [`router`] — [`ServingStack`]: one pool per trained frequency,
//!   dispatching requests by frequency and exposing the hot-swap API
//!   (including checkpoint reloads in either persistence format).
//! * [`shard`] — [`ShardedStack`]: N shards behind a consistent-hash
//!   ring keyed by series id — stable assignment across restarts, ≈1/N
//!   key movement on shard add/remove, live drain, aggregated
//!   per-frequency stats, R-way replication (`set_replicas`) with
//!   hedged reads, and health-masked routing.
//! * [`remote`] — [`ShardClient`]: the dispatch trait the ring routes
//!   through. In-process `ServingStack`s are one impl; [`RemoteShard`]
//!   is the other — a keep-alive connection pool speaking the `/v1`
//!   wire format to another machine, with per-request deadlines and a
//!   background health prober driving ejection/readmission.
//! * [`http`] — [`HttpServer`]: the resource-first series surface
//!   (`POST /v1/series/{id}/observe`, `GET /v1/series/{id}/forecast`,
//!   `GET /v1/series/{id}/state`, `POST /v1/series/{id}/forecast` for
//!   stateless bodies), plus `GET /v1/stats`, `GET /v1/metrics`
//!   (Prometheus text), `GET /v1/healthz` and `POST /v1/reload` over
//!   `std::net::TcpListener` and
//!   [`util::json`](crate::util::json) — no async runtime, no
//!   frameworks (the unversioned paths remain as deprecated aliases).
//!   HTTP/1.1 keep-alive on a bounded pool of connection-handler
//!   workers with an accept backlog; overload is shed as `429` (pool
//!   queue full, [`QueueFull`]) or `503` (backlog full), never an
//!   unbounded queue, and every non-2xx body is the
//!   `{"error": {"code", "message", ...}}` envelope.
//!
//! [`ForecastService`] keeps the original single-frequency API as a thin
//! wrapper over a one-pool stack: existing callers (tests, examples, the
//! CLI demo path) keep working unchanged.

pub mod api;
pub mod http;
pub mod pool;
pub mod remote;
pub mod router;
pub mod shard;
pub mod state;

pub use http::{ClientOptions, ClientPool, HttpClient, HttpOptions,
               HttpReply, HttpServer};
pub use pool::{ForecastHandle, FreqPool, ObserveOutcome, QueueFull};
pub use remote::{RemoteOptions, RemoteShard, ShardClient, ShardHealth};
pub use router::ServingStack;
pub use shard::{HashRing, ShardedStack};
pub use state::{SeriesRecord, StateStore};

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;

use crate::config::{Category, Frequency};
use crate::coordinator::ModelState;
use crate::runtime::{Backend, NativeBackend};
use crate::telemetry::LatencySummary;
use crate::util::json::Json;

/// A single forecast request: raw history (≥ C values) + category.
#[derive(Debug, Clone)]
pub struct ForecastRequest {
    pub id: String,
    pub values: Vec<f32>,
    pub category: Category,
}

/// The H-step forecast for one request, tagged with the model generation
/// that produced it (every value comes from that one coherent state).
#[derive(Debug, Clone)]
pub struct ForecastResponse {
    pub id: String,
    pub forecast: Vec<f32>,
    pub generation: u64,
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceOptions {
    /// How long a worker holds the first request of a round while more
    /// arrive.
    pub batch_window: Duration,
    /// Cap on requests drained per batching round. May exceed the largest
    /// available batch program: the round is split into multiple
    /// executions, each padded-accounted individually.
    pub max_batch: usize,
    /// Worker threads per frequency, each with its own backend. 1 keeps
    /// the original single-thread service behavior.
    pub workers: usize,
    /// Backpressure: maximum accepted-but-undrained requests the pool
    /// will queue. A submit beyond this depth is rejected with a typed
    /// [`QueueFull`] error (the HTTP layer maps it to `429` +
    /// `Retry-After`) instead of growing the queue without bound — under
    /// a traffic spike the excess is shed instead of degrading every
    /// queued request. `0` disables the limit.
    pub queue_limit: usize,
    /// Directory for the durable per-series ES state store
    /// ([`StateStore`]). `None` (the default) keeps live state in
    /// memory only — observes still work, they just don't survive a
    /// restart. Each pool stores under `<state_dir>/<freq>/`.
    pub state_dir: Option<PathBuf>,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        Self {
            batch_window: Duration::from_millis(4),
            max_batch: 256,
            workers: 1,
            queue_limit: 1024,
            state_dir: None,
        }
    }
}

/// Counters + latency percentiles exposed for tests/benches and the
/// `GET /v1/stats` endpoint. Latencies are sliding-window percentiles
/// from [`telemetry::Quantiles`](crate::telemetry::Quantiles), in
/// seconds.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct ServiceStats {
    /// Requests accepted into the queue.
    pub requests: u64,
    /// Requests rejected before enqueue (short history etc.).
    pub rejected: u64,
    /// Requests shed with [`QueueFull`] because the queue was at
    /// `queue_limit` (HTTP 429).
    pub rejected_overload: u64,
    /// Executed batches (one per backend execution, not per drain round).
    pub batches: u64,
    pub padded_slots: u64,
    /// Completed model hot-swaps.
    pub reloads: u64,
    /// Current model generation.
    pub generation: u64,
    /// Worker threads serving the pool.
    pub workers: usize,
    /// Accepted-but-undrained requests at snapshot time (gauge).
    pub queue_depth: usize,
    /// The configured backpressure limit (0 = unbounded).
    pub queue_limit: usize,
    /// Enqueue → drain-round pickup.
    pub queue_wait: LatencySummary,
    /// Backend execution, per request (chunk time attributed to each
    /// request in the chunk).
    pub execute: LatencySummary,
    /// Enqueue → response sent.
    pub total: LatencySummary,
    /// OS threads the backend spawned (a persistent compute pool
    /// plateaus at its worker count).
    pub backend_spawns: u64,
    /// Post-warmup steady-state heap allocations charged by the backend
    /// (nonzero only under the counting allocator).
    pub backend_steady_allocs: u64,
    /// Bytes pinned by the backend's reusable compute arenas.
    pub backend_scratch_bytes: u64,
    /// Observe requests processed (accepted + rejected).
    pub observe_requests: u64,
    /// Observes that seeded a brand-new series state.
    pub observe_new_series: u64,
    /// Observes rejected because the batch rewound time
    /// (`stale_observation`, HTTP 409).
    pub observe_stale: u64,
    /// Series with live ES state in the store (gauge).
    pub state_series: u64,
    /// State-store slab footprint in bytes (gauge).
    pub state_bytes: u64,
    /// Stateful forecast served from the per-series cache.
    pub state_cache_hits: u64,
    /// Stateful forecast recomputed (cold or invalidated key).
    pub state_cache_misses: u64,
    /// Cache entries dropped by an observe on the same series.
    pub state_cache_invalidations: u64,
}

impl ServiceStats {
    /// JSON shape served inside `GET /v1/stats` (`schema_version` 1).
    /// Every field name matches its `/v1/metrics` metric name (minus
    /// the `fesrnn_` prefix) one-for-one so dashboards can join the
    /// two; latencies are `{count, p50, p95, p99}` in **seconds**, like
    /// the `_seconds` histograms.
    pub fn to_json(&self) -> Json {
        let lat = |s: &LatencySummary| {
            Json::obj(vec![
                ("count", Json::num(s.count as f64)),
                ("p50", Json::num(s.p50)),
                ("p95", Json::num(s.p95)),
                ("p99", Json::num(s.p99)),
            ])
        };
        Json::obj(vec![
            ("queue_submitted_total",
             Json::num((self.requests + self.rejected_overload) as f64)),
            ("queue_accepted_total", Json::num(self.requests as f64)),
            ("queue_shed_total",
             Json::num(self.rejected_overload as f64)),
            ("queue_rejected_total", Json::num(self.rejected as f64)),
            ("batches_total", Json::num(self.batches as f64)),
            ("padded_slots_total", Json::num(self.padded_slots as f64)),
            ("reloads_total", Json::num(self.reloads as f64)),
            ("model_generation", Json::num(self.generation as f64)),
            ("pool_workers", Json::num(self.workers as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("queue_limit", Json::num(self.queue_limit as f64)),
            ("queue_wait_seconds", lat(&self.queue_wait)),
            ("execute_seconds", lat(&self.execute)),
            ("request_total_seconds", lat(&self.total)),
            ("backend_spawns", Json::num(self.backend_spawns as f64)),
            ("backend_steady_allocs",
             Json::num(self.backend_steady_allocs as f64)),
            ("backend_scratch_bytes",
             Json::num(self.backend_scratch_bytes as f64)),
            ("observe_requests_total",
             Json::num(self.observe_requests as f64)),
            ("observe_new_series_total",
             Json::num(self.observe_new_series as f64)),
            ("observe_stale_total", Json::num(self.observe_stale as f64)),
            ("state_series", Json::num(self.state_series as f64)),
            ("state_bytes", Json::num(self.state_bytes as f64)),
            ("state_cache_hits_total",
             Json::num(self.state_cache_hits as f64)),
            ("state_cache_misses_total",
             Json::num(self.state_cache_misses as f64)),
            ("state_cache_invalidations_total",
             Json::num(self.state_cache_invalidations as f64)),
        ])
    }

    /// Parse the [`to_json`](Self::to_json) shape back — the round-trip
    /// contract a dashboard consuming `/v1/stats` relies on.
    /// (`queue_submitted_total` is derived, so it is validated as
    /// redundant rather than stored.)
    pub fn from_json(j: &Json) -> Result<Self> {
        let lat = |j: &Json| -> Result<LatencySummary> {
            Ok(LatencySummary {
                count: j.get("count")?.as_f64()? as u64,
                p50: j.get("p50")?.as_f64()?,
                p95: j.get("p95")?.as_f64()?,
                p99: j.get("p99")?.as_f64()?,
            })
        };
        let n = |key: &str| -> Result<u64> {
            Ok(j.get(key)?.as_f64()? as u64)
        };
        // Fields added after PR 9 parse leniently (default 0) so a newer
        // router can still aggregate stats from an older remote shard.
        let opt_n = |key: &str| -> Result<u64> {
            match j.opt(key) {
                Some(v) => Ok(v.as_f64()? as u64),
                None => Ok(0),
            }
        };
        Ok(ServiceStats {
            requests: n("queue_accepted_total")?,
            rejected: n("queue_rejected_total")?,
            rejected_overload: n("queue_shed_total")?,
            batches: n("batches_total")?,
            padded_slots: n("padded_slots_total")?,
            reloads: n("reloads_total")?,
            generation: n("model_generation")?,
            workers: j.get("pool_workers")?.as_usize()?,
            queue_depth: j.get("queue_depth")?.as_usize()?,
            queue_limit: j.get("queue_limit")?.as_usize()?,
            queue_wait: lat(j.get("queue_wait_seconds")?)?,
            execute: lat(j.get("execute_seconds")?)?,
            total: lat(j.get("request_total_seconds")?)?,
            backend_spawns: n("backend_spawns")?,
            backend_steady_allocs: n("backend_steady_allocs")?,
            backend_scratch_bytes: n("backend_scratch_bytes")?,
            observe_requests: opt_n("observe_requests_total")?,
            observe_new_series: opt_n("observe_new_series_total")?,
            observe_stale: opt_n("observe_stale_total")?,
            state_series: opt_n("state_series")?,
            state_bytes: opt_n("state_bytes")?,
            state_cache_hits: opt_n("state_cache_hits_total")?,
            state_cache_misses: opt_n("state_cache_misses_total")?,
            state_cache_invalidations:
                opt_n("state_cache_invalidations_total")?,
        })
    }

    /// Fold another pool's stats into this one — how [`ShardedStack`]
    /// aggregates across shards. Counters, worker counts and queue
    /// depths sum (the aggregate is the fleet's capacity); limits sum
    /// too, except that the `0 = unbounded` sentinel is sticky (one
    /// unbounded shard makes the fleet unbounded); `generation` takes
    /// the max (shards reload independently; the max is the newest
    /// model any shard serves); latency percentiles take the worst
    /// shard (see
    /// [`LatencySummary::absorb_worst`](crate::telemetry::LatencySummary::absorb_worst)).
    pub fn absorb(&mut self, other: &ServiceStats) {
        // `queue_limit: 0` means *unbounded* — that sentinel must be
        // sticky under aggregation, or a fleet with one unbounded shard
        // would report a finite capacity it does not have. A live pool
        // always has workers ≥ 1, so `workers == 0` identifies a
        // fresh accumulator (adopt the first shard's limit verbatim).
        // Computed before `workers` is summed below.
        self.queue_limit = if self.workers == 0 {
            other.queue_limit
        } else if self.queue_limit == 0 || other.queue_limit == 0 {
            0
        } else {
            self.queue_limit + other.queue_limit
        };
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.rejected_overload += other.rejected_overload;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.reloads += other.reloads;
        self.generation = self.generation.max(other.generation);
        self.workers += other.workers;
        self.queue_depth += other.queue_depth;
        self.queue_wait.absorb_worst(&other.queue_wait);
        self.execute.absorb_worst(&other.execute);
        self.total.absorb_worst(&other.total);
        self.backend_spawns += other.backend_spawns;
        self.backend_steady_allocs += other.backend_steady_allocs;
        self.backend_scratch_bytes += other.backend_scratch_bytes;
        self.observe_requests += other.observe_requests;
        self.observe_new_series += other.observe_new_series;
        self.observe_stale += other.observe_stale;
        self.state_series += other.state_series;
        self.state_bytes += other.state_bytes;
        self.state_cache_hits += other.state_cache_hits;
        self.state_cache_misses += other.state_cache_misses;
        self.state_cache_invalidations += other.state_cache_invalidations;
    }
}

/// Pick the smallest available batch that fits `n`; callers must have
/// already split `n` to at most the largest available size.
pub(crate) fn pick_batch(available: &[usize], n: usize) -> usize {
    available
        .iter()
        .copied()
        .filter(|b| *b >= n)
        .min()
        .unwrap_or_else(|| available.iter().copied().max().unwrap_or(1))
}

/// Split a pending set of `n` requests into per-execution real counts,
/// each at most the largest available batch program. A drain round larger
/// than the biggest program becomes several executions instead of
/// silently truncating (the old behavior under-counted `padded_slots`
/// and over-read the forecast buffer).
pub(crate) fn plan_batches(available: &[usize], n: usize) -> Vec<usize> {
    let cap = available.iter().copied().max().unwrap_or(1);
    let mut plan = Vec::with_capacity(n.div_ceil(cap));
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(cap);
        plan.push(take);
        remaining -= take;
    }
    plan
}

/// A running single-frequency forecast service: the original API, now a
/// wrapper over a one-frequency [`FreqPool`] (`opts.workers` threads).
pub struct ForecastService {
    pub handle: ForecastHandle,
    _pool: FreqPool,
}

impl ForecastService {
    /// Start the service for one frequency with backends built by
    /// `factory` *on each worker thread* (backends may be `!Send`; the
    /// factory is called once per worker). `state` is a trained
    /// [`ModelState`]; requests for series the model was not trained on
    /// get classical primer parameters (the shared RNN generalizes —
    /// paper §9's "generalization towards specific problems").
    pub fn start<F>(factory: F, freq: Frequency, state: ModelState,
                    opts: ServiceOptions) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let pool = FreqPool::start(std::sync::Arc::new(factory), freq, state,
                                   opts)?;
        Ok(Self { handle: pool.handle(), _pool: pool })
    }

    /// Start on the pure-Rust native backend (no artifacts needed).
    pub fn start_native(freq: Frequency, state: ModelState,
                        opts: ServiceOptions) -> Result<Self> {
        Self::start(|| Ok(Box::new(NativeBackend::new()) as Box<dyn Backend>),
                    freq, state, opts)
    }

    /// Start on the PJRT backend over an AOT artifact directory.
    #[cfg(feature = "pjrt")]
    pub fn start_pjrt(artifacts_dir: std::path::PathBuf, freq: Frequency,
                      state: ModelState, opts: ServiceOptions) -> Result<Self> {
        Self::start(
            move || {
                Ok(Box::new(crate::runtime::PjrtBackend::load(&artifacts_dir)?)
                   as Box<dyn Backend>)
            },
            freq, state, opts,
        )
    }
}

/// Convenience alias so callers can name the receiver type.
pub type ResponseReceiver = mpsc::Receiver<Result<ForecastResponse>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fitting() {
        let avail = vec![1, 16, 64, 256];
        assert_eq!(pick_batch(&avail, 1), 1);
        assert_eq!(pick_batch(&avail, 2), 16);
        assert_eq!(pick_batch(&avail, 16), 16);
        assert_eq!(pick_batch(&avail, 17), 64);
    }

    #[test]
    fn plan_splits_oversized_rounds() {
        // 500 pending with max program 256 → two executions, not a
        // truncated single one.
        assert_eq!(plan_batches(&[1, 16, 64, 256], 500), vec![256, 244]);
        assert_eq!(plan_batches(&[1, 16], 20), vec![16, 4]);
        assert_eq!(plan_batches(&[1, 16], 16), vec![16]);
        assert_eq!(plan_batches(&[8], 7), vec![7]);
        assert_eq!(plan_batches(&[4], 9), vec![4, 4, 1]);
    }

    #[test]
    fn plan_padding_accounting_is_exact() {
        // Padding per execution = pick_batch(real) - real; summed over an
        // oversized round it must count every padded slot.
        let avail = vec![1, 16, 64];
        let n = 100; // 64 + 36→64(pad 28)
        let mut padded = 0usize;
        let mut covered = 0usize;
        for real in plan_batches(&avail, n) {
            let b = pick_batch(&avail, real);
            assert!(b >= real, "split must remove truncation");
            padded += b - real;
            covered += real;
        }
        assert_eq!(covered, n);
        assert_eq!(padded, 28);
    }

    #[test]
    fn default_options_sane() {
        let o = ServiceOptions::default();
        assert!(o.max_batch >= 1);
        assert!(o.workers >= 1);
        assert!(o.batch_window >= Duration::from_millis(1));
    }

    #[test]
    fn stats_json_shape() {
        let st = ServiceStats { requests: 3, workers: 2, queue_depth: 5,
                                rejected_overload: 1,
                                ..Default::default() };
        let j = st.to_json();
        // Field names mirror the /v1/metrics names minus the prefix.
        assert_eq!(
            j.get("queue_accepted_total").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            j.get("queue_shed_total").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.get("queue_submitted_total").unwrap().as_usize().unwrap(),
            4, "submitted = accepted + shed");
        assert_eq!(j.get("pool_workers").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 5);
        assert!(j.get("queue_wait_seconds").unwrap().get("p99").is_ok());
        assert!(j.get("request_total_seconds").unwrap().get("p50").is_ok());
        assert!(j.get("backend_spawns").is_ok());
    }

    #[test]
    fn stats_json_round_trips() {
        let mut st = ServiceStats {
            requests: 10,
            rejected: 2,
            rejected_overload: 3,
            batches: 4,
            padded_slots: 5,
            reloads: 1,
            generation: 7,
            workers: 2,
            queue_depth: 1,
            queue_limit: 64,
            backend_spawns: 8,
            backend_steady_allocs: 0,
            backend_scratch_bytes: 123_456,
            observe_requests: 42,
            observe_new_series: 6,
            observe_stale: 2,
            state_series: 6,
            state_bytes: 4096,
            state_cache_hits: 30,
            state_cache_misses: 12,
            state_cache_invalidations: 9,
            ..Default::default()
        };
        st.total = LatencySummary {
            count: 10, p50: 0.002, p95: 0.0105, p99: 0.02,
        };
        st.queue_wait.p95 = 0.001;
        let text = st.to_json().to_string();
        let back =
            ServiceStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn stats_json_tolerates_pre_stateful_payloads() {
        // A PR-9 era remote shard emits no observe/state fields; the
        // aggregating router must parse its payload with zero defaults
        // instead of erroring the whole /v1/stats scrape.
        let modern = ServiceStats { requests: 4, workers: 1,
                                    ..Default::default() };
        let mut doc = match modern.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.retain(|k, _| !k.starts_with("observe_")
                   && !k.starts_with("state_"));
        let back = ServiceStats::from_json(&Json::Obj(doc)).unwrap();
        assert_eq!(back.requests, 4);
        assert_eq!(back.observe_requests, 0);
        assert_eq!(back.state_series, 0);
    }

    #[test]
    fn stats_absorb_sums_counters_and_takes_worst_latency() {
        let mut a = ServiceStats {
            requests: 10,
            rejected: 1,
            rejected_overload: 2,
            batches: 4,
            padded_slots: 3,
            reloads: 1,
            generation: 2,
            workers: 2,
            queue_depth: 1,
            queue_limit: 8,
            ..Default::default()
        };
        a.total.p95 = 0.010;
        let mut b = ServiceStats {
            requests: 5,
            rejected_overload: 7,
            generation: 5,
            workers: 2,
            queue_depth: 3,
            queue_limit: 8,
            ..Default::default()
        };
        b.total.p95 = 0.030;
        a.absorb(&b);
        assert_eq!(a.requests, 15);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.rejected_overload, 9);
        assert_eq!(a.generation, 5, "generation is the max, not a sum");
        assert_eq!(a.workers, 4);
        assert_eq!(a.queue_depth, 4);
        assert_eq!(a.queue_limit, 16);
        assert_eq!(a.total.p95, 0.030, "latency takes the worst shard");
    }

    #[test]
    fn stats_absorb_keeps_unbounded_queue_sentinel_sticky() {
        // Folding into a fresh accumulator adopts the first shard's
        // limit verbatim (including a real 0).
        let bounded = ServiceStats { workers: 2, queue_limit: 8,
                                     ..Default::default() };
        let unbounded = ServiceStats { workers: 2, queue_limit: 0,
                                       ..Default::default() };
        let mut agg = ServiceStats::default();
        agg.absorb(&bounded);
        assert_eq!(agg.queue_limit, 8);
        agg.absorb(&bounded);
        assert_eq!(agg.queue_limit, 16, "bounded shards sum");
        agg.absorb(&unbounded);
        assert_eq!(agg.queue_limit, 0,
                   "one unbounded shard makes the fleet unbounded");
        agg.absorb(&bounded);
        assert_eq!(agg.queue_limit, 0, "the sentinel is sticky");

        let mut agg = ServiceStats::default();
        agg.absorb(&unbounded);
        assert_eq!(agg.queue_limit, 0);
        agg.absorb(&bounded);
        assert_eq!(agg.queue_limit, 0);
    }
}
