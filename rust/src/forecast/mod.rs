//! Forecast service: a vLLM-router-style request loop over the backend's
//! predict program.
//!
//! Clients submit single series; the service dynamically batches them
//! (collect-until-deadline, like continuous batching in serving systems),
//! splits the pending set into executions no larger than the biggest
//! available batch program, pads each execution up to the smallest
//! program that fits, runs the backend and fans the results back out.
//!
//! Backends may be `!Send` (the PJRT client is), so the service owns its
//! backend on a dedicated thread and *constructs it there* from a factory
//! closure; the public [`ForecastHandle`] is a cheap clonable channel
//! endpoint usable from any thread (no async runtime available offline —
//! std threads + mpsc).

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{Category, Frequency, NetworkConfig};
use crate::coordinator::ModelState;
use crate::hw;
use crate::runtime::{execute_with_maps, Backend, HostTensor, Manifest,
                     NativeBackend};

/// A single forecast request: raw history (≥ C values) + category.
#[derive(Debug, Clone)]
pub struct ForecastRequest {
    pub id: String,
    pub values: Vec<f32>,
    pub category: Category,
}

/// The H-step forecast for one request.
#[derive(Debug, Clone)]
pub struct ForecastResponse {
    pub id: String,
    pub forecast: Vec<f32>,
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceOptions {
    /// How long to hold the first request while more arrive.
    pub batch_window: Duration,
    /// Cap on requests drained per batching round. May exceed the largest
    /// available batch program: the round is split into multiple
    /// executions, each padded-accounted individually.
    pub max_batch: usize,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        Self { batch_window: Duration::from_millis(4), max_batch: 256 }
    }
}

/// Counters exposed for tests/benches.
#[derive(Debug, Default, Clone)]
pub struct ServiceStats {
    pub requests: u64,
    /// Executed batches (one per backend execution, not per drain round).
    pub batches: u64,
    pub padded_slots: u64,
}

enum Msg {
    Request(ForecastRequest, mpsc::Sender<Result<ForecastResponse>>),
    Stats(mpsc::Sender<ServiceStats>),
    Shutdown,
}

/// Clonable client handle to a running service.
#[derive(Clone)]
pub struct ForecastHandle {
    tx: mpsc::Sender<Msg>,
}

impl ForecastHandle {
    /// Blocking single forecast.
    pub fn forecast(&self, req: ForecastRequest) -> Result<ForecastResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, tx))
            .map_err(|_| anyhow!("forecast service is down"))?;
        rx.recv().map_err(|_| anyhow!("forecast service dropped reply"))?
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit(&self, req: ForecastRequest)
                  -> Result<mpsc::Receiver<Result<ForecastResponse>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, tx))
            .map_err(|_| anyhow!("forecast service is down"))?;
        Ok(rx)
    }

    pub fn stats(&self) -> Result<ServiceStats> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats(tx))
            .map_err(|_| anyhow!("forecast service is down"))?;
        rx.recv().map_err(|_| anyhow!("forecast service dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// A running forecast service (backend thread + request channel).
pub struct ForecastService {
    pub handle: ForecastHandle,
    join: Option<JoinHandle<()>>,
}

impl ForecastService {
    /// Start the service for one frequency with a backend built by
    /// `factory` *on the service thread* (backends may be `!Send`).
    /// `state` is a trained [`ModelState`]; requests for series the model
    /// was not trained on get classical primer parameters (the shared RNN
    /// generalizes — paper §9's "generalization towards specific
    /// problems").
    pub fn start<F>(factory: F, freq: Frequency, state: ModelState,
                    opts: ServiceOptions) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let net = NetworkConfig::for_freq(freq)?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("forecast-{}", freq.name()))
            .spawn(move || {
                match factory() {
                    Ok(backend) => {
                        let _ = ready_tx.send(Ok(()));
                        serve(backend.as_ref(), net, state, opts, rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("service thread died during startup"))??;
        Ok(Self { handle: ForecastHandle { tx }, join: Some(join) })
    }

    /// Start on the pure-Rust native backend (no artifacts needed).
    pub fn start_native(freq: Frequency, state: ModelState,
                        opts: ServiceOptions) -> Result<Self> {
        Self::start(|| Ok(Box::new(NativeBackend::new()) as Box<dyn Backend>),
                    freq, state, opts)
    }

    /// Start on the PJRT backend over an AOT artifact directory.
    #[cfg(feature = "pjrt")]
    pub fn start_pjrt(artifacts_dir: std::path::PathBuf, freq: Frequency,
                      state: ModelState, opts: ServiceOptions) -> Result<Self> {
        Self::start(
            move || {
                Ok(Box::new(crate::runtime::PjrtBackend::load(&artifacts_dir)?)
                   as Box<dyn Backend>)
            },
            freq, state, opts,
        )
    }
}

impl Drop for ForecastService {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Pick the smallest available batch that fits `n`; callers must have
/// already split `n` to at most the largest available size.
fn pick_batch(available: &[usize], n: usize) -> usize {
    available
        .iter()
        .copied()
        .filter(|b| *b >= n)
        .min()
        .unwrap_or_else(|| available.iter().copied().max().unwrap_or(1))
}

/// Split a pending set of `n` requests into per-execution real counts,
/// each at most the largest available batch program. A drain round larger
/// than the biggest program becomes several executions instead of
/// silently truncating (the old behavior under-counted `padded_slots`
/// and over-read the forecast buffer).
fn plan_batches(available: &[usize], n: usize) -> Vec<usize> {
    let cap = available.iter().copied().max().unwrap_or(1);
    let mut plan = Vec::with_capacity(n.div_ceil(cap));
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(cap);
        plan.push(take);
        remaining -= take;
    }
    plan
}

fn serve(backend: &dyn Backend, net: NetworkConfig, state: ModelState,
         opts: ServiceOptions, rx: mpsc::Receiver<Msg>) {
    let freq = net.freq.name().to_string();
    let available = backend.manifest().available_batches(&freq, "predict");
    let mut stats = ServiceStats::default();

    loop {
        // Block for the first message.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut pending: Vec<(ForecastRequest,
                              mpsc::Sender<Result<ForecastResponse>>)> = Vec::new();
        match first {
            Msg::Shutdown => return,
            Msg::Stats(tx) => {
                let _ = tx.send(stats.clone());
                continue;
            }
            Msg::Request(r, tx) => pending.push((r, tx)),
        }
        // Dynamic batching window: gather more requests until deadline.
        let deadline = Instant::now() + opts.batch_window;
        while pending.len() < opts.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Request(r, tx)) => pending.push((r, tx)),
                Ok(Msg::Stats(tx)) => {
                    let _ = tx.send(stats.clone());
                }
                Ok(Msg::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Serve what we have, then exit.
                    run_round(backend, &net, &state, &available, &mut stats,
                              &mut pending);
                    return;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
            }
        }
        run_round(backend, &net, &state, &available, &mut stats, &mut pending);
    }
}

/// Serve one drained round of requests, splitting it into as many backend
/// executions as the available batch sizes require.
fn run_round(backend: &dyn Backend, net: &NetworkConfig, state: &ModelState,
             available: &[usize], stats: &mut ServiceStats,
             pending: &mut Vec<(ForecastRequest,
                                mpsc::Sender<Result<ForecastResponse>>)>) {
    if pending.is_empty() {
        return;
    }
    stats.requests += pending.len() as u64;
    let mut start = 0usize;
    for real in plan_batches(available, pending.len()) {
        let chunk = &pending[start..start + real];
        stats.batches += 1;
        match execute_batch(backend, net, state, available, stats, chunk) {
            Ok(forecasts) => {
                for ((req, tx), fc) in chunk.iter().zip(forecasts) {
                    let _ = tx.send(Ok(ForecastResponse {
                        id: req.id.clone(),
                        forecast: fc,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, tx) in chunk {
                    let _ = tx.send(Err(anyhow!("{msg}")));
                }
            }
        }
        start += real;
    }
    pending.clear();
}

fn execute_batch(backend: &dyn Backend, net: &NetworkConfig,
                 state: &ModelState, available: &[usize],
                 stats: &mut ServiceStats,
                 pending: &[(ForecastRequest,
                             mpsc::Sender<Result<ForecastResponse>>)])
                 -> Result<Vec<Vec<f32>>> {
    let n = pending.len();
    let b = pick_batch(available, n);
    let c = net.length;
    let h = net.horizon;
    stats.padded_slots += (b - n.min(b)) as u64;

    // Assemble y/cat plus per-request primer parameters.
    let mut y = Vec::with_capacity(b * c);
    let mut cat = vec![0.0f32; b * 6];
    let mut inputs: HashMap<String, HostTensor> = HashMap::new();
    let s_width = net.total_seasonality();
    let mut alpha = Vec::with_capacity(b);
    let mut gamma = Vec::with_capacity(b);
    let mut gamma2 = Vec::with_capacity(b);
    let mut s_init = Vec::with_capacity(b * s_width);
    for slot in 0..b {
        let (req, _) = &pending[slot.min(n - 1)];
        if req.values.len() < c {
            bail!("request `{}`: need ≥ {c} values, got {}", req.id,
                  req.values.len());
        }
        let window = &req.values[req.values.len() - c..];
        y.extend_from_slice(window);
        cat[slot * 6 + req.category.index()] = 1.0;
        let p = hw::primer_for(window, net.seasonality, net.seasonality2);
        alpha.push(p.alpha_logit);
        gamma.push(p.gamma_logit);
        gamma2.push(p.gamma2_logit);
        s_init.extend_from_slice(&p.log_s_init);
    }
    inputs.insert("data.y".into(), HostTensor::new(vec![b, c], y)?);
    inputs.insert("data.cat".into(), HostTensor::new(vec![b, 6], cat)?);
    inputs.insert("params.series.alpha_logit".into(),
                  HostTensor::new(vec![b], alpha)?);
    inputs.insert("params.series.gamma_logit".into(),
                  HostTensor::new(vec![b], gamma)?);
    inputs.insert("params.series.gamma2_logit".into(),
                  HostTensor::new(vec![b], gamma2)?);
    inputs.insert("params.series.log_s_init".into(),
                  HostTensor::new(vec![b, s_width], s_init)?);

    let name = Manifest::program_name(net.freq.name(), b, "predict");
    let outs = execute_with_maps(backend, &name, &inputs, &state.tensors)?;
    let fc = &outs[0].1;
    Ok((0..n).map(|i| fc.data[i * h..(i + 1) * h].to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fitting() {
        let avail = vec![1, 16, 64, 256];
        assert_eq!(pick_batch(&avail, 1), 1);
        assert_eq!(pick_batch(&avail, 2), 16);
        assert_eq!(pick_batch(&avail, 16), 16);
        assert_eq!(pick_batch(&avail, 17), 64);
    }

    #[test]
    fn plan_splits_oversized_rounds() {
        // 500 pending with max program 256 → two executions, not a
        // truncated single one.
        assert_eq!(plan_batches(&[1, 16, 64, 256], 500), vec![256, 244]);
        assert_eq!(plan_batches(&[1, 16], 20), vec![16, 4]);
        assert_eq!(plan_batches(&[1, 16], 16), vec![16]);
        assert_eq!(plan_batches(&[8], 7), vec![7]);
        assert_eq!(plan_batches(&[4], 9), vec![4, 4, 1]);
    }

    #[test]
    fn plan_padding_accounting_is_exact() {
        // Padding per execution = pick_batch(real) - real; summed over an
        // oversized round it must count every padded slot.
        let avail = vec![1, 16, 64];
        let n = 100; // 64 + 36→64(pad 28)
        let mut padded = 0usize;
        let mut covered = 0usize;
        for real in plan_batches(&avail, n) {
            let b = pick_batch(&avail, real);
            assert!(b >= real, "split must remove truncation");
            padded += b - real;
            covered += real;
        }
        assert_eq!(covered, n);
        assert_eq!(padded, 28);
    }

    #[test]
    fn default_options_sane() {
        let o = ServiceOptions::default();
        assert!(o.max_batch >= 1);
        assert!(o.batch_window >= Duration::from_millis(1));
    }
}
