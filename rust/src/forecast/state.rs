//! Memory-mapped per-series ES state store — the online half of the
//! paper's per-series parameters.
//!
//! Each modeled series owns one fixed-size record
//! `[crc | ordinal | observed | generation | level | ring1[S1] | ring2[S2]]`
//! in a log-structured slab (`state.slab`). Updates append a fresh
//! version of the record; the newest CRC-valid version wins on reopen,
//! so a crash mid-write can only lose the torn tail, never corrupt an
//! older version. Series ids live in an append-only sidecar
//! (`state.ids`, one id per line, line number = ordinal) so the slab
//! itself stays fixed-stride and mmap-friendly: a shard holding millions
//! of series pays heap only for the id → ordinal index, while the float
//! payload is paged in by the kernel on demand.
//!
//! Compaction (automatic once the slab is ≥ [`COMPACT_MIN_BYTES`] and
//! ≥ 75% garbage, or explicit via [`StateStore::compact`]) rewrites the
//! live records to a temp file and publishes it with an atomic rename —
//! the same write-then-rename discipline as the checkpoint writer.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::hw::EsState;
use anyhow::{anyhow, bail, Context, Result};

/// Slab header: magic + format version + ring widths.
pub const SLAB_MAGIC: &[u8; 8] = b"FESRNNST";
pub const SLAB_VERSION: u32 = 1;
const HEADER_BYTES: usize = 8 + 4 + 4 + 4;

/// Auto-compaction floor: below this slab size garbage is not worth
/// rewriting the file for.
pub const COMPACT_MIN_BYTES: u64 = 1 << 20;

/// One series' durable state: the live ES recurrence plus the model
/// generation it was last observed under (the forecast-cache key is
/// `(series, generation, observed)`).
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesRecord {
    pub state: EsState,
    pub generation: u64,
}

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — records are small, the
/// bitwise form keeps the module table-free.
fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Read-only `mmap(2)` wrapper over the slab file. `std` already links
/// libc on unix, so the two raw syscall bindings below add no
/// dependency; on other targets the store transparently falls back to
/// positioned reads.
#[cfg(unix)]
mod mm {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    use core::ffi::c_void;

    const PROT_READ: i32 = 1;
    const MAP_SHARED: i32 = 1;

    extern "C" {
        fn mmap(addr: *mut c_void, len: usize, prot: i32, flags: i32,
                fd: i32, offset: i64) -> *mut c_void;
        fn munmap(ptr: *mut c_void, len: usize) -> i32;
    }

    pub struct Mmap {
        ptr: *mut c_void,
        len: usize,
    }

    // SAFETY: the mapping is PROT_READ and owned exclusively by this
    // wrapper; concurrent shared reads of immutable pages are safe.
    unsafe impl Send for Mmap {}
    // SAFETY: as above — read-only pages, no interior mutation.
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `len` bytes of `file` read-only. Returns `None` on any
        /// failure (including `len == 0`, which `mmap` rejects) so the
        /// caller can fall back to positioned reads.
        pub fn map(file: &File, len: usize) -> Option<Mmap> {
            if len == 0 {
                return None;
            }
            // SAFETY: fd is a valid open file descriptor for the
            // lifetime of the call; a NULL addr + MAP_SHARED read-only
            // mapping has no aliasing requirements on our side. The
            // result is checked against MAP_FAILED before use.
            let ptr = unsafe {
                mmap(core::ptr::null_mut(), len, PROT_READ, MAP_SHARED,
                     file.as_raw_fd(), 0)
            };
            if ptr as usize == usize::MAX {
                return None;
            }
            Some(Mmap { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            // SAFETY: ptr/len come from a successful PROT_READ mapping
            // that lives exactly as long as `self`; the pages are never
            // written through this mapping.
            unsafe {
                core::slice::from_raw_parts(self.ptr as *const u8, self.len)
            }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: ptr/len are the exact values returned by mmap and
            // have not been unmapped before; double-unmap is impossible
            // because Drop runs once.
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset).context("read_exact_at")
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(offset)).context("seek")?;
        std::io::Read::read_exact(&mut f, buf).context("read_exact")
    }
}

fn write_all_at(file: &File, buf: &[u8], offset: u64) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.write_all_at(buf, offset).context("write_all_at")
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom, Write};
        let mut f = file;
        f.seek(SeekFrom::Start(offset)).context("seek")?;
        f.write_all(buf).context("write_all")
    }
}

enum Backing {
    /// Default: the slab lives in a heap buffer (no persistence).
    Mem(Vec<u8>),
    /// Durable: slab + ids sidecar on disk, slab mmapped read-only.
    Disk {
        file: File,
        #[cfg(unix)]
        map: Option<mm::Mmap>,
        len: u64,
        slab_path: PathBuf,
        ids_path: PathBuf,
        ids_file: File,
    },
}

struct Inner {
    backing: Backing,
    /// id → ordinal (dense, assigned at first observe).
    index: HashMap<String, u32>,
    /// ordinal → id (mirrors the ids sidecar).
    ids: Vec<String>,
    /// ordinal → byte offset of the newest live record, if any.
    offsets: Vec<Option<u64>>,
    live: usize,
}

/// The per-frequency series state store. One instance per `FreqPool`;
/// all mutation happens under a single mutex so an observe's
/// read-modify-write is atomic with respect to concurrent observes.
pub struct StateStore {
    s1: usize,
    s2: usize,
    // lint:lock-name(state.slab)
    inner: Mutex<Inner>,
}

impl StateStore {
    /// Payload bytes per record (everything after the CRC).
    fn payload_bytes(&self) -> usize {
        4 + 4 + 8 + 4 + 4 * (self.s1 + self.s2)
    }

    /// Total bytes per record, CRC included. Bounded by the acceptance
    /// contract: ≤ `4 * (4 + S1 + S2 + 3 floats)`.
    pub fn record_bytes(&self) -> usize {
        4 + self.payload_bytes()
    }

    fn header(&self) -> Vec<u8> {
        let mut h = Vec::with_capacity(HEADER_BYTES);
        h.extend_from_slice(SLAB_MAGIC);
        h.extend_from_slice(&SLAB_VERSION.to_le_bytes());
        h.extend_from_slice(&(self.s1 as u32).to_le_bytes());
        h.extend_from_slice(&(self.s2 as u32).to_le_bytes());
        h
    }

    /// In-memory store for the given ring widths (`s1` clamped to ≥ 1,
    /// `s2 == 0` means single seasonality).
    pub fn in_memory(s1: usize, s2: usize) -> StateStore {
        let store = StateStore {
            s1: s1.max(1),
            s2,
            inner: Mutex::new(Inner {
                backing: Backing::Mem(Vec::new()),
                index: HashMap::new(),
                ids: Vec::new(),
                offsets: Vec::new(),
                live: 0,
            }),
        };
        if let Backing::Mem(buf) = &mut store.inner.lock().unwrap().backing {
            buf.extend_from_slice(&store.header());
        }
        store
    }

    /// Open (or create) the durable store under `dir` — `dir/state.slab`
    /// plus `dir/state.ids`. A torn tail from a crashed writer is
    /// truncated; every intact record version before it survives.
    pub fn open(dir: &Path, s1: usize, s2: usize) -> Result<StateStore> {
        fs::create_dir_all(dir)
            .with_context(|| format!("create state dir {}", dir.display()))?;
        let slab_path = dir.join("state.slab");
        let ids_path = dir.join("state.ids");
        let store = StateStore {
            s1: s1.max(1),
            s2,
            inner: Mutex::new(Inner {
                backing: Backing::Mem(Vec::new()),
                index: HashMap::new(),
                ids: Vec::new(),
                offsets: Vec::new(),
                live: 0,
            }),
        };

        // Ids sidecar first: line number = ordinal.
        let mut ids: Vec<String> = Vec::new();
        let mut index = HashMap::new();
        if ids_path.exists() {
            let text = fs::read_to_string(&ids_path)
                .with_context(|| format!("read {}", ids_path.display()))?;
            for line in text.lines() {
                index.insert(line.to_string(), ids.len() as u32);
                ids.push(line.to_string());
            }
        }

        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&slab_path)
            .with_context(|| format!("open {}", slab_path.display()))?;
        let mut len = file
            .metadata()
            .context("slab metadata")?
            .len();
        if len == 0 {
            write_all_at(&file, &store.header(), 0)?;
            len = HEADER_BYTES as u64;
        } else {
            if len < HEADER_BYTES as u64 {
                bail!("state slab {} shorter than its header",
                      slab_path.display());
            }
            let mut head = [0u8; HEADER_BYTES];
            read_exact_at(&file, &mut head, 0)?;
            if &head[..8] != SLAB_MAGIC {
                bail!("{} is not a state slab (bad magic)",
                      slab_path.display());
            }
            let ver = u32::from_le_bytes([head[8], head[9], head[10],
                                          head[11]]);
            if ver != SLAB_VERSION {
                bail!("state slab version {ver} unsupported");
            }
            let fs1 = u32::from_le_bytes([head[12], head[13], head[14],
                                          head[15]]) as usize;
            let fs2 = u32::from_le_bytes([head[16], head[17], head[18],
                                          head[19]]) as usize;
            if fs1 != store.s1 || fs2 != store.s2 {
                bail!("state slab ring widths ({fs1},{fs2}) do not match \
                       the serving config ({},{})", store.s1, store.s2);
            }
        }

        // Replay: newest CRC-valid version per ordinal wins; stop (and
        // truncate) at the first short or corrupt record — that is the
        // torn tail of a crashed writer.
        let rb = store.record_bytes() as u64;
        let mut offsets: Vec<Option<u64>> = vec![None; ids.len()];
        let mut live = 0usize;
        let mut off = HEADER_BYTES as u64;
        let mut buf = vec![0u8; rb as usize];
        while off + rb <= len {
            read_exact_at(&file, &mut buf, off)?;
            let crc = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
            if crc != crc32(&buf[4..]) {
                break;
            }
            let ord = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]])
                as usize;
            if ord >= ids.len() {
                break;
            }
            if offsets[ord].is_none() {
                live += 1;
            }
            offsets[ord] = Some(off);
            off += rb;
        }
        if off < len {
            file.set_len(off).context("truncate torn slab tail")?;
        }
        len = off;

        let ids_file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&ids_path)
            .with_context(|| format!("open {}", ids_path.display()))?;
        {
            let mut inner = store.inner.lock().unwrap();
            inner.backing = Backing::Disk {
                #[cfg(unix)]
                map: mm::Mmap::map(&file, len as usize),
                file,
                len,
                slab_path,
                ids_path,
                ids_file,
            };
            inner.index = index;
            inner.ids = ids;
            inner.offsets = offsets;
            inner.live = live;
        }
        Ok(store)
    }

    pub fn s1(&self) -> usize {
        self.s1
    }

    pub fn s2(&self) -> usize {
        self.s2
    }

    /// Number of series with live state.
    pub fn series(&self) -> usize {
        self.inner.lock().unwrap().live
    }

    /// Current slab footprint in bytes (header + all record versions).
    pub fn bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        match &inner.backing {
            Backing::Mem(buf) => buf.len() as u64,
            Backing::Disk { len, .. } => *len,
        }
    }

    fn encode_record(&self, ord: u32, rec: &SeriesRecord) -> Result<Vec<u8>> {
        if rec.state.ring1.len() != self.s1
            || rec.state.ring2.len() != self.s2
        {
            bail!("record ring widths ({},{}) do not match the store \
                   ({},{})", rec.state.ring1.len(), rec.state.ring2.len(),
                  self.s1, self.s2);
        }
        let observed = u32::try_from(rec.state.observed)
            .map_err(|_| anyhow!("observed count {} exceeds the record \
                                  format", rec.state.observed))?;
        let mut body = Vec::with_capacity(self.payload_bytes());
        body.extend_from_slice(&ord.to_le_bytes());
        body.extend_from_slice(&observed.to_le_bytes());
        body.extend_from_slice(&rec.generation.to_le_bytes());
        body.extend_from_slice(&rec.state.level.to_le_bytes());
        for v in rec.state.ring1.iter().chain(rec.state.ring2.iter()) {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        Ok(out)
    }

    fn decode_record(&self, buf: &[u8]) -> SeriesRecord {
        let observed =
            u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as u64;
        let generation = u64::from_le_bytes([
            buf[12], buf[13], buf[14], buf[15], buf[16], buf[17], buf[18],
            buf[19],
        ]);
        let f = |i: usize| {
            f32::from_le_bytes([buf[20 + 4 * i], buf[21 + 4 * i],
                                buf[22 + 4 * i], buf[23 + 4 * i]])
        };
        let level = f(0);
        let ring1 = (0..self.s1).map(|i| f(1 + i)).collect();
        let ring2 = (0..self.s2).map(|i| f(1 + self.s1 + i)).collect();
        SeriesRecord {
            state: EsState { level, ring1, ring2, observed },
            generation,
        }
    }

    fn read_record(&self, inner: &Inner, off: u64) -> Result<SeriesRecord> {
        let rb = self.record_bytes();
        match &inner.backing {
            Backing::Mem(buf) => {
                let o = off as usize;
                Ok(self.decode_record(&buf[o..o + rb]))
            }
            Backing::Disk { file, len, .. } => {
                #[cfg(unix)]
                if let Backing::Disk { map: Some(m), .. } = &inner.backing {
                    let o = off as usize;
                    if off + rb as u64 <= m.as_slice().len() as u64 {
                        return Ok(self.decode_record(
                            &m.as_slice()[o..o + rb]));
                    }
                }
                if off + rb as u64 > *len {
                    bail!("record offset {off} past slab end {len}");
                }
                let mut buf = vec![0u8; rb];
                read_exact_at(file, &mut buf, off)?;
                Ok(self.decode_record(&buf))
            }
        }
    }

    /// Look up a series' live state.
    pub fn get(&self, id: &str) -> Result<Option<SeriesRecord>> {
        let inner = self.inner.lock().unwrap();
        let Some(&ord) = inner.index.get(id) else {
            return Ok(None);
        };
        match inner.offsets.get(ord as usize).copied().flatten() {
            Some(off) => Ok(Some(self.read_record(&inner, off)?)),
            None => Ok(None),
        }
    }

    /// Atomic read-modify-write: `f` sees the current record (if any)
    /// and returns the replacement. Returns the stored record and
    /// whether the series was newly created. The whole operation runs
    /// under the slab lock, so concurrent observes of one series
    /// serialize instead of losing updates.
    pub fn update<F>(&self, id: &str, f: F) -> Result<(SeriesRecord, bool)>
    where
        F: FnOnce(Option<SeriesRecord>) -> Result<SeriesRecord>,
    {
        if id.is_empty() || id.contains('\n') || id.contains('\r') {
            bail!("invalid series id");
        }
        let mut inner = self.inner.lock().unwrap();
        let existing_ord = inner.index.get(id).copied();
        let current = match existing_ord {
            Some(ord) => {
                match inner.offsets.get(ord as usize).copied().flatten() {
                    Some(off) => Some(self.read_record(&inner, off)?),
                    None => None,
                }
            }
            None => None,
        };
        let was_new = current.is_none();
        let rec = f(current)?;

        // Assign an ordinal (persisting the id first, so a crash between
        // the two appends leaves an id without a record — harmless).
        let ord = match existing_ord {
            Some(o) => o,
            None => {
                let o = inner.ids.len() as u32;
                if let Backing::Disk { ids_file, .. } = &mut inner.backing {
                    use std::io::Write;
                    ids_file
                        .write_all(format!("{id}\n").as_bytes())
                        .context("append state.ids")?;
                }
                inner.ids.push(id.to_string());
                inner.index.insert(id.to_string(), o);
                inner.offsets.push(None);
                o
            }
        };

        let bytes = self.encode_record(ord, &rec)?;
        let off = match &mut inner.backing {
            Backing::Mem(buf) => {
                let off = buf.len() as u64;
                buf.extend_from_slice(&bytes);
                off
            }
            Backing::Disk { file, len, .. } => {
                let off = *len;
                write_all_at(file, &bytes, off)?;
                *len = off + bytes.len() as u64;
                off
            }
        };
        if inner.offsets[ord as usize].is_none() {
            inner.live += 1;
        }
        inner.offsets[ord as usize] = Some(off);

        // Auto-compact once the slab is mostly dead versions.
        let total = match &inner.backing {
            Backing::Mem(buf) => buf.len() as u64,
            Backing::Disk { len, .. } => *len,
        };
        let live_bytes = HEADER_BYTES as u64
            + inner.live as u64 * self.record_bytes() as u64;
        if total >= COMPACT_MIN_BYTES && live_bytes * 4 <= total {
            self.compact_locked(&mut inner)?;
        }
        Ok((rec, was_new))
    }

    /// Rewrite the slab keeping only the newest version of each record,
    /// publishing via write-then-rename.
    pub fn compact(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.compact_locked(&mut inner)
    }

    fn compact_locked(&self, inner: &mut Inner) -> Result<()> {
        let rb = self.record_bytes() as u64;
        let ordinals: Vec<(usize, u64)> = inner
            .offsets
            .iter()
            .enumerate()
            .filter_map(|(ord, off)| off.map(|o| (ord, o)))
            .collect();
        let mut fresh = self.header();
        let mut new_offsets: Vec<Option<u64>> = vec![None; inner.ids.len()];
        for (ord, off) in ordinals {
            let rec = self.read_record(inner, off)?;
            let bytes = self.encode_record(ord as u32, &rec)?;
            new_offsets[ord] = Some(fresh.len() as u64);
            fresh.extend_from_slice(&bytes);
        }
        debug_assert_eq!(fresh.len() as u64,
                         HEADER_BYTES as u64 + inner.live as u64 * rb);
        match &mut inner.backing {
            Backing::Mem(buf) => {
                *buf = fresh;
            }
            Backing::Disk { file, len, slab_path, .. } => {
                let tmp = slab_path.with_extension("slab.tmp");
                fs::write(&tmp, &fresh)
                    .with_context(|| format!("write {}", tmp.display()))?;
                let tmp_file = File::open(&tmp).context("reopen tmp slab")?;
                tmp_file.sync_data().context("sync tmp slab")?;
                fs::rename(&tmp, &*slab_path)
                    .with_context(|| format!("publish {}",
                                             slab_path.display()))?;
                let reopened = OpenOptions::new()
                    .read(true)
                    .write(true)
                    .open(&*slab_path)
                    .context("reopen compacted slab")?;
                *len = fresh.len() as u64;
                *file = reopened;
            }
        }
        #[cfg(unix)]
        if let Backing::Disk { file, map, len, .. } = &mut inner.backing {
            *map = mm::Mmap::map(file, *len as usize);
        }
        inner.offsets = new_offsets;
        Ok(())
    }

    /// Serialize every live record (with its id) into a self-contained
    /// sidecar file — the checkpoint writer calls this so a reload on a
    /// fresh process restores the live states alongside the weights.
    pub fn export_to(&self, path: &Path) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let mut out = self.header();
        let live: Vec<(usize, u64)> = inner
            .offsets
            .iter()
            .enumerate()
            .filter_map(|(ord, off)| off.map(|o| (ord, o)))
            .collect();
        out.extend_from_slice(&(live.len() as u32).to_le_bytes());
        for (ord, off) in live {
            let rec = self.read_record(&inner, off)?;
            let id = inner.ids[ord].as_bytes();
            out.extend_from_slice(&(id.len() as u32).to_le_bytes());
            out.extend_from_slice(id);
            out.extend_from_slice(&self.encode_record(ord as u32, &rec)?);
        }
        let tmp = path.with_extension("state.tmp");
        fs::write(&tmp, &out)
            .with_context(|| format!("write {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("publish {}", path.display()))?;
        Ok(())
    }

    /// Load a sidecar written by [`export_to`](Self::export_to),
    /// merging its records into this store (imported records replace
    /// same-id state).
    pub fn import_from(&self, path: &Path) -> Result<usize> {
        let mut bytes = Vec::new();
        File::open(path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .with_context(|| format!("read {}", path.display()))?;
        if bytes.len() < HEADER_BYTES + 4 || &bytes[..8] != SLAB_MAGIC {
            bail!("{} is not a state sidecar", path.display());
        }
        let fs1 = u32::from_le_bytes([bytes[12], bytes[13], bytes[14],
                                      bytes[15]]) as usize;
        let fs2 = u32::from_le_bytes([bytes[16], bytes[17], bytes[18],
                                      bytes[19]]) as usize;
        if fs1 != self.s1 || fs2 != self.s2 {
            bail!("state sidecar ring widths ({fs1},{fs2}) do not match \
                   the store ({},{})", self.s1, self.s2);
        }
        let count = u32::from_le_bytes([
            bytes[HEADER_BYTES], bytes[HEADER_BYTES + 1],
            bytes[HEADER_BYTES + 2], bytes[HEADER_BYTES + 3],
        ]) as usize;
        let rb = self.record_bytes();
        let mut i = HEADER_BYTES + 4;
        let mut imported = 0usize;
        for _ in 0..count {
            if i + 4 > bytes.len() {
                bail!("truncated state sidecar");
            }
            let id_len = u32::from_le_bytes([bytes[i], bytes[i + 1],
                                             bytes[i + 2], bytes[i + 3]])
                as usize;
            i += 4;
            if i + id_len + rb > bytes.len() {
                bail!("truncated state sidecar");
            }
            let id = std::str::from_utf8(&bytes[i..i + id_len])
                .context("state sidecar id is not utf-8")?
                .to_string();
            i += id_len;
            let buf = &bytes[i..i + rb];
            let crc = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
            if crc != crc32(&buf[4..]) {
                bail!("state sidecar record for '{id}' fails its CRC");
            }
            let rec = self.decode_record(buf);
            i += rb;
            self.update(&id, |_| Ok(rec))?;
            imported += 1;
        }
        Ok(imported)
    }

    /// Ids of every series with live state (test/debug helper; the hot
    /// path never materializes this).
    pub fn ids(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .offsets
            .iter()
            .enumerate()
            .filter_map(|(ord, off)| {
                off.map(|_| inner.ids[ord].clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::es_state_seed;

    fn rec(seed: f32, s1: usize, s2: usize, observed: u64) -> SeriesRecord {
        SeriesRecord {
            state: EsState {
                level: seed,
                ring1: (0..s1).map(|i| seed + i as f32).collect(),
                ring2: (0..s2).map(|i| seed - i as f32).collect(),
                observed,
            },
            generation: 7,
        }
    }

    #[test]
    fn record_size_within_acceptance_bound() {
        for (s1, s2) in [(1usize, 0usize), (12, 0), (24, 168)] {
            let st = StateStore::in_memory(s1, s2);
            assert!(st.record_bytes() <= 4 * (4 + s1 + s2 + 3),
                    "({s1},{s2}): {} bytes", st.record_bytes());
        }
    }

    #[test]
    fn memory_store_round_trips_and_counts() {
        let st = StateStore::in_memory(4, 0);
        assert_eq!(st.series(), 0);
        assert!(st.get("a").unwrap().is_none());
        let (r, new) = st.update("a", |cur| {
            assert!(cur.is_none());
            Ok(rec(1.0, 4, 0, 10))
        }).unwrap();
        assert!(new);
        assert_eq!(r.state.observed, 10);
        let (_, new) = st.update("a", |cur| {
            let mut r = cur.unwrap();
            r.state.observed += 1;
            Ok(r)
        }).unwrap();
        assert!(!new);
        assert_eq!(st.series(), 1);
        assert_eq!(st.get("a").unwrap().unwrap().state.observed, 11);
    }

    #[test]
    fn disk_store_persists_across_reopen() {
        let dir = std::env::temp_dir()
            .join(format!("fesrnn-state-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        {
            let st = StateStore::open(&dir, 12, 0).unwrap();
            for i in 0..50 {
                st.update(&format!("s{i}"), |_| Ok(rec(i as f32, 12, 0, i)))
                    .unwrap();
            }
            // Update a subset so multiple versions exist.
            for i in 0..10 {
                st.update(&format!("s{i}"), |cur| {
                    let mut r = cur.unwrap();
                    r.state.level += 100.0;
                    Ok(r)
                }).unwrap();
            }
        }
        let st = StateStore::open(&dir, 12, 0).unwrap();
        assert_eq!(st.series(), 50);
        assert_eq!(st.get("s3").unwrap().unwrap().state.level, 103.0);
        assert_eq!(st.get("s30").unwrap().unwrap().state.level, 30.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_without_losing_older_versions() {
        let dir = std::env::temp_dir()
            .join(format!("fesrnn-state-torn-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        {
            let st = StateStore::open(&dir, 4, 0).unwrap();
            st.update("a", |_| Ok(rec(1.0, 4, 0, 5))).unwrap();
            st.update("b", |_| Ok(rec(2.0, 4, 0, 6))).unwrap();
        }
        // Simulate a crash mid-append: garbage half-record at the tail.
        let slab = dir.join("state.slab");
        let mut bytes = fs::read(&slab).unwrap();
        bytes.extend_from_slice(&[0xAB; 13]);
        fs::write(&slab, &bytes).unwrap();
        let st = StateStore::open(&dir, 4, 0).unwrap();
        assert_eq!(st.series(), 2);
        assert_eq!(st.get("a").unwrap().unwrap().state.observed, 5);
        assert_eq!(st.get("b").unwrap().unwrap().state.observed, 6);
        // A corrupted full-size tail record is also dropped.
        let mut bytes = fs::read(&slab).unwrap();
        let rb = st.record_bytes();
        bytes.extend_from_slice(&vec![0x5A; rb]);
        drop(st);
        fs::write(&slab, &bytes).unwrap();
        let st = StateStore::open(&dir, 4, 0).unwrap();
        assert_eq!(st.series(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_bounds_slab_growth() {
        let st = StateStore::in_memory(2, 0);
        let rb = st.record_bytes() as u64;
        for round in 0..200u64 {
            for i in 0..40 {
                st.update(&format!("s{i}"), |_| Ok(rec(1.0, 2, 0, round)))
                    .unwrap();
            }
        }
        st.compact().unwrap();
        assert_eq!(st.series(), 40);
        assert_eq!(st.bytes(), HEADER_BYTES as u64 + 40 * rb);
        assert_eq!(st.get("s39").unwrap().unwrap().state.observed, 199);
    }

    #[test]
    fn sidecar_export_import_round_trip() {
        let dir = std::env::temp_dir()
            .join(format!("fesrnn-state-side-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let st = StateStore::in_memory(3, 2);
        st.update("x", |_| Ok(rec(4.0, 3, 2, 9))).unwrap();
        st.update("y", |_| Ok(rec(5.0, 3, 2, 11))).unwrap();
        let side = dir.join("ck.state");
        st.export_to(&side).unwrap();
        let fresh = StateStore::in_memory(3, 2);
        assert_eq!(fresh.import_from(&side).unwrap(), 2);
        assert_eq!(fresh.get("x").unwrap().unwrap(), rec(4.0, 3, 2, 9));
        assert_eq!(fresh.get("y").unwrap().unwrap(), rec(5.0, 3, 2, 11));
        // Width mismatch is a descriptive error, not silent corruption.
        let wrong = StateStore::in_memory(4, 0);
        assert!(wrong.import_from(&side).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hundred_thousand_series_round_trip() {
        // Acceptance bar: the store round-trips ≥ 100k series per shard.
        let st = StateStore::in_memory(1, 0);
        for i in 0..100_000u64 {
            let mut state = es_state_seed(&[i as f32 + 1.0, i as f32 + 2.0],
                                          1, 0);
            state.observed = i;
            st.update(&format!("M4-{i}"), |_| {
                Ok(SeriesRecord { state: state.clone(), generation: 1 })
            }).unwrap();
        }
        assert_eq!(st.series(), 100_000);
        assert_eq!(st.get("M4-99999").unwrap().unwrap().state.observed,
                   99_999);
        assert_eq!(st.bytes(),
                   HEADER_BYTES as u64
                       + 100_000 * st.record_bytes() as u64);
    }
}
